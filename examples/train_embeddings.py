"""End-to-end training driver example: train a ~100M-class LM config for a
few hundred steps with async checkpointing and a simulated failure+restart.

    PYTHONPATH=src python examples/train_embeddings.py [--steps 300]

On this CPU container the arch is the reduced qwen2 family config scaled up
to ~20M params (a few hundred steps in minutes); on a real pod the same
driver takes --arch qwen2-1.5b without --smoke (identical code path — mesh,
sharded init, prefetch, checkpoints).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.train import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    # ~20M params: the biggest qwen2-family config that trains a few hundred
    # steps in CPU-minutes
    cfg = get_smoke_config("qwen2-1.5b").with_overrides(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
        d_ff=1024, vocab_size=32_000)
    print(f"arch family: qwen2 (reduced) — {cfg.param_count() / 1e6:.1f}M "
          f"params, {args.steps} steps")

    with tempfile.TemporaryDirectory() as ckpt:
        if args.fail_at:
            try:
                train(cfg, steps=args.steps, global_batch=8, seq_len=128,
                      ckpt_dir=ckpt, checkpoint_every=25, lr=1e-3,
                      log_every=25, simulate_failure_at=args.fail_at)
            except RuntimeError:
                print(">>> simulated failure; restarting from checkpoint")
        out = train(cfg, steps=args.steps, global_batch=8, seq_len=128,
                    ckpt_dir=ckpt, checkpoint_every=25, lr=1e-3,
                    log_every=25)
    print(f"done in {out['seconds']:.1f}s; final loss {out['final_loss']:.4f}")
    first = out["metrics"][0]["loss"] if out["metrics"] else float("nan")
    print(f"loss {first:.3f} -> {out['final_loss']:.3f} "
          f"({'learning' if out['final_loss'] < first else 'check lr'})")


if __name__ == "__main__":
    main()
