"""Retrieval-augmented serving: an LM backbone embeds documents, a sharded
Quantixar collection indexes them, and declarative query plans retrieve
before decode.

    PYTHONPATH=src python examples/rag_serve.py

This is the combined-system story (DESIGN.md §5): the vector database is the
retrieval layer for any assigned architecture; here the reduced qwen2 family
config is the embedder AND the generator.  Documents live in ONE
`ShardedCollection` (`shards=4`) under stable string ids ("doc-<i>"): rows
hash-partition across in-process engine shards, every query plan scatters to
all shards and exact-merges the global top-k, and the shard layout that used
to be hand-rolled (a `shard` keyword payload plus one prefetch sub-query per
shard, RRF-fused) is now the collection's own routing — the same plan runs
unchanged on one shard or eight, embedded or over the wire.
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import Database, VectorField  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.data.synthetic import zipf_tokens  # noqa: E402
from repro.models import init_train_state, make_serve_step  # noqa: E402
from repro.models.model import forward, init_decode_state  # noqa: E402

N_DOCS, DOC_LEN, N_SHARDS = 512, 24, 4


def main():
    cfg = get_smoke_config("qwen2-1.5b")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    params = state.params
    rng = np.random.RandomState(0)

    # 1. "documents" = token sequences; embedding = mean-pooled hidden state
    docs = zipf_tokens(rng, (N_DOCS, DOC_LEN), cfg.vocab_size)

    @jax.jit
    def embed(tokens):
        logits, _ = forward(params, {"tokens": tokens}, cfg)
        return logits.mean(axis=1)          # (B, V) pooled next-token dist

    print("embedding documents ...")
    emb = np.asarray(embed(jnp.asarray(docs)), dtype=np.float32)
    dim = emb.shape[1]

    # 2. one sharded collection: rows hash-partition by id across N_SHARDS
    #    engine shards, searches scatter-gather with an exact global merge —
    #    no per-shard payload tags or manual prefetch fan-out needed
    db = Database()
    col = db.create_collection(
        name="docs", vector=VectorField(dim=dim, index="flat"),
        shards=N_SHARDS)
    col.upsert([f"doc-{i}" for i in range(N_DOCS)], emb)

    # 3. retrieval-augmented decode: retrieve nearest doc, prepend, generate
    serve = jax.jit(make_serve_step(cfg))
    queries = zipf_tokens(rng, (8, DOC_LEN), cfg.vocab_size)
    q_emb = np.asarray(embed(jnp.asarray(queries)), dtype=np.float32)

    t0 = time.perf_counter()
    retrieved = [col.query(q).top_k(3).run() for q in q_emb]
    print(f"retrieved top-3 docs for 8 queries in "
          f"{time.perf_counter() - t0:.2f}s "
          f"(scatter-gather across {col.num_shards} shards)")
    explain = col.query(q_emb[0]).top_k(3).explain()
    print(f"retrieval plan: {explain}")
    rows = [f"{s['shard']}: {s['rows']} rows" for s in col.shard_stats()]
    print(f"shard layout: {', '.join(rows)}")

    # prefill query + best doc, then greedy-decode 8 tokens
    best = np.array([int(hits[0].id.split("-")[1]) for hits in retrieved])
    ctx = np.concatenate([docs[best], queries], axis=1)  # (8, 2*DOC_LEN)
    dstate = init_decode_state(cfg, 8, ctx.shape[1] + 16)
    tok = jnp.asarray(ctx[:, :1])
    for t in range(ctx.shape[1] - 1):      # teacher-forced prefill
        _, dstate = serve(params, dstate, jnp.asarray(ctx[:, t:t + 1]))
        tok = jnp.asarray(ctx[:, t + 1:t + 2])
    gen = []
    for _ in range(8):                      # generation
        tok, dstate = serve(params, dstate, tok)
        gen.append(np.asarray(tok)[:, 0])
    print("generated continuations (token ids):")
    for i, row in enumerate(np.stack(gen, axis=1)):
        print(f"  q{i}: doc={int(best[i])} -> {row.tolist()}")
    db.close()


if __name__ == "__main__":
    main()
