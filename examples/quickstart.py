"""Quantixar quickstart: the collection-oriented public API end to end.

    PYTHONPATH=src python examples/quickstart.py

Covers: declarative schema (vector field + typed metadata), string-id
upsert, fluent filtered queries, quantized collections with rescore,
delete/tombstone + compact, Database save/load persistence, client mode
(the same fluent query over the embedded HTTP server via QuantixarClient),
declarative query plans (coarse-to-fine `.stages()`, prefetch + RRF
fusion, filtered `count()`, and `.explain()` introspection), hybrid
search (BM25 keyword via `TextField` + `.text()`, fused with dense ANN),
and sharded serving (`shards=N` hash-partitions rows across in-process
engine shards with an exact scatter-gather merge, live `rebalance()`,
and per-shard stats).
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.api import (BoolField, CollectionSchema, Database,  # noqa: E402
                       KeywordField, NumericField, Predicate, VectorField)
from repro.core import BQConfig, PQConfig, exact_knn  # noqa: E402
from repro.data.synthetic import gaussian_mixture  # noqa: E402

N, DIM, K = 8000, 64, 10


def recall(hit_ids, gt):
    return np.mean([len(set(ids) & {f"item-{j}" for j in row}) / gt.shape[1]
                    for ids, row in zip(hit_ids, gt)])


def main():
    print("== Quantixar quickstart ==")
    corpus = gaussian_mixture(N, DIM, n_clusters=24, scale=0.2, seed=0)
    queries = gaussian_mixture(32, DIM, n_clusters=24, scale=0.2, seed=1)
    gt = exact_knn(queries, corpus, K, metric="cosine")
    ids = [f"item-{i}" for i in range(N)]
    payloads = [{"category": f"cat-{i % 8}", "price": float(i % 100),
                 "in_stock": i % 5 != 0} for i in range(N)]

    db = Database()

    # 1. HNSW collection (the paper's default path) -------------------------
    # ef_search=128: the bulk builder trades a little graph quality for a
    # ~100x faster build (examples/ann_benchmark.py --full uses the paper's
    # incremental algorithm, recall ~0.99 at ef=64)
    items = db.create_collection(CollectionSchema(
        name="items",
        vector=VectorField(dim=DIM, metric="cosine", index="hnsw",
                           ef_search=128),
        fields=(KeywordField("category"), NumericField("price"),
                BoolField("in_stock"))))
    t0 = time.perf_counter()
    items.upsert(ids, corpus, payloads)
    hits = items.query(queries[0]).top_k(K).run()   # triggers the build
    print(f"hnsw build: {time.perf_counter() - t0:.2f}s  "
          f"stats={items.stats()}")

    t0 = time.perf_counter()
    batches = items.query(queries).top_k(K).run()
    qps = len(queries) / (time.perf_counter() - t0)
    r = recall([[h.id for h in hs] for hs in batches], gt)
    print(f"vector query: recall@{K}={r:.3f} ({qps:.0f} QPS)")

    # 2. MEVS: schema-validated filtered search -----------------------------
    hits = (items.query(queries[0])
            .filter(category="cat-3", in_stock=True)
            .where("price", "lt", 50)
            .top_k(5)
            .run())
    cats = {h.payload["category"] for h in hits}
    print(f"filtered query category==cat-3 & price<50 & in_stock: "
          f"{[h.id for h in hits]} cats={cats}")

    # 3. Quantized collections ----------------------------------------------
    for quant, qcfg in (("pq", {"pq": PQConfig(m=16, k=64, iters=10)}),
                        ("bq", {"bq": BQConfig(bits=256)})):
        col = db.create_collection(
            name=f"items-{quant}",
            vector=VectorField(dim=DIM, index="flat", quantization=quant,
                               **qcfg))
        col.upsert(ids, corpus)
        batches = col.query(queries).top_k(K).run()
        r = recall([[h.id for h in hs] for hs in batches], gt)
        print(f"{quant}+rescore: recall@{K}={r:.3f} "
              f"compression={col.stats()['compression']:.0f}x")

    # 4. Upsert / delete / compact ------------------------------------------
    items.upsert("item-0", queries[0], [{"category": "cat-0", "price": 1.0}])
    items.delete(["item-1", "item-2"])
    print(f"after upsert+delete: live={len(items)} "
          f"tombstones={items.tombstones}")
    reclaimed = items.compact()
    print(f"compact() reclaimed {reclaimed} rows; live={len(items)}")

    # 5. Persistence --------------------------------------------------------
    with tempfile.TemporaryDirectory() as tmp:
        db.save(tmp, step=1)
        db2 = Database.load(tmp)
        same = ([h.id for h in db2["items"].query(queries[1]).top_k(K).run()]
                == [h.id for h in items.query(queries[1]).top_k(K).run()])
        print(f"Database save/load round-trip identical: {same}")
        print(f"collections on disk: {db2.list_collections()}")
        db2.close()

    # 6. Client mode: the same surface over the wire ------------------------
    # The service plane wraps this very Database in an embedded HTTP server;
    # QuantixarClient mirrors Database/Collection, so the query above runs
    # unchanged over REST (single-vector wire searches coalesce through the
    # serving batcher on the server side).
    from repro.api import QuantixarClient  # noqa: E402
    from repro.serving.http import QuantixarHTTPServer  # noqa: E402
    from repro.serving.service import QuantixarService  # noqa: E402

    server = QuantixarHTTPServer(QuantixarService(db)).start()
    client = QuantixarClient(server.url)
    remote = client.collection("items")
    wire_hits = (remote.query(queries[0])
                 .filter(category="cat-3", in_stock=True)
                 .where("price", "lt", 50)
                 .top_k(5)
                 .run())
    embedded_hits = (items.query(queries[0])
                     .filter(category="cat-3", in_stock=True)
                     .where("price", "lt", 50)
                     .top_k(5)
                     .run())
    print(f"client mode @ {server.url}: wire == embedded hits: "
          f"{[h.id for h in wire_hits] == [h.id for h in embedded_hits]}")
    serving_stats = {k: v for k, v in remote.stats().items()
                     if k.startswith("serving_")}
    print(f"server-side serving stats: {serving_stats}")
    server.shutdown(close_service=False)

    # 7. Query plans: coarse-to-fine, fusion, explain -----------------------
    # Every query compiles to a declarative QueryPlan; .stages() makes the
    # quantized coarse-to-fine retrieval explicit (code-domain first pass
    # fetching oversample*k, exact float rescore down to k) and .explain()
    # shows the per-stage candidate counts and timings.
    pq_items = db["items-pq"]
    ex = pq_items.query(queries[0]).top_k(K).stages(oversample=4).explain()
    print("coarse-to-fine explain:")
    for s in ex.stages:
        print(f"  {s['stage']:<8} k={s['k']:<4} in={s['candidates_in']:<4} "
              f"out={s['candidates_out']:<4} {s['seconds'] * 1e3:7.2f} ms")
    # prefetch + fusion: independent sub-queries merged by reciprocal rank
    fused = (items.query(queries[0]).top_k(5)
             .prefetch(category="cat-1")
             .prefetch(category="cat-2")
             .fuse("rrf")
             .run())
    print(f"prefetch+rrf across cat-1/cat-2: "
          f"{[(h.id, h.payload['category']) for h in fused]}")
    # filtered cardinality without fetching hits, and example-based queries
    n_cat3 = items.count(Predicate("category", "eq", "cat-3"))
    rec = items.recommend(positives=["item-5", "item-10"],
                          negatives=["item-99"]).top_k(3).run()
    print(f"count(category==cat-3)={n_cat3}; "
          f"recommend from examples -> {[h.id for h in rec]}")

    # 8. Hybrid search: BM25 keyword + dense fusion -------------------------
    # A TextField on the schema maintains an incremental BM25 inverted index;
    # .text() alone is pure keyword search, vector + .text() compiles to a
    # prefetch of [dense ann, sparse bm25] legs fused by reciprocal rank.
    from repro.api import TextField  # noqa: E402

    tags = [f"tag{i % 16}" for i in range(N)]
    docs = db.create_collection(CollectionSchema(
        name="docs",
        vector=VectorField(dim=DIM, metric="cosine", index="flat"),
        fields=(TextField("body"), KeywordField("lang"))))
    docs.upsert(ids, corpus,
                [{"body": f"{t} quick brown fox", "lang": "en" if i % 2
                  else "de"} for i, t in enumerate(tags)])

    kw = docs.query().text("tag3 fox").top_k(3).run()
    print(f"keyword 'tag3 fox': {[h.id for h in kw]}")
    kw_f = (docs.query().text("tag3 fox").filter(lang="en").top_k(3).run())
    langs = {docs.get(h.id).payload["lang"] for h in kw_f}
    print(f"filtered keyword (lang==en): {[h.id for h in kw_f]} "
          f"langs={langs}")

    hybrid = docs.query(queries[0]).text("tag3 fox").top_k(5)
    ex = hybrid.explain()
    legs = [c[0]["stage"] for c in ex.stages[0]["children"]]
    print(f"hybrid query -> {[s['stage'] for s in ex.stages]} "
          f"legs={legs}; hits={[h.id for h in hybrid.run()]}")
    sparse_stats = {k: v for k, v in docs.stats().items()
                    if k.startswith("sparse_")}
    print(f"sparse stats: {sparse_stats}")

    # the same hybrid query over the wire, hit-for-hit
    server = QuantixarHTTPServer(QuantixarService(db)).start()
    remote_docs = QuantixarClient(server.url).collection("docs")
    wire = (remote_docs.query(queries[0]).text("tag3 fox").top_k(5).run())
    print(f"hybrid wire == embedded hits: "
          f"{[h.id for h in wire] == [h.id for h in hybrid.run()]}")
    server.shutdown(close_service=False)
    db.close()

    # 9. Sharded serving: hash-partitioned scatter-gather -------------------
    # shards=N builds a ShardedCollection behind the same API: rows
    # hash-partition by string id across N in-process engine shards
    # (replicated `replicas` times), every query scatters to all shards and
    # exact-merges the global top-k — the SAME hits as one engine, embedded
    # or over the wire.  rebalance() re-partitions live via per-shard
    # snapshots; shard_stats() shows the layout.
    db = Database()
    single = db.create_collection(name="single",
                                  vector=VectorField(dim=DIM, index="flat"))
    sharded = db.create_collection(
        name="sharded", vector=VectorField(dim=DIM, index="flat"),
        shards=3, replicas=2)
    single.upsert(ids, corpus)
    sharded.upsert(ids, corpus)
    want = [h.id for h in single.query(queries[0]).top_k(K).run()]
    got = [h.id for h in sharded.query(queries[0]).top_k(K).run()]
    print(f"sharded (3 shards x 2 replicas) == single-engine hits: "
          f"{got == want}")
    info = sharded.rebalance(shards=4, replicas=1)
    got = [h.id for h in sharded.query(queries[0]).top_k(K).run()]
    print(f"rebalanced 3x2 -> {info['shards']}x{info['replicas']} in "
          f"{info['seconds']:.2f}s; hits still identical: {got == want}")
    server = QuantixarHTTPServer(QuantixarService(db)).start()
    remote_sh = QuantixarClient(server.url).collection("sharded")
    layout = [(s["shard"], s["rows"]) for s in remote_sh.shard_stats()]
    print(f"wire shard layout (shard, rows): {layout}")
    server.shutdown(close_service=False)
    db.close()


if __name__ == "__main__":
    main()
