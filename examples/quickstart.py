"""Quantixar quickstart: the paper's engine end to end on one host.

    PYTHONPATH=src python examples/quickstart.py

Covers: entity insert (vectors + metadata), HNSW build, vector query, MEVS
filtered query, PQ/BQ quantized engines with rescore, persistence round-trip.
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (And, BQConfig, EngineConfig, PQConfig, Predicate,
                        QuantixarEngine, exact_knn)  # noqa: E402
from repro.data.synthetic import gaussian_mixture  # noqa: E402

N, DIM, K = 8000, 64, 10


def recall(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / gt.shape[1]
                    for a, b in zip(ids, gt)])


def main():
    print("== Quantixar quickstart ==")
    corpus = gaussian_mixture(N, DIM, n_clusters=24, scale=0.2, seed=0)
    queries = gaussian_mixture(32, DIM, n_clusters=24, scale=0.2, seed=1)
    meta = [{"category": int(i % 8), "price": float(i % 100)}
            for i in range(N)]
    gt = exact_knn(queries, corpus, K, metric="cosine")

    # 1. HNSW engine (the paper's default path) -----------------------------
    # ef_search=128: the bulk builder trades a little graph quality for a
    # ~100x faster build (examples/ann_benchmark.py --full uses the paper's
    # incremental algorithm, recall ~0.99 at ef=64)
    eng = QuantixarEngine(EngineConfig(dim=DIM, index="hnsw", ef_search=128,
                                       quantization="none", builder="bulk"))
    t0 = time.perf_counter()
    eng.add(corpus, meta)
    eng.build()
    print(f"hnsw build: {time.perf_counter() - t0:.2f}s  stats={eng.stats()}")

    t0 = time.perf_counter()
    d, ids = eng.search(queries, K)
    print(f"vector query: recall@{K}={recall(ids, gt):.3f} "
          f"({len(queries) / (time.perf_counter() - t0):.0f} QPS)")

    # 2. MEVS: metadata-filtered search --------------------------------------
    flt = And([Predicate("category", "eq", 3), Predicate("price", "lt", 50)])
    d, ids = eng.search(queries, 5, flt=flt)
    cats = {meta[i]["category"] for i in ids.ravel() if i >= 0}
    print(f"MEVS filter category==3 & price<50: returned cats={cats}")

    # 3. Quantized engines ----------------------------------------------------
    for quant, qcfg in (("pq", {"pq": PQConfig(m=16, k=64, iters=10)}),
                        ("bq", {"bq": BQConfig(bits=256)})):
        e = QuantixarEngine(EngineConfig(dim=DIM, index="flat",
                                         quantization=quant, **qcfg))
        e.add(corpus)
        e.build()
        _, ids = e.search(queries, K)
        print(f"{quant}+rescore: recall@{K}={recall(ids, gt):.3f} "
              f"compression={e.stats()['compression']:.0f}x")

    # 4. Persistence ----------------------------------------------------------
    from repro.checkpoint import CheckpointStore
    with tempfile.TemporaryDirectory() as tmp:
        store = CheckpointStore(tmp)
        store.save(eng.state_dict(), step=1)
        eng2 = QuantixarEngine.from_state_dict(eng.config, store.load())
        _, ids2 = eng2.search(queries, K)
        print(f"persistence round-trip identical: "
              f"{bool((ids2 == eng.search(queries, K)[1]).all())}")


if __name__ == "__main__":
    main()
