"""Paper Table I, example-sized: HNSW on Fashion-MNIST-like / SIFT-like data.

    PYTHONPATH=src python examples/ann_benchmark.py [--n 4000] [--full]

--full uses the faithful incremental builder (paper's algorithm, slower);
default uses the bulk builder so the example finishes in ~1 CPU minute.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--full", action="store_true",
                    help="faithful incremental builder (paper Alg 1)")
    args = ap.parse_args()

    from benchmarks import bench_hnsw
    builder = "incremental" if args.full else "bulk"
    rows = bench_hnsw.main(n_fmnist=args.n, n_sift=args.n,
                           n_queries=args.queries, builder=builder)
    print("\npaper Table I reference points: recall ef=64: 0.978 (fmnist) / "
          "0.9908 (sift); ef=128: 0.9964 (sift); last-dist ratio ~1.000x")
    worst = min(r["recall"] for r in rows)
    print(f"our worst recall across cells: {worst:.4f} "
          f"({'matches paper band' if worst > 0.95 else 'below paper band'})")


if __name__ == "__main__":
    main()
