# Tier-1 verification + smoke runs.

PY ?= python

.PHONY: test smoke ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke:
	PYTHONPATH=src $(PY) examples/quickstart.py

ci: test smoke
