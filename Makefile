# Tier-1 verification + smoke runs.

PY ?= python

.PHONY: test lint qlint fuzz-smoke smoke serve-smoke serve bench \
	bench-smoke bench-serve bench-query bench-query-smoke \
	bench-hybrid bench-hybrid-smoke ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Static analysis: the repo-custom qlint analyzers always run (stdlib-only);
# ruff and mypy run when installed (CI installs them via requirements-dev)
# and are skipped with a notice otherwise, so `make lint` works in minimal
# containers too.
lint: qlint
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tools tests benchmarks; \
	else echo "lint: ruff not installed, skipping (pip install -r requirements-dev.txt)"; fi
	@if command -v mypy >/dev/null 2>&1; then \
		mypy src/repro/api/requests.py src/repro/api/plan.py \
			src/repro/api/schema.py; \
	else echo "lint: mypy not installed, skipping (pip install -r requirements-dev.txt)"; fi

# lock discipline + wire-protocol exhaustiveness + jax/pallas hygiene
qlint:
	PYTHONPATH=src:. $(PY) -m tools.qlint

# thread-fuzz stress test under instrumented (deadlock-detecting) locks;
# bounded so a real deadlock fails the run instead of wedging it
fuzz-smoke:
	PYTHONPATH=src:. $(PY) -m pytest tests/test_fuzz_concurrency.py -x -q

# full HNSW width x ef sweep, incremental and bulk builders side by side
# -> BENCH_hnsw.json at the repo root (timestamp passed in at the make
# boundary, not sampled by the writer); the bulk path must be >=10x
# faster at recall within 0.02 of incremental
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --only table1 \
		--builder both --min-speedup 10 \
		--out BENCH_hnsw.json --timestamp $$(date +%s)

# CI-sized sweep with a recall floor + builder-throughput floor: perf PRs
# can't trade away quality, and the bulk builder can't regress below 5x
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --only table1 --fast \
		--builder both --min-speedup 5 \
		--out BENCH_hnsw.json --timestamp $$(date +%s) --min-recall 0.9

smoke:
	PYTHONPATH=src $(PY) examples/quickstart.py

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --smoke \
		--n 5000 --dim 64 --index hnsw --requests 128

serve:
	PYTHONPATH=src $(PY) -m repro.launch.serve --serve --port 6333

# shard-count sweep with scaling gates: sharded recall must equal
# single-shard recall (exact merge) and QPS at 4 shards must hold vs 1
bench-serve:
	PYTHONPATH=src $(PY) benchmarks/bench_serve.py \
		--n 128000 --dim 64 --index flat --requests 300 \
		--concurrency 12 --shards 1,2,4 --gate

# single-stage vs coarse-to-fine plan sweep -> BENCH_query.json
bench-query:
	PYTHONPATH=src $(PY) benchmarks/bench_query.py \
		--out BENCH_query.json --timestamp $$(date +%s)

# CI-sized sweep: coarse-to-fine may never lose recall vs legacy rescore
bench-query-smoke:
	PYTHONPATH=src $(PY) benchmarks/bench_query.py \
		--n 2000 --dim 32 --queries 16 --oversamples 2,4 \
		--coarse-efs 32,64 --min-recall 0.5 \
		--out BENCH_query.json --timestamp $$(date +%s)

# dense+sparse hybrid on a keyword-skewed corpus -> BENCH_hybrid.json
bench-hybrid:
	PYTHONPATH=src $(PY) benchmarks/bench_query.py --hybrid \
		--out BENCH_hybrid.json --timestamp $$(date +%s)

# CI-sized hybrid run: RRF fusion may never lose hybrid-oracle recall
# vs the dense leg alone
bench-hybrid-smoke:
	PYTHONPATH=src $(PY) benchmarks/bench_query.py --hybrid \
		--n 2000 --dim 32 --queries 24 --index flat --min-recall 0.6 \
		--out BENCH_hybrid.json --timestamp $$(date +%s)

ci: lint test smoke serve-smoke
