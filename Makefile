# Tier-1 verification + smoke runs.

PY ?= python

.PHONY: test smoke serve-smoke serve bench bench-smoke bench-serve ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# full HNSW width x ef sweep -> BENCH_hnsw.json at the repo root
# (timestamp passed in at the make boundary, not sampled by the writer)
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --only table1 \
		--out BENCH_hnsw.json --timestamp $$(date +%s)

# CI-sized sweep with a recall floor: perf PRs can't trade away quality
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --only table1 --fast \
		--out BENCH_hnsw.json --timestamp $$(date +%s) --min-recall 0.9

smoke:
	PYTHONPATH=src $(PY) examples/quickstart.py

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --smoke \
		--n 5000 --dim 64 --index hnsw --requests 128

serve:
	PYTHONPATH=src $(PY) -m repro.launch.serve --serve --port 6333

bench-serve:
	PYTHONPATH=src $(PY) benchmarks/bench_serve.py

ci: test smoke serve-smoke
