# Tier-1 verification + smoke runs.

PY ?= python

.PHONY: test smoke serve-smoke serve bench-serve ci

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke:
	PYTHONPATH=src $(PY) examples/quickstart.py

serve-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.serve --smoke \
		--n 5000 --dim 64 --index hnsw --requests 128

serve:
	PYTHONPATH=src $(PY) -m repro.launch.serve --serve --port 6333

bench-serve:
	PYTHONPATH=src $(PY) benchmarks/bench_serve.py

ci: test smoke serve-smoke
