"""Query-plan benchmark: single-stage vs coarse-to-fine retrieval sweep.

Builds a quantized (default PQ) collection and compares the legacy
engine-internal rescore path against explicit coarse-to-fine plans
(`.stages(oversample=...)` + `.ef(...)`) over an oversample × coarse-ef
grid, reporting QPS and recall@k as JSON:

    PYTHONPATH=src python benchmarks/bench_query.py --n 20000 --dim 128 \
        --quant pq --oversamples 2,4,8 --coarse-efs 32,64,128 \
        --out BENCH_query.json --timestamp $(date +%s)

`--min-recall` gates the run (CI smoke): the best coarse-to-fine recall
must reach the floor AND the grid point matching the schema's
rescore_multiplier must reach the legacy rescore path's recall — a
quality ratchet so the plan layer can never silently lose what
`rescore=True` delivered.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import Database, VectorField
from repro.core.hnsw_build import exact_knn
from repro.core.pq import PQConfig
from repro.data.synthetic import gaussian_mixture

REPEATS = 3          # best-of timing, first call pays compilation


def _recall(batches, gt) -> float:
    hits = sum(len({h.id for h in row} & {f"v-{j}" for j in t})
               for row, t in zip(batches, gt))
    return hits / (gt.shape[0] * gt.shape[1])


def _timed(fn):
    best, result = float("inf"), None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def run_bench(args) -> Dict:
    db = Database()
    quant_cfg = {}
    if args.quant == "pq":
        m = max(4, args.dim // 8)
        while args.dim % m:
            m -= 1
        quant_cfg["pq"] = PQConfig(m=m, k=64, iters=8)
    col = db.create_collection(
        name="bench",
        vector=VectorField(dim=args.dim, index=args.index,
                           quantization=args.quant, builder="bulk",
                           **quant_cfg))
    corpus = gaussian_mixture(args.n, args.dim, seed=0)
    col.upsert([f"v-{i}" for i in range(args.n)], corpus)
    queries = gaussian_mixture(args.queries, args.dim, seed=7)
    gt = exact_knn(queries, corpus, args.k, metric="cosine")
    col.query(queries[0]).top_k(1).run()        # build outside timing

    def measure(query) -> Dict:
        secs, batches = _timed(lambda: query.run())
        return {"qps": round(args.queries / secs, 1),
                "recall": round(_recall(batches, gt), 4)}

    base = col.query(queries).top_k(args.k)
    out: Dict = {
        "bench": "query_plan",
        "n": args.n, "dim": args.dim, "index": args.index,
        "quant": args.quant, "k": args.k, "queries": args.queries,
        "rescore_multiplier": col.schema.vector.rescore_multiplier,
        "single_stage_raw": measure(base.rescore(False)),
        "single_stage_rescore": measure(base.rescore(True)),
        "grid": [],
    }
    for oversample in args.oversamples:
        for ef in args.coarse_efs:
            cell = measure(base.stages(oversample=oversample).ef(ef))
            cell.update({"oversample": oversample, "coarse_ef": ef})
            out["grid"].append(cell)
    if args.timestamp is not None:
        out["timestamp"] = args.timestamp
    return out


def gate(out: Dict, min_recall: Optional[float]) -> List[str]:
    failures: List[str] = []
    if min_recall is None:
        return failures
    best = max(c["recall"] for c in out["grid"])
    if best < min_recall:
        failures.append(f"best coarse-to-fine recall {best:.3f} "
                        f"< floor {min_recall}")
    matched = [c for c in out["grid"]
               if c["oversample"] == out["rescore_multiplier"]]
    baseline = out["single_stage_rescore"]["recall"]
    if not matched:
        # the ratchet is the point of the gate — a grid that skips the
        # schema's multiplier must fail loudly, not pass vacuously
        failures.append(
            f"gate cannot run: no grid cell at "
            f"oversample={out['rescore_multiplier']} (the schema's "
            f"rescore_multiplier); add it to --oversamples")
    elif max(c["recall"] for c in matched) < baseline:
        failures.append(
            f"coarse-to-fine at oversample={out['rescore_multiplier']} "
            f"({max(c['recall'] for c in matched):.3f}) lost recall vs "
            f"legacy rescore ({baseline:.3f})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat", "ivf"])
    ap.add_argument("--quant", default="pq", choices=["none", "pq", "bq"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--oversamples", default="2,4,8",
                    type=lambda s: [int(x) for x in s.split(",")])
    ap.add_argument("--coarse-efs", default="32,64,128",
                    type=lambda s: [int(x) for x in s.split(",")])
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--timestamp", type=int, default=None,
                    help="run timestamp (passed in at the CLI/make boundary)")
    ap.add_argument("--min-recall", type=float, default=None,
                    help="fail unless best grid recall reaches this AND the "
                         "matched-oversample cell >= legacy rescore recall")
    args = ap.parse_args()

    out = run_bench(args)
    failures = gate(out, args.min_recall)
    out["gate_failures"] = failures
    text = json.dumps(out, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text + "\n")
    for f in failures:
        print(f"[bench-query] FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
