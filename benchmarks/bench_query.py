"""Query-plan benchmark: coarse-to-fine retrieval sweep + hybrid search.

Default mode builds a quantized (default PQ) collection and compares the
legacy engine-internal rescore path against explicit coarse-to-fine plans
(`.stages(oversample=...)` + `.ef(...)`) over an oversample × coarse-ef
grid, reporting QPS and recall@k as JSON:

    PYTHONPATH=src python benchmarks/bench_query.py --n 20000 --dim 128 \
        --quant pq --oversamples 2,4,8 --coarse-efs 32,64,128 \
        --out BENCH_query.json --timestamp $(date +%s)

`--min-recall` gates the run (CI smoke): the best coarse-to-fine recall
must reach the floor AND the grid point matching the schema's
rescore_multiplier must reach the legacy rescore path's recall — a
quality ratchet so the plan layer can never silently lose what
`rescore=True` delivered.

`--hybrid` switches to the dense+sparse benchmark: a keyword-skewed
corpus where each doc carries a tag word *uncorrelated* with its vector
cluster (tag = i % T while clusters follow the mixture), so neither
modality alone can reconstruct the hybrid ground truth.  Queries pair an
anchor doc's (noised) vector with its tag text; the oracle is RRF over
exact dense ranking and brute-force BM25.  Reports sparse-only /
dense-only / RRF-fused QPS + recall@k, and gates on
fused recall >= dense-only recall.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import CollectionSchema, Database, TextField, VectorField
from repro.core.executor import fuse_rrf
from repro.core.hnsw_build import exact_knn
from repro.core.pq import PQConfig
from repro.core.sparse import TokenizerConfig, bm25_reference, rank_scores
from repro.data.synthetic import gaussian_mixture

REPEATS = 3          # best-of timing, first call pays compilation


def _recall(batches, gt) -> float:
    hits = sum(len({h.id for h in row} & {f"v-{j}" for j in t})
               for row, t in zip(batches, gt))
    return hits / (gt.shape[0] * gt.shape[1])


def _timed(fn):
    best, result = float("inf"), None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def run_bench(args) -> Dict:
    db = Database()
    quant_cfg = {}
    if args.quant == "pq":
        m = max(4, args.dim // 8)
        while args.dim % m:
            m -= 1
        quant_cfg["pq"] = PQConfig(m=m, k=64, iters=8)
    col = db.create_collection(
        name="bench",
        vector=VectorField(dim=args.dim, index=args.index,
                           quantization=args.quant, builder="bulk",
                           **quant_cfg))
    corpus = gaussian_mixture(args.n, args.dim, seed=0)
    col.upsert([f"v-{i}" for i in range(args.n)], corpus)
    queries = gaussian_mixture(args.queries, args.dim, seed=7)
    gt = exact_knn(queries, corpus, args.k, metric="cosine")
    col.query(queries[0]).top_k(1).run()        # build outside timing

    def measure(query) -> Dict:
        secs, batches = _timed(lambda: query.run())
        return {"qps": round(args.queries / secs, 1),
                "recall": round(_recall(batches, gt), 4)}

    base = col.query(queries).top_k(args.k)
    out: Dict = {
        "bench": "query_plan",
        "n": args.n, "dim": args.dim, "index": args.index,
        "quant": args.quant, "k": args.k, "queries": args.queries,
        "rescore_multiplier": col.schema.vector.rescore_multiplier,
        "single_stage_raw": measure(base.rescore(False)),
        "single_stage_rescore": measure(base.rescore(True)),
        "grid": [],
    }
    for oversample in args.oversamples:
        for ef in args.coarse_efs:
            cell = measure(base.stages(oversample=oversample).ef(ef))
            cell.update({"oversample": oversample, "coarse_ef": ef})
            out["grid"].append(cell)
    if args.timestamp is not None:
        out["timestamp"] = args.timestamp
    return out


# ------------------------------------------------------------------- hybrid
_NOISE_WORDS = ["alpha", "beta", "gamma", "delta", "omega", "sigma",
                "lambda", "kappa", "theta", "zeta", "epsilon", "iota"]


def _hybrid_corpus(args, rng):
    """Keyword-skewed corpus: tag words cycle i % T while vector clusters
    follow the mixture, so tag-mates scatter across vector space and the
    sparse leg carries signal the dense leg cannot see (and vice versa)."""
    vectors = gaussian_mixture(args.n, args.dim, seed=0)
    texts = []
    for i in range(args.n):
        tag = f"tag{i % args.tags}"
        words = [tag] * int(rng.integers(1, 4))
        words += list(rng.choice(_NOISE_WORDS, size=rng.integers(2, 6)))
        rng.shuffle(words)
        texts.append(" ".join(words))
    return vectors, texts


def run_hybrid(args) -> Dict:
    rng = np.random.default_rng(11)
    vectors, texts = _hybrid_corpus(args, rng)
    db = Database()
    col = db.create_collection(CollectionSchema(
        name="bench_hybrid",
        vector=VectorField(dim=args.dim, index=args.index,
                           quantization="none", builder="bulk"),
        fields=(TextField("body"),)))
    col.upsert([f"v-{i}" for i in range(args.n)], vectors,
               [{"body": t} for t in texts])

    # queries: an anchor doc's noised vector + its tag as keyword text
    anchors = rng.choice(args.n, size=args.queries, replace=False)
    qvecs = (vectors[anchors]
             + 0.15 * rng.standard_normal((args.queries, args.dim))
             ).astype(np.float32)
    qtexts = [f"tag{a % args.tags}" for a in anchors]

    # oracle: RRF of exact dense ranking and brute-force BM25, each leg
    # contributing a top-k list — the same leg size the engine's implicit
    # hybrid plan uses, so the oracle is exactly "both legs done perfectly"
    k = args.k
    dense_gt = exact_knn(qvecs, vectors, k, metric="cosine")
    cfg = TokenizerConfig()
    oracle = []
    for qi in range(args.queries):
        sparse_d, sparse_rows = rank_scores(
            bm25_reference(texts, qtexts[qi], cfg), k)
        dense_rows = dense_gt[qi].astype(np.int64)
        dense_d = np.arange(k, dtype=np.float32)     # RRF only needs order
        fused_d, fused_rows = fuse_rrf(
            [(dense_d[None, :], dense_rows[None, :]),
             (sparse_d[None, :], sparse_rows[None, :])], k)
        oracle.append({f"v-{r}" for r in fused_rows if r >= 0})

    col.query(qvecs[0]).top_k(1).run()          # build outside timing

    def measure(build) -> Dict:
        def once():
            return [build(qi).run() for qi in range(args.queries)]
        secs, batches = _timed(once)
        hits = sum(len({h.id for h in row} & oracle[qi])
                   for qi, row in enumerate(batches))
        return {"qps": round(args.queries / secs, 1),
                "recall_vs_hybrid_oracle":
                    round(hits / (args.queries * k), 4)}

    out: Dict = {
        "bench": "hybrid_search",
        "n": args.n, "dim": args.dim, "index": args.index, "k": k,
        "queries": args.queries, "tags": args.tags,
        "sparse_only": measure(
            lambda qi: col.query().text(qtexts[qi]).top_k(k)),
        "dense_only": measure(
            lambda qi: col.query(qvecs[qi]).top_k(k)),
        "fused_rrf": measure(
            lambda qi: col.query(qvecs[qi]).text(qtexts[qi]).top_k(k)),
    }
    if args.timestamp is not None:
        out["timestamp"] = args.timestamp
    return out


def gate_hybrid(out: Dict, min_recall: Optional[float]) -> List[str]:
    """CI ratchet: fusing a sparse leg in must never lose hybrid-oracle
    recall vs the dense leg alone (plus an optional absolute floor)."""
    failures: List[str] = []
    fused = out["fused_rrf"]["recall_vs_hybrid_oracle"]
    dense = out["dense_only"]["recall_vs_hybrid_oracle"]
    if fused < dense:
        failures.append(f"fused recall {fused:.3f} < dense-only {dense:.3f}")
    if min_recall is not None and fused < min_recall:
        failures.append(f"fused recall {fused:.3f} < floor {min_recall}")
    return failures


def gate(out: Dict, min_recall: Optional[float]) -> List[str]:
    failures: List[str] = []
    if min_recall is None:
        return failures
    best = max(c["recall"] for c in out["grid"])
    if best < min_recall:
        failures.append(f"best coarse-to-fine recall {best:.3f} "
                        f"< floor {min_recall}")
    matched = [c for c in out["grid"]
               if c["oversample"] == out["rescore_multiplier"]]
    baseline = out["single_stage_rescore"]["recall"]
    if not matched:
        # the ratchet is the point of the gate — a grid that skips the
        # schema's multiplier must fail loudly, not pass vacuously
        failures.append(
            f"gate cannot run: no grid cell at "
            f"oversample={out['rescore_multiplier']} (the schema's "
            f"rescore_multiplier); add it to --oversamples")
    elif max(c["recall"] for c in matched) < baseline:
        failures.append(
            f"coarse-to-fine at oversample={out['rescore_multiplier']} "
            f"({max(c['recall'] for c in matched):.3f}) lost recall vs "
            f"legacy rescore ({baseline:.3f})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat", "ivf"])
    ap.add_argument("--quant", default="pq", choices=["none", "pq", "bq"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--oversamples", default="2,4,8",
                    type=lambda s: [int(x) for x in s.split(",")])
    ap.add_argument("--coarse-efs", default="32,64,128",
                    type=lambda s: [int(x) for x in s.split(",")])
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--timestamp", type=int, default=None,
                    help="run timestamp (passed in at the CLI/make boundary)")
    ap.add_argument("--min-recall", type=float, default=None,
                    help="fail unless best grid recall reaches this AND the "
                         "matched-oversample cell >= legacy rescore recall "
                         "(in --hybrid mode: absolute fused-recall floor)")
    ap.add_argument("--hybrid", action="store_true",
                    help="run the dense+sparse hybrid benchmark instead")
    ap.add_argument("--tags", type=int, default=32,
                    help="hybrid mode: distinct keyword tags in the corpus")
    args = ap.parse_args()

    if args.hybrid:
        out = run_hybrid(args)
        failures = gate_hybrid(out, args.min_recall)
    else:
        out = run_bench(args)
        failures = gate(out, args.min_recall)
    out["gate_failures"] = failures
    text = json.dumps(out, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text + "\n")
    for f in failures:
        print(f"[bench-query] FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
