"""HLO-text cost analysis with loop trip-count accounting.

Why this exists: XLA-CPU's ``compiled.cost_analysis()`` counts a ``while``
body's cost ONCE, but scanned-layer models execute it n_layers times — flops,
bytes and collective traffic would all be undercounted by ~n_layers×
(calibrated in tests/test_hlo_cost.py).  This parser walks the post-SPMD HLO
call graph, multiplies loop bodies by their trip counts, and produces:

  flops            — 2·M·N·K for dots, |shape| for elementwise/reduce
  bytes_naive      — every op's operands+results (unfused upper bound)
  bytes_fused      — materialisation estimate: dots, gathers/scatters,
                     reduces, copies, slices/DUS, converts at function
                     boundaries, collectives (what a fused TPU program
                     actually moves through HBM)
  collective bytes — by kind (all-reduce / all-gather / reduce-scatter /
                     all-to-all / collective-permute), trip-multiplied

All values are per-device (post-SPMD shapes).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_RE_OP = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)(.*)$")
_RE_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_RE_CALLS = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations|"
    r"true_computation|false_computation)=\{?%?([\w.\-]+)")
_RE_CONST = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_RE_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil", "cosine",
    "sine", "logistic", "select", "compare", "and", "or", "xor", "not",
    "clamp", "round-nearest-afz", "round-nearest-even", "remainder", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "popcnt", "clz", "cbrt", "erf",
}
_MATERIALIZING = {
    "dot", "gather", "scatter", "reduce", "reduce-window", "copy",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate", "pad",
    "sort", "iota", "broadcast", "transpose", "reverse", "convolution",
    "cholesky", "triangular-solve", "rng", "rng-bit-generator", "custom-call",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
         "get-dimension-size", "reshape", "convert", "copy-start",
         "copy-done", "send", "recv", "send-done", "recv-done"}


def _shape_list_bytes_elems(type_str: str) -> Tuple[int, int]:
    """All shapes in a type string -> (total bytes, total elements)."""
    total_b = total_e = 0
    for dtype, dims in _RE_SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dtype]
    return total_b, total_e


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes_naive: float = 0.0
    bytes_fused: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    # (callee, kind) pairs; kind "while" multiplies by trip count
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_int_const: int = 1

    def add(self, other: "CompCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_naive += other.bytes_naive * mult
        self.bytes_fused += other.bytes_fused * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_count[k] += other.coll_count[k] * mult


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_naive: float
    bytes_fused: float
    coll_bytes: Dict[str, float]
    coll_count: Dict[str, float]
    loops: List[Tuple[str, int]]

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())

    def coll_summary(self) -> str:
        parts = [
            f"{k}:{int(self.coll_count[k])}x{self.coll_bytes[k]/1e6:.1f}MB"
            for k in sorted(self.coll_bytes) if self.coll_count[k]]
        return " ".join(parts) or "none"


def _parse_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _RE_COMP_HEAD.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


_RE_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _first_shape_dims(type_str: str) -> List[int]:
    m = _RE_SHAPE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _cost_of_computation(lines: List[str]) -> CompCost:
    c = CompCost()
    # pass 1: symbol table (scheduled HLO references operands by bare name)
    sym: Dict[str, Tuple[int, int, List[int]]] = {}
    parsed = []
    for line in lines:
        mc = _RE_CONST.search(line)
        if mc:
            c.max_int_const = max(c.max_int_const, int(mc.group(1)))
        m = _RE_OP.match(line)
        if not m:
            continue
        name, result_type, op, operands, tail = m.groups()
        b, e = _shape_list_bytes_elems(result_type)
        sym[name] = (b, e, _first_shape_dims(result_type))
        parsed.append((name, result_type, op, operands, tail))

    def operand_names(operands: str) -> List[str]:
        return [n for n in _RE_OPERAND_NAME.findall(operands) if n in sym]

    # pass 2: costs
    for name, result_type, op, operands, tail in parsed:
        full_tail = operands + " " + tail
        if op == "while":
            mb = re.search(r"body=\{?%?([\w.\-]+)", full_tail)
            mcond = re.search(r"condition=\{?%?([\w.\-]+)", full_tail)
            if mb and mcond:
                c.calls.append((f"{mcond.group(1)}|{mb.group(1)}", "while"))
        else:
            for callee in _RE_CALLS.findall(full_tail):
                c.calls.append((callee, "call"))
        if op in _SKIP:
            continue
        res_b, res_e = _shape_list_bytes_elems(result_type)
        ops = operand_names(operands)
        opnd_b = sum(sym[n][0] for n in ops)
        opnd_e = sum(sym[n][1] for n in ops)
        c.bytes_naive += res_b + opnd_b

        is_coll = None
        for k in _COLLECTIVES:
            if op == k or op == k + "-start":
                is_coll = k
                break
        if is_coll:
            nbytes = opnd_b if is_coll == "reduce-scatter" else res_b
            c.coll[is_coll] += nbytes
            c.coll_count[is_coll] += 1
            c.bytes_fused += nbytes
            continue
        if op == "dot":
            k_contract = 1
            mct = _RE_CONTRACT.search(full_tail)
            if mct and ops:
                lhs_dims = sym[ops[0]][2]
                if mct.group(1):
                    for idx in mct.group(1).split(","):
                        i = int(idx)
                        if i < len(lhs_dims):
                            k_contract *= lhs_dims[i]
            c.flops += 2.0 * res_e * k_contract
            c.bytes_fused += res_b + opnd_b
        elif op == "convolution":
            c.flops += 2.0 * res_e * max(opnd_e // max(res_e, 1), 1)
            c.bytes_fused += res_b + opnd_b
        elif op in _ELEMENTWISE:
            c.flops += res_e
        elif op in ("reduce", "reduce-window"):
            c.flops += opnd_e
            c.bytes_fused += res_b + opnd_b
        elif op in ("gather", "dynamic-slice", "slice", "broadcast", "iota",
                    "pad", "reverse"):
            # these READ only what they produce (dynamic-slice of a 2 GB
            # scan input reads one slice, not 2 GB) — charging full operands
            # inflated loop-heavy cells ~200x (see EXPERIMENTS.md §Dry-run)
            c.bytes_fused += res_b
        elif op == "dynamic-update-slice":
            # read-modify-write of the update region only (result aliases)
            upd = sym[ops[1]][0] if len(ops) > 1 else res_b
            c.bytes_fused += 2 * upd
        elif op == "scatter":
            upd = sym[ops[-1]][0] if ops else res_b
            c.bytes_fused += 2 * upd
        elif op in _MATERIALIZING:
            c.bytes_fused += res_b + opnd_b
        elif op in ("while", "call", "fusion", "conditional"):
            pass  # handled via call graph
        else:
            # unknown op: count result bytes conservatively
            c.bytes_fused += res_b
    return c


def analyze(hlo_text: str, entry: Optional[str] = None) -> HloCost:
    comps = _parse_computations(hlo_text)
    raw = {name: _cost_of_computation(lines)
           for name, lines in comps.items()}

    # entry = computation that nothing calls (or named ENTRY in text)
    called = {callee for c in raw.values() for callee, _ in c.calls}
    entries = [n for n in raw if n not in called]
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        entry = m.group(1) if m and m.group(1) in raw else (
            entries[0] if entries else next(iter(raw)))

    memo: Dict[str, CompCost] = {}
    loops: List[Tuple[str, int]] = []
    visiting = set()

    def total(name: str) -> CompCost:
        if name in memo:
            return memo[name]
        if name in visiting or name not in raw:
            return CompCost()
        visiting.add(name)
        own = raw[name]
        agg = CompCost()
        agg.add(own)
        for callee, kind in own.calls:
            if kind == "while":
                cond_name, body_name = callee.split("|", 1)
                # trip count: the loop bound is a scalar constant in the
                # condition computation (jax scans lower to `lt(i, N)`).
                trip = max(raw.get(cond_name, CompCost()).max_int_const, 1)
                agg.add(total(body_name), mult=trip)
                agg.add(total(cond_name), mult=trip)
                loops.append((body_name, trip))
            else:
                agg.add(total(callee))
        visiting.discard(name)
        memo[name] = agg
        return agg

    t = total(entry)
    return HloCost(flops=t.flops, bytes_naive=t.bytes_naive,
                   bytes_fused=t.bytes_fused, coll_bytes=t.coll,
                   coll_count=t.coll_count, loops=loops)


def xla_cost_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jaxlib versions: older
    releases return a per-device list of dicts, newer ones a single dict
    (and either may return None when the backend has no analysis)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}
