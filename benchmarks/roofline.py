"""Roofline model + HLO collective-bytes parser (EXPERIMENTS.md §Roofline).

Hardware constants (assignment): TPU v5e-class chip —
  197 TFLOP/s bf16 peak, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (per device; SPMD means per-device == global/chips):
  compute    = flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = collective_bytes / LINK_BW

``flops`` / ``hbm_bytes`` come from ``compiled.cost_analysis()`` (per-device
post-SPMD program).  ``collective_bytes`` is parsed from the post-SPMD HLO
text: per op we count the bytes a device moves —
  all-reduce / all-to-all / collective-permute: result bytes
  all-gather: result bytes (each device receives the gathered result)
  reduce-scatter: operand bytes (each device sends its full operand)
Async pairs (``-start``/``-done``) are counted once, at the start op.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
DCN_BW = 25e9              # bytes/s per host for cross-pod (pod axis) traffic

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")

# result shape at line head:  %name = f32[1,2,3]{...} op-name(...)
_RE_LINE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+([a-z0-9-]+)\(")
# operand shapes inside parens: f32[8,128]
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}:{self.count_by_kind[k]}x{self.bytes_by_kind[k]/1e6:.1f}MB"
                 for k in sorted(self.bytes_by_kind) if self.count_by_kind[k]]
        return " ".join(parts) or "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_kind = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by_kind = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _RE_LINE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        kind = None
        for k in _COLLECTIVE_KINDS:
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        if kind == "reduce-scatter":
            # operand bytes: first shape inside the call parens
            paren = line[m.end():]
            shapes = _RE_SHAPE.findall(paren)
            nbytes = (_shape_bytes(*shapes[0]) if shapes
                      else _shape_bytes(dtype, dims))
        else:
            # result bytes; tuple results (start ops) -> parse all shapes in
            # the tuple before the op name
            head = line[: m.start() + 1]
            nbytes = _shape_bytes(dtype, dims)
            if "(" in line[: line.find(op)] and line.strip().find("= (") > 0:
                tup = _RE_SHAPE.findall(line[: line.find(op)])
                if tup:
                    nbytes = max(_shape_bytes(*s) for s in tup)
        bytes_by_kind[kind] += nbytes
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device
    collective_bytes: float      # per device
    model_flops: float = 0.0     # analytic useful FLOPs (global)
    chips: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPS (global) — remat/dispatch waste detector."""
        if self.model_flops <= 0 or self.flops <= 0:
            return None
        return self.model_flops / (self.flops * self.chips)

    @property
    def mfu(self) -> Optional[float]:
        """Model FLOPs utilisation at the roofline step time."""
        if self.model_flops <= 0:
            return None
        return self.model_flops / (self.step_time * self.chips * PEAK_FLOPS)

    def row(self) -> Dict[str, object]:
        return {
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "roofline_step_s": round(self.step_time, 6),
            "useful_flops_ratio": (round(self.useful_flops_ratio, 4)
                                   if self.useful_flops_ratio else None),
            "roofline_mfu": round(self.mfu, 4) if self.mfu else None,
        }


def train_model_flops(n_active_params: float, tokens: float) -> float:
    """6·N·D (the assignment's MODEL_FLOPS definition)."""
    return 6.0 * n_active_params * tokens


def decode_model_flops(n_active_params: float, batch: float) -> float:
    """One token per sequence: 2·N per token forward (no backward)."""
    return 2.0 * n_active_params * batch
