"""Quantization benchmark (paper §II-B-2 claims): compression ratio, recall
impact, rescore recovery, and scan-cost comparison for PQ and BQ."""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import (BinaryQuantizer, BQConfig, EngineConfig, PQConfig,
                        ProductQuantizer, QuantixarEngine, exact_knn)
from repro.data.synthetic import sift_like

K = 10


def _recall(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / gt.shape[1]
                    for a, b in zip(np.asarray(ids), gt)])


def main(n: int = 20_000, n_q: int = 128) -> List[Dict]:
    corpus = sift_like(n, seed=0)
    queries = sift_like(n_q, seed=1)
    gt = exact_knn(queries, corpus, K, metric="cosine")
    rows = []

    # float scan baseline
    from repro.core.flat import flat_search
    xq, xc = jnp.asarray(queries), jnp.asarray(corpus)
    flat_search(xq[:4], xc, K, metric="cosine")[1].block_until_ready()
    t0 = time.perf_counter()
    _, ids = flat_search(xq, xc, K, metric="cosine")
    ids.block_until_ready()
    t_flat = time.perf_counter() - t0
    rows.append({"method": "flat-f32", "compression": 1.0,
                 "recall": round(_recall(ids, gt), 4),
                 "scan_s": round(t_flat, 4), "bytes_per_vec": 512})

    for m, kk in ((8, 256), (16, 256), (32, 256)):
        pq = ProductQuantizer(PQConfig(m=m, k=kk, iters=12, metric="cosine"))
        pq.train(xc)
        codes = pq.encode(xc)
        pq.search(codes, xq[:4], K)[1].block_until_ready()
        t0 = time.perf_counter()
        _, ids = pq.search(codes, xq, K)
        ids.block_until_ready()
        rows.append({"method": f"pq-m{m}", "compression": 512 / m,
                     "recall": round(_recall(ids, gt), 4),
                     "scan_s": round(time.perf_counter() - t0, 4),
                     "bytes_per_vec": m})

    for bits in (128, 256, 512):
        bq = BinaryQuantizer(BQConfig(bits=bits))
        bq.train(xc)
        codes = bq.encode(xc)
        bq.search(codes, xq[:4], K)[1].block_until_ready()
        t0 = time.perf_counter()
        _, ids = bq.search(codes, xq, K)
        ids.block_until_ready()
        rows.append({"method": f"bq-{bits}b", "compression": 512 / (bits / 8),
                     "recall": round(_recall(ids, gt), 4),
                     "scan_s": round(time.perf_counter() - t0, 4),
                     "bytes_per_vec": bits // 8})

    # rescore recovery (engine path)
    for quant in ("pq", "bq"):
        eng = QuantixarEngine(EngineConfig(
            dim=128, index="flat", quantization=quant, rescore=True,
            pq=PQConfig(m=16, k=256, iters=12), bq=BQConfig(bits=256)))
        eng.add(corpus)
        eng.build()
        _, ids = eng.search(queries, K)
        rows.append({"method": f"{quant}+rescore", "compression": "-",
                     "recall": round(_recall(ids, gt), 4),
                     "scan_s": "-", "bytes_per_vec": "-"})

    print(f"# quantization benchmark (n={n}, sift-like-128)")
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
