"""Continuous-ingest benchmark: add→searchable latency vs sealed corpus size.

Before the segment layer, every `add()` after `build()` marked the engine
dirty and the *next query* retrained quantizers and rebuilt the whole HNSW
graph — O(N) work billed to one search, growing with the corpus.  With the
segmented write path the batch lands in the delta segment (encode-only) and
is served by an exact flat scan merged with the sealed index, so the
add→searchable latency should be roughly independent of sealed-corpus size.

Reported per sealed size N:
  * add_ms        — wall time of `add(batch)` (encode + delta append)
  * first_search_ms / steady_search_ms — next-query latency (the old design
    paid the full rebuild here; now it is a delta scan + merge)
  * seal_ms       — explicit `seal()` fold (graph rebuild, no retraining),
    the amortized cost the old design hid inside a query
  * recall@10     — sealed+delta fan-out vs a full rebuild over the same
    rows (should match within noise), both against exact ground truth
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.core import EngineConfig, QuantixarEngine, SealPolicy, exact_knn
from repro.core.hnsw_build import HNSWConfig
from repro.core.pq import PQConfig
from repro.data.synthetic import gaussian_mixture

K = 10


def _recall(ids: np.ndarray, gt: np.ndarray) -> float:
    return float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / gt.shape[1]
        for a, b in zip(ids, gt)]))


def _make_engine(dim: int, quant: str) -> QuantixarEngine:
    return QuantixarEngine(EngineConfig(
        dim=dim, index="hnsw", quantization=quant, builder="bulk",
        hnsw=HNSWConfig(M=16, ef_construction=80),
        pq=PQConfig(m=8, k=64, iters=10),
        # explicit seal() only: the bench measures the delta path itself
        seal=SealPolicy(auto=False)))


def run_size(n: int, dim: int, batch: int, n_queries: int,
             quant: str, seed: int) -> Dict:
    rng_seed = seed + n          # distinct corpora per size
    corpus = gaussian_mixture(n, dim, n_clusters=32, scale=0.25,
                              seed=rng_seed)
    fresh = gaussian_mixture(batch, dim, n_clusters=32, scale=0.25,
                             seed=rng_seed + 1)
    queries = gaussian_mixture(n_queries, dim, n_clusters=32, scale=0.25,
                               seed=rng_seed + 2)

    eng = _make_engine(dim, quant)
    eng.add(corpus)
    eng.build()
    eng.search(queries, K)       # warm the sealed-path compilation

    t0 = time.perf_counter()
    eng.add(fresh)
    add_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, ids_first = eng.search(queries, K)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, ids = eng.search(queries, K)
    steady_s = time.perf_counter() - t0
    assert eng.index_builds == 1 and eng.quantizer_trains <= 1, \
        "delta path rebuilt the sealed segment!"

    full = np.concatenate([corpus, fresh])
    gt = exact_knn(queries, full, K, metric="cosine")
    rec_delta = _recall(ids, gt)

    t0 = time.perf_counter()
    eng.seal()
    seal_s = time.perf_counter() - t0

    # reference: full rebuild over the same rows (the old write path)
    ref = _make_engine(dim, quant)
    ref.add(full)
    t0 = time.perf_counter()
    ref.build()
    rebuild_s = time.perf_counter() - t0
    _, ids_ref = ref.search(queries, K)
    rec_rebuild = _recall(ids_ref, gt)

    return {
        "n_sealed": n, "batch": batch, "quant": quant,
        "add_ms": round(add_s * 1e3, 2),
        "first_search_ms": round(first_s * 1e3, 2),
        "steady_search_ms": round(steady_s * 1e3, 2),
        "seal_ms": round(seal_s * 1e3, 1),
        "full_rebuild_ms": round(rebuild_s * 1e3, 1),
        "recall_delta": round(rec_delta, 4),
        "recall_rebuild": round(rec_rebuild, 4),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[2000, 8000, 32000])
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--quant", choices=["none", "pq"], default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    print(f"# ingest bench: add {args.batch} rows into a sealed corpus, "
          f"then query (dim={args.dim}, quant={args.quant})")
    rows = []
    for n in args.sizes:
        r = run_size(n, args.dim, args.batch, args.queries,
                     args.quant, args.seed)
        rows.append(r)
        print(",".join(f"{k}={v}" for k, v in r.items()))
    if len(rows) >= 2:
        lo, hi = rows[0], rows[-1]
        growth = ((hi["add_ms"] + hi["steady_search_ms"])
                  / max(lo["add_ms"] + lo["steady_search_ms"], 1e-9))
        rebuild_growth = hi["full_rebuild_ms"] / max(lo["full_rebuild_ms"],
                                                     1e-9)
        print(f"# add→searchable grew {growth:.2f}x over a "
              f"{hi['n_sealed'] // lo['n_sealed']}x corpus "
              f"(full rebuild grew {rebuild_growth:.2f}x)")
    return rows


if __name__ == "__main__":
    main()
