"""Per-computation contribution profile from a saved dry-run HLO.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hlo_profile \
      experiments/dryrun/pod16x16/qwen3-4b__decode_32k.hlo.gz [--top 12]

Prints each computation's trip-multiplied contribution to flops / fused bytes
/ collective bytes — the "profile" the §Perf hypothesis loop reads (no
wall-clock on CPU; the lowered IR is the profiler).
"""

from __future__ import annotations

import argparse
import gzip
from typing import Dict

from . import hlo_cost as HC


def profile(text: str):
    comps = HC._parse_computations(text)
    raw = {n: HC._cost_of_computation(l) for n, l in comps.items()}
    import re
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1)

    # accumulate own-cost × multiplier per computation, walking the graph
    contrib: Dict[str, Dict[str, float]] = {}
    seen_mult: Dict[str, float] = {}

    def walk(name: str, mult: float):
        own = raw.get(name)
        if own is None:
            return
        c = contrib.setdefault(name, {"mult": 0.0, "flops": 0.0,
                                      "bytes": 0.0, "coll": 0.0})
        c["mult"] = max(c["mult"], mult)
        c["flops"] += own.flops * mult
        c["bytes"] += own.bytes_fused * mult
        c["coll"] += sum(own.coll.values()) * mult
        for callee, kind in own.calls:
            if kind == "while":
                cond, body = callee.split("|", 1)
                trip = max(raw.get(cond, HC.CompCost()).max_int_const, 1)
                walk(body, mult * trip)
                walk(cond, mult * trip)
            else:
                walk(callee, mult)

    walk(entry, 1.0)
    return contrib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--by", default="bytes",
                    choices=["bytes", "flops", "coll"])
    args = ap.parse_args()
    opener = gzip.open if args.path.endswith(".gz") else open
    with opener(args.path, "rt") as f:
        text = f.read()
    contrib = profile(text)
    rows = sorted(contrib.items(), key=lambda kv: -kv[1][args.by])
    tot = {k: sum(c[k] for c in contrib.values())
           for k in ("flops", "bytes", "coll")}
    print(f"{'computation':58s} {'mult':>6s} {'GF':>10s} {'GB':>10s} "
          f"{'collGB':>9s}")
    for name, c in rows[: args.top]:
        print(f"{name[:58]:58s} {c['mult']:6.0f} {c['flops'] / 1e9:10.1f} "
              f"{c['bytes'] / 1e9:10.2f} {c['coll'] / 1e9:9.2f}")
    print(f"{'TOTAL':58s} {'':6s} {tot['flops'] / 1e9:10.1f} "
          f"{tot['bytes'] / 1e9:10.2f} {tot['coll'] / 1e9:9.2f}")


if __name__ == "__main__":
    main()
