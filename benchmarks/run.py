"""Benchmark harness entry point — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract, plus
section headers.  Scales are CPU-budget-reduced (factors printed inline).

  table1   — HNSW width × ef sweep on Fashion-MNIST-like / SIFT-like
             (paper Table I + wide-beam traversal counters); `--out`
             persists the sweep as JSON (``make bench`` writes
             ``BENCH_hnsw.json`` at the repo root), `--min-recall` turns
             the run into a CI gate
  quant    — PQ/BQ compression vs recall vs scan cost (paper §II-B-2)
  kernels  — distance-kernel microbench + TPU roofline (paper §II-B-3)

The report timestamp is *passed in* (``--timestamp``, or computed once here
at the CLI boundary) — the writer itself never samples ambient time, so
re-runs over the same inputs are reproducible.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "table1", "quant", "kernels"])
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI budget)")
    ap.add_argument("--builder", default=None,
                    choices=["incremental", "bulk", "bulk_ref", "both"],
                    help="HNSW builder for table1; 'both' sweeps "
                         "incremental and bulk side by side (default: "
                         "incremental, bulk under --fast)")
    ap.add_argument("--out", default=None,
                    help="write the table1 sweep as JSON to this path "
                         "(e.g. BENCH_hnsw.json at the repo root)")
    ap.add_argument("--timestamp", type=float, default=None,
                    help="report timestamp (unix seconds); defaults to one "
                         "sample taken here at the CLI boundary")
    ap.add_argument("--min-recall", type=float, default=None,
                    help="fail (exit 1) if any widest-beam table1 row "
                         "falls below this recall@10 floor")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="with --builder both: fail (exit 1) unless the "
                         "bulk build is at least this many times faster "
                         "than incremental at recall within 0.02")
    args = ap.parse_args()

    timestamp = args.timestamp if args.timestamp is not None else time.time()
    failures = []
    t0 = time.perf_counter()
    if args.only in ("all", "table1"):
        from . import bench_hnsw
        if args.fast:
            scale = dict(n_fmnist=1500, n_sift=2000, n_queries=100,
                         builder=args.builder or "bulk")
        else:
            scale = dict(builder=args.builder or "incremental")
        rows = bench_hnsw.main(**scale)
        if args.out:
            bench_hnsw.write_report(rows, args.out, timestamp,
                                    meta={"fast": args.fast, **scale})
            print(f"# wrote {args.out}")
        if args.min_recall is not None:
            failures += bench_hnsw.check_recall_floor(rows, args.min_recall)
        if args.min_speedup is not None:
            failures += bench_hnsw.check_builder_floor(rows, args.min_speedup)
    if args.only in ("all", "quant"):
        from . import bench_quant
        bench_quant.main(n=8_000 if args.fast else 20_000)
    if args.only in ("all", "kernels"):
        from . import bench_kernels
        bench_kernels.main()
    print(f"# benchmarks done in {time.perf_counter() - t0:.1f}s")
    for f in failures:
        print(f"# FAIL: {f}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
