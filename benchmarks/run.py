"""Benchmark harness entry point — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract, plus
section headers.  Scales are CPU-budget-reduced (factors printed inline).

  table1   — HNSW on Fashion-MNIST-like / SIFT-like (paper Table I)
  quant    — PQ/BQ compression vs recall vs scan cost (paper §II-B-2)
  kernels  — distance-kernel microbench + TPU roofline (paper §II-B-3)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "table1", "quant", "kernels"])
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI budget)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    if args.only in ("all", "table1"):
        from . import bench_hnsw
        scale = dict(n_fmnist=2000, n_sift=3000, n_queries=100) \
            if args.fast else {}
        bench_hnsw.main(**scale)
    if args.only in ("all", "quant"):
        from . import bench_quant
        bench_quant.main(n=8_000 if args.fast else 20_000)
    if args.only in ("all", "kernels"):
        from . import bench_kernels
        bench_kernels.main()
    print(f"# benchmarks done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
