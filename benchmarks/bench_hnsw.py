"""Paper Table I reproduction: HNSW on Fashion-MNIST-like and SIFT-like data.

Reports the paper's metrics: construction time (graph build machinery),
insertion time, search time at ef ∈ {64, 128}, recall rate, last-distances
ratio, mean fraction of neighbours returned, and QPS — now swept over the
wide-beam ``expansion_width`` as well, with the device loop's per-query
iteration counter reported (`mean_iters`/`max_iters`): the sequential
while-loop trip count is the hot-path bottleneck the wide beam attacks, and
vmapped batches step until the *slowest* query finishes.

Offline-container deltas (DESIGN.md §8): datasets are statistically matched
synthetics; corpus sizes are scaled to the CPU budget (the paper ran 60k/1M
on a t4g.xlarge for hours) with the scale factor printed; wall-clock numbers
are host-CPU and NOT comparable to the paper's instance — recall/ratio/
iteration metrics are the comparable part.

`benchmarks/run.py --only table1 --out BENCH_hnsw.json` (the `make bench`
entry) persists the sweep as JSON at the repo root so the perf trajectory is
tracked across PRs; the timestamp is passed in by the caller, never sampled
ambiently here.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import HNSWConfig, bulk_build, exact_knn, recall_at_k
from repro.core.hnsw_build import build as incremental_build, \
    preprocess_vectors
from repro.core.hnsw_search import search, to_device
from repro.data.synthetic import fashion_mnist_like, sift_like

K = 10
DEFAULT_WIDTHS = (1, 2, 4)


def run_dataset(name: str, corpus: np.ndarray, queries: np.ndarray,
                metric: str = "l2", builder: str = "incremental",
                ef_values: Sequence[int] = (64, 128),
                widths: Sequence[int] = DEFAULT_WIDTHS,
                repeats: int = 3) -> List[Dict]:
    cfg = HNSWConfig(M=16, ef_construction=100, metric=metric)
    t0 = time.perf_counter()
    build_fn = incremental_build if builder == "incremental" else bulk_build
    packed = build_fn(corpus, cfg)
    t_build = time.perf_counter() - t0

    g, max_level, dev_metric = to_device(packed)
    gt = exact_knn(queries, corpus, K, metric=metric)
    gt_d = np.sort(
        ((preprocess_vectors(queries, metric)[:, None, :]
          - preprocess_vectors(corpus, metric)[gt]) ** 2).sum(-1), axis=1)

    rows = []
    qn = preprocess_vectors(queries, metric)
    corpus_n = preprocess_vectors(corpus, metric)
    q_dev = jnp.asarray(qn)
    for ef in ef_values:
        for width in widths:
            # warm at the timed shape so QPS measures the search, not XLA;
            # best-of-`repeats` timing (timeit-style) rejects machine-load
            # noise that would otherwise swamp the width comparison
            search(g, q_dev, k=K, ef=ef, max_level=max_level,
                   metric=dev_metric, expansion_width=width,
                   with_iters=True)[1].block_until_ready()
            t_search = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                d, ids, iters = search(g, q_dev, k=K, ef=ef,
                                       max_level=max_level,
                                       metric=dev_metric,
                                       expansion_width=width,
                                       with_iters=True)
                ids.block_until_ready()
                t_search = min(t_search, time.perf_counter() - t0)
            ids_np = np.asarray(ids)
            iters_np = np.asarray(iters)
            rec = recall_at_k(ids_np, gt)
            filled = (ids_np >= 0).mean()
            # last-distances ratio (ann-benchmarks): found kth / true kth
            found_vecs = corpus_n[np.maximum(ids_np[:, -1], 0)]
            found_last = ((qn - found_vecs) ** 2).sum(-1)
            ldr = float(np.mean(np.sqrt(np.maximum(found_last, 1e-12))
                                / np.sqrt(np.maximum(gt_d[:, -1], 1e-12))))
            rows.append({
                "dataset": name, "builder": builder, "ef": ef,
                "width": width,
                "n": len(corpus), "construction_s": round(t_build, 3),
                "search_s": round(t_search, 4),
                "qps": round(len(queries) / t_search, 1),
                "recall": round(rec, 4),
                "mean_iters": round(float(iters_np.mean()), 1),
                "max_iters": int(iters_np.max()),
                "fraction_returned": round(float(filled), 4),
                "last_dist_ratio": round(ldr, 4),
            })
    return rows


def write_report(rows: List[Dict], out_path: str, timestamp: float,
                 meta: Optional[Dict] = None) -> None:
    """Persist the sweep as JSON.  `timestamp` is supplied by the caller
    (CLI flag / CI env), keeping the report a pure function of its inputs."""
    report = {
        "bench": "hnsw",
        "timestamp": timestamp,
        "k": K,
        "rows": rows,
    }
    if meta:
        report["meta"] = meta
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def check_recall_floor(rows: List[Dict], min_recall: float) -> List[str]:
    """Recall floor over the *widest* beam at the *largest* ef per dataset —
    the serving default at its quality setting — so perf PRs cannot silently
    trade recall for QPS.  (Small-ef rows are latency points; their recall
    is a property of ef, not of the traversal.)"""
    failures = []
    widest = max(r["width"] for r in rows)
    top_ef = max(r["ef"] for r in rows)
    for r in rows:
        if (r["width"] == widest and r["ef"] == top_ef
                and r["recall"] < min_recall):
            failures.append(
                f"{r['dataset']} ef={r['ef']} width={r['width']}: "
                f"recall {r['recall']:.4f} < floor {min_recall}")
    return failures


def main(n_fmnist: int = 6000, n_sift: int = 8000, n_queries: int = 200,
         builder: str = "incremental",
         widths: Sequence[int] = DEFAULT_WIDTHS,
         ef_values: Sequence[int] = (64, 128)) -> List[Dict]:
    print(f"# Table I reproduction (scaled: fmnist {n_fmnist}/60k, "
          f"sift {n_sift}/1M; builder={builder}; widths={tuple(widths)})")
    rows = []
    rows += run_dataset("fashion-mnist-784",
                        fashion_mnist_like(n_fmnist, seed=0),
                        fashion_mnist_like(n_queries, seed=1),
                        builder=builder, widths=widths, ef_values=ef_values)
    rows += run_dataset("sift-128", sift_like(n_sift, seed=0),
                        sift_like(n_queries, seed=1), builder=builder,
                        widths=widths, ef_values=ef_values)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
