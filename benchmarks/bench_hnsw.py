"""Paper Table I reproduction: HNSW on Fashion-MNIST-like and SIFT-like data.

Reports the paper's metrics: construction time (graph build machinery),
insertion time, search time at ef ∈ {64, 128}, recall rate, last-distances
ratio, mean fraction of neighbours returned, and QPS — now swept over the
wide-beam ``expansion_width`` as well, with the device loop's per-query
iteration counter reported (`mean_iters`/`max_iters`): the sequential
while-loop trip count is the hot-path bottleneck the wide beam attacks, and
vmapped batches step until the *slowest* query finishes.

Offline-container deltas (DESIGN.md §8): datasets are statistically matched
synthetics; corpus sizes are scaled to the CPU budget (the paper ran 60k/1M
on a t4g.xlarge for hours) with the scale factor printed; wall-clock numbers
are host-CPU and NOT comparable to the paper's instance — recall/ratio/
iteration metrics are the comparable part.

`benchmarks/run.py --only table1 --out BENCH_hnsw.json` (the `make bench`
entry) persists the sweep as JSON at the repo root so the perf trajectory is
tracked across PRs; the timestamp is passed in by the caller, never sampled
ambiently here.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import (HNSWConfig, bulk_build, bulk_build_device, exact_knn,
                        recall_at_k)
from repro.core.hnsw_build import build as incremental_build, \
    preprocess_vectors
from repro.core.hnsw_search import search, to_device
from repro.data.synthetic import fashion_mnist_like, sift_like

K = 10
DEFAULT_WIDTHS = (1, 2, 4)

BUILD_FNS = {"incremental": incremental_build, "bulk": bulk_build_device,
             "bulk_ref": bulk_build}


def expand_builders(builder: str) -> Sequence[str]:
    """CLI spelling -> builder list ("both" = incremental + bulk rows
    side by side, the construction-throughput comparison)."""
    if builder == "both":
        return ("incremental", "bulk")
    if builder not in BUILD_FNS:
        raise ValueError(f"builder {builder!r}; "
                         f"have {sorted(BUILD_FNS)} or 'both'")
    return (builder,)


def run_dataset(name: str, corpus: np.ndarray, queries: np.ndarray,
                metric: str = "l2", builder: str = "incremental",
                ef_values: Sequence[int] = (64, 128),
                widths: Sequence[int] = DEFAULT_WIDTHS,
                repeats: int = 3) -> List[Dict]:
    """Sweep one dataset; `builder` may be a single name or "both"
    (incremental + bulk share the ground truth and search sweep)."""
    rows: List[Dict] = []
    gt = exact_knn(queries, corpus, K, metric=metric)
    gt_d = np.sort(
        ((preprocess_vectors(queries, metric)[:, None, :]
          - preprocess_vectors(corpus, metric)[gt]) ** 2).sum(-1), axis=1)
    for one in expand_builders(builder):
        rows += _run_one_builder(name, corpus, queries, metric, one,
                                 ef_values, widths, repeats, gt, gt_d)
    return rows


def _run_one_builder(name: str, corpus: np.ndarray, queries: np.ndarray,
                     metric: str, builder: str, ef_values: Sequence[int],
                     widths: Sequence[int], repeats: int,
                     gt: np.ndarray, gt_d: np.ndarray) -> List[Dict]:
    cfg = HNSWConfig(M=16, ef_construction=100, metric=metric)
    t0 = time.perf_counter()
    packed = BUILD_FNS[builder](corpus, cfg)
    t_build = time.perf_counter() - t0

    g, max_level, dev_metric = to_device(packed)

    rows = []
    qn = preprocess_vectors(queries, metric)
    corpus_n = preprocess_vectors(corpus, metric)
    q_dev = jnp.asarray(qn)
    for ef in ef_values:
        for width in widths:
            # warm at the timed shape so QPS measures the search, not XLA;
            # best-of-`repeats` timing (timeit-style) rejects machine-load
            # noise that would otherwise swamp the width comparison
            search(g, q_dev, k=K, ef=ef, max_level=max_level,
                   metric=dev_metric, expansion_width=width,
                   with_iters=True)[1].block_until_ready()
            t_search = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                d, ids, iters = search(g, q_dev, k=K, ef=ef,
                                       max_level=max_level,
                                       metric=dev_metric,
                                       expansion_width=width,
                                       with_iters=True)
                ids.block_until_ready()
                t_search = min(t_search, time.perf_counter() - t0)
            ids_np = np.asarray(ids)
            iters_np = np.asarray(iters)
            rec = recall_at_k(ids_np, gt)
            filled = (ids_np >= 0).mean()
            # last-distances ratio (ann-benchmarks): found kth / true kth
            found_vecs = corpus_n[np.maximum(ids_np[:, -1], 0)]
            found_last = ((qn - found_vecs) ** 2).sum(-1)
            ldr = float(np.mean(np.sqrt(np.maximum(found_last, 1e-12))
                                / np.sqrt(np.maximum(gt_d[:, -1], 1e-12))))
            rows.append({
                "dataset": name, "builder": builder, "ef": ef,
                "width": width,
                "n": len(corpus), "construction_s": round(t_build, 3),
                "search_s": round(t_search, 4),
                "qps": round(len(queries) / t_search, 1),
                "recall": round(rec, 4),
                "mean_iters": round(float(iters_np.mean()), 1),
                "max_iters": int(iters_np.max()),
                "fraction_returned": round(float(filled), 4),
                "last_dist_ratio": round(ldr, 4),
            })
    return rows


def write_report(rows: List[Dict], out_path: str, timestamp: float,
                 meta: Optional[Dict] = None) -> None:
    """Persist the sweep as JSON.  `timestamp` is supplied by the caller
    (CLI flag / CI env), keeping the report a pure function of its inputs."""
    report = {
        "bench": "hnsw",
        "timestamp": timestamp,
        "k": K,
        "rows": rows,
    }
    if meta:
        report["meta"] = meta
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


def check_recall_floor(rows: List[Dict], min_recall: float) -> List[str]:
    """Recall floor over the *widest* beam at the *largest* ef per dataset —
    the serving default at its quality setting — so perf PRs cannot silently
    trade recall for QPS.  (Small-ef rows are latency points; their recall
    is a property of ef, not of the traversal.)"""
    failures = []
    widest = max(r["width"] for r in rows)
    top_ef = max(r["ef"] for r in rows)
    for r in rows:
        if (r["width"] == widest and r["ef"] == top_ef
                and r["recall"] < min_recall):
            failures.append(
                f"{r['dataset']} ef={r['ef']} width={r['width']}: "
                f"recall {r['recall']:.4f} < floor {min_recall}")
    return failures


def check_builder_floor(rows: List[Dict], min_speedup: float,
                        recall_slack: float = 0.02) -> List[str]:
    """Construction-throughput gate for `--builder both` sweeps: per
    dataset, the bulk build must be at least ``min_speedup``× faster than
    the incremental build AND every (ef, width) cell's bulk recall must be
    within ``recall_slack`` of the incremental cell — "faster at equal
    recall", enforced, so the bulk path cannot regress either axis."""
    failures = []
    by_ds: Dict[str, Dict[str, List[Dict]]] = {}
    for r in rows:
        by_ds.setdefault(r["dataset"], {}).setdefault(
            r["builder"], []).append(r)
    for ds, builders in sorted(by_ds.items()):
        if "incremental" not in builders or "bulk" not in builders:
            continue
        inc_s = builders["incremental"][0]["construction_s"]
        blk_s = builders["bulk"][0]["construction_s"]
        speedup = inc_s / max(blk_s, 1e-9)
        if speedup < min_speedup:
            failures.append(
                f"{ds}: bulk construction {blk_s:.2f}s is only "
                f"{speedup:.2f}x faster than incremental {inc_s:.2f}s "
                f"(< {min_speedup}x floor)")
        inc_cells = {(r["ef"], r["width"]): r["recall"]
                     for r in builders["incremental"]}
        for r in builders["bulk"]:
            want = inc_cells.get((r["ef"], r["width"]))
            if want is not None and r["recall"] < want - recall_slack:
                failures.append(
                    f"{ds} ef={r['ef']} width={r['width']}: bulk recall "
                    f"{r['recall']:.4f} < incremental {want:.4f} - "
                    f"{recall_slack}")
    return failures


def main(n_fmnist: int = 6000, n_sift: int = 8000, n_queries: int = 200,
         builder: str = "incremental",
         widths: Sequence[int] = DEFAULT_WIDTHS,
         ef_values: Sequence[int] = (64, 128)) -> List[Dict]:
    print(f"# Table I reproduction (scaled: fmnist {n_fmnist}/60k, "
          f"sift {n_sift}/1M; builder={builder}; widths={tuple(widths)})")
    rows = []
    rows += run_dataset("fashion-mnist-784",
                        fashion_mnist_like(n_fmnist, seed=0),
                        fashion_mnist_like(n_queries, seed=1),
                        builder=builder, widths=widths, ef_values=ef_values)
    rows += run_dataset("sift-128", sift_like(n_sift, seed=0),
                        sift_like(n_queries, seed=1), builder=builder,
                        widths=widths, ef_values=ef_values)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
