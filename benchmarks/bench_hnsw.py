"""Paper Table I reproduction: HNSW on Fashion-MNIST-like and SIFT-like data.

Reports the paper's metrics: construction time (graph build machinery),
insertion time, search time at ef ∈ {64, 128}, recall rate, last-distances
ratio, mean fraction of neighbours returned, and QPS.

Offline-container deltas (DESIGN.md §8): datasets are statistically matched
synthetics; corpus sizes are scaled to the CPU budget (the paper ran 60k/1M
on a t4g.xlarge for hours) with the scale factor printed; wall-clock numbers
are host-CPU and NOT comparable to the paper's instance — recall/ratio
metrics are the comparable part.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import HNSWConfig, bulk_build, exact_knn, recall_at_k
from repro.core.hnsw_build import build as incremental_build, \
    preprocess_vectors
from repro.core.hnsw_search import search, to_device
from repro.data.synthetic import fashion_mnist_like, sift_like

K = 10


def run_dataset(name: str, corpus: np.ndarray, queries: np.ndarray,
                metric: str = "l2", builder: str = "incremental",
                ef_values=(64, 128)) -> List[Dict]:
    cfg = HNSWConfig(M=16, ef_construction=100, metric=metric)
    t0 = time.perf_counter()
    build_fn = incremental_build if builder == "incremental" else bulk_build
    packed = build_fn(corpus, cfg)
    t_build = time.perf_counter() - t0

    g, max_level, dev_metric = to_device(packed)
    gt = exact_knn(queries, corpus, K, metric=metric)
    gt_d = np.sort(
        ((preprocess_vectors(queries, metric)[:, None, :]
          - preprocess_vectors(corpus, metric)[gt]) ** 2).sum(-1), axis=1)

    rows = []
    for ef in ef_values:
        q_dev = jnp.asarray(preprocess_vectors(queries, metric))
        # warm (compile)
        search(g, q_dev[:4], k=K, ef=ef, max_level=max_level,
               metric=dev_metric)[1].block_until_ready()
        t0 = time.perf_counter()
        d, ids = search(g, q_dev, k=K, ef=ef, max_level=max_level,
                        metric=dev_metric)
        ids.block_until_ready()
        t_search = time.perf_counter() - t0
        ids_np = np.asarray(ids)
        rec = recall_at_k(ids_np, gt)
        filled = (ids_np >= 0).mean()
        # last-distances ratio (ann-benchmarks): found kth / true kth
        found_vecs = preprocess_vectors(corpus, metric)[
            np.maximum(ids_np[:, -1], 0)]
        qn = preprocess_vectors(queries, metric)
        found_last = ((qn - found_vecs) ** 2).sum(-1)
        ldr = float(np.mean(np.sqrt(np.maximum(found_last, 1e-12))
                            / np.sqrt(np.maximum(gt_d[:, -1], 1e-12))))
        rows.append({
            "dataset": name, "builder": builder, "ef": ef,
            "n": len(corpus), "construction_s": round(t_build, 3),
            "search_s": round(t_search, 4),
            "qps": round(len(queries) / t_search, 1),
            "recall": round(rec, 4),
            "fraction_returned": round(float(filled), 4),
            "last_dist_ratio": round(ldr, 4),
        })
    return rows


def main(n_fmnist: int = 6000, n_sift: int = 8000, n_queries: int = 200,
         builder: str = "incremental"):
    print(f"# Table I reproduction (scaled: fmnist {n_fmnist}/60k, "
          f"sift {n_sift}/1M; builder={builder})")
    rows = []
    rows += run_dataset("fashion-mnist-784",
                        fashion_mnist_like(n_fmnist, seed=0),
                        fashion_mnist_like(n_queries, seed=1),
                        builder=builder)
    rows += run_dataset("sift-128", sift_like(n_sift, seed=0),
                        sift_like(n_queries, seed=1), builder=builder)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
