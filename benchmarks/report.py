"""Assemble the dry-run JSON records into the EXPERIMENTS.md roofline table.

Usage:  PYTHONPATH=src:. python -m benchmarks.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load_records(root: str, mesh_tag: str) -> List[Dict]:
    out = []
    d = os.path.join(root, mesh_tag)
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                out.append(json.load(f))
    return out


def fmt_row(r: Dict) -> str:
    if r.get("skipped"):
        return f"| {r['cell']} | — | — | — | — | skip | — | — |"
    if not r.get("ok"):
        return f"| {r['cell']} | FAIL | | | | | | |"
    rl = r["roofline"]
    mfu = rl.get("roofline_mfu")
    ratio = rl.get("useful_flops_ratio")
    return ("| {cell} | {tc:.4f} | {tm:.4f} | {tl:.4f} | {bn} | {step:.4f} "
            "| {ratio} | {mfu} |").format(
        cell=r["cell"], tc=rl["t_compute_s"], tm=rl["t_memory_s"],
        tl=rl["t_collective_s"], bn=rl["bottleneck"],
        step=rl["roofline_step_s"],
        ratio=f"{ratio:.3f}" if ratio else "—",
        mfu=f"{mfu:.4f}" if mfu else "—")


HEADER = ("| cell | compute s | memory s | collective s | bottleneck | "
          "roofline step s | useful-FLOPs ratio | roofline MFU |\n"
          "|---|---|---|---|---|---|---|---|")


def table(records: List[Dict]) -> str:
    return "\n".join([HEADER] + [fmt_row(r) for r in records])


def summary(records: List[Dict]) -> Dict:
    ok = [r for r in records if r.get("ok") and not r.get("skipped")]
    bns = {}
    for r in ok:
        bn = r["roofline"]["bottleneck"]
        bns[bn] = bns.get(bn, 0) + 1
    worst = sorted(
        (r for r in ok if r["roofline"].get("roofline_mfu")),
        key=lambda r: r["roofline"]["roofline_mfu"])
    most_coll = sorted(
        ok, key=lambda r: -r["roofline"]["t_collective_s"] /
        max(r["roofline"]["roofline_step_s"], 1e-12))
    return {
        "cells_ok": len(ok),
        "bottlenecks": bns,
        "worst_mfu": [(r["cell"], r["roofline"]["roofline_mfu"])
                      for r in worst[:5]],
        "most_collective_bound": [
            (r["cell"], round(r["roofline"]["t_collective_s"]
                              / max(r["roofline"]["roofline_step_s"],
                                    1e-12), 3))
            for r in most_coll[:5]],
        "compile_s_max": max((r.get("compile_s", 0) for r in ok),
                             default=0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    for tag in ("pod16x16", "pod2x16x16"):
        recs = load_records(args.dir, tag)
        if not recs:
            continue
        print(f"\n## {tag} ({len(recs)} cells)\n")
        print(table(recs))
        print("\n", json.dumps(summary(recs), indent=1))


if __name__ == "__main__":
    main()
