"""Kernel microbenchmark (paper §II-B-3 SIMD claims, TPU form).

Two numbers per kernel:
  * wall-clock µs/call of the jnp oracle on this host CPU (what we can run)
  * analytic TPU-v5e roofline time for the same shape (what the BlockSpec
    tiling is designed for): max(flops/197e12, bytes/819e9)

The interpret-mode Pallas path is correctness-validated in tests; timing it
would measure the Python interpreter, so the oracle timing stands in for the
arithmetic while the analytic column stands in for the TPU target.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / iters


def main() -> List[Dict]:
    rng = np.random.RandomState(0)
    rows = []

    # L2 distance: 1024 queries x 100k corpus x 128d (SIFT-scale tile)
    q = jnp.asarray(rng.randn(1024, 128), jnp.float32)
    x = jnp.asarray(rng.randn(100_000, 128), jnp.float32)
    f = jax.jit(ref.l2_distance_ref)
    us = _time(f, q, x) * 1e6
    flops = 2.0 * 1024 * 100_000 * 128
    bytes_ = (1024 * 128 + 100_000 * 128 + 1024 * 100_000) * 4
    rows.append({"name": "l2_distance_1024x100k_d128", "us_per_call": round(us, 1),
                 "derived": f"tpu_roofline_us={max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6:.1f}"})

    # PQ ADC: 1024 queries x 1M codes, m=16 k=256
    lut = jnp.asarray(rng.rand(64, 16, 256), jnp.float32)
    codes = jnp.asarray(rng.randint(0, 256, (1_000_000, 16)), jnp.uint8)
    f = jax.jit(ref.pq_adc_ref)
    us = _time(f, lut, codes) * 1e6
    bytes_ = (64 * 16 * 256 * 4 + 1_000_000 * 16 + 64 * 1_000_000 * 4)
    flops = 64 * 1_000_000 * 16
    rows.append({"name": "pq_adc_64x1M_m16", "us_per_call": round(us, 1),
                 "derived": f"tpu_roofline_us={max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6:.1f}"})

    # Hamming: 256 queries x 1M codes, 256 bits
    qc = jnp.asarray(rng.randint(0, 2 ** 31, (256, 8)), jnp.uint32)
    xc = jnp.asarray(rng.randint(0, 2 ** 31, (1_000_000, 8)), jnp.uint32)
    f = jax.jit(ref.hamming_ref)
    us = _time(f, qc, xc) * 1e6
    bytes_ = (256 * 32 + 1_000_000 * 32 + 256 * 1_000_000 * 4)
    flops = 3.0 * 256 * 1_000_000 * 8
    rows.append({"name": "hamming_256x1M_256b", "us_per_call": round(us, 1),
                 "derived": f"tpu_roofline_us={max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6:.1f}"})

    print("# kernel microbenchmarks (host-CPU oracle µs + TPU analytic)")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    return rows


if __name__ == "__main__":
    main()
