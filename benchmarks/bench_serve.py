"""End-to-end serving benchmark: client -> HTTP -> service -> batcher.

Measures the full request plane the way a user sees it: a live
`ThreadingHTTPServer` in this process, `QuantixarClient` workers firing
single-vector searches from a closed loop, and per-request wall-clock
latency.  Reports JSON (QPS, p50/p99 ms, recall@k, batcher coalescing) so CI
and `benchmarks/report.py`-style tooling can track serving regressions.

    PYTHONPATH=src python benchmarks/bench_serve.py --n 20000 --quant pq \
        --requests 400 --concurrency 16
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np

from repro.api import QuantixarClient
from repro.core.hnsw_build import exact_knn
from repro.data.synthetic import gaussian_mixture
from repro.launch.serve import _recall_of, build_database
from repro.serving.http import QuantixarHTTPServer
from repro.serving.service import QuantixarService, ServiceConfig

K = 10


def run_bench(args) -> Dict:
    db, corpus = build_database(args.n, args.dim, args.index, args.quant,
                                max_batch=args.max_batch,
                                max_wait_ms=args.max_wait_ms)
    col_embedded = db["corpus"]
    # build outside the timed window
    col_embedded.query(gaussian_mixture(1, args.dim, seed=5)[0]).top_k(1).run()

    service = QuantixarService(db, ServiceConfig(
        default_max_batch=args.max_batch,
        default_max_wait_ms=args.max_wait_ms))
    server = QuantixarHTTPServer(service).start()
    client = QuantixarClient(server.url, timeout=60)
    col = client.collection("corpus")

    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    gt = exact_knn(queries, corpus, K, metric="cosine")

    latencies: List[float] = [0.0] * args.requests
    results: List = [None] * args.requests
    cursor = iter(range(args.requests))
    cursor_lock = threading.Lock()

    errors: List[str] = []

    def worker():
        while True:
            with cursor_lock:
                i = next(cursor, None)
            if i is None:
                return
            t0 = time.perf_counter()
            try:
                results[i] = col.query(queries[i]).top_k(K).run()
            except Exception as exc:          # noqa: BLE001 — keep measuring
                errors.append(f"request {i}: {exc}")
            latencies[i] = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker)
               for _ in range(args.concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    done = [(r, t, l) for r, t, l in zip(results, gt, latencies)
            if r is not None]
    if not done:
        raise RuntimeError(f"every request failed; first: {errors[:3]}")
    recall = _recall_of([r for r, _, _ in done], [t for _, t, _ in done], K)
    stats = col.stats()
    lat = np.asarray([l for _, _, l in done])
    out = {
        "bench": "serve_e2e",
        "n": args.n, "dim": args.dim, "index": args.index,
        "quant": args.quant, "k": K,
        "requests": args.requests, "concurrency": args.concurrency,
        "max_batch": args.max_batch, "max_wait_ms": args.max_wait_ms,
        "wall_s": round(wall, 4),
        "qps": round(args.requests / wall, 1),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "mean_ms": round(float(lat.mean()), 3),
        "recall": round(recall, 4),
        "failed": len(errors),
        "batches_served": stats["serving_batches_served"],
        "requests_batched": stats["serving_requests_served"],
    }
    if errors:
        out["first_errors"] = errors[:3]
    server.shutdown()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat", "ivf"])
    ap.add_argument("--quant", default="none", choices=["none", "pq", "bq"])
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--concurrency", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    args = ap.parse_args()
    print(json.dumps(run_bench(args), indent=2))


if __name__ == "__main__":
    main()
