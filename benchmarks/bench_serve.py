"""End-to-end serving benchmark: client -> HTTP -> service -> batcher.

Measures the full request plane the way a user sees it: a live
`ThreadingHTTPServer` in this process, `QuantixarClient` workers firing
single-vector searches from a closed loop, and per-request wall-clock
latency.  Reports JSON (QPS, p50/p99 ms, recall@k, batcher coalescing) so CI
and `benchmarks/report.py`-style tooling can track serving regressions.

    PYTHONPATH=src python benchmarks/bench_serve.py --n 20000 --quant pq \
        --requests 400 --concurrency 16

`--shards 1,2,4` sweeps shard counts (the QPS/p99-vs-shard-count study shape
from the HPC distributed-VDB paper): the same corpus is re-served as a
`ShardedCollection` at each count and every configuration reports its own
QPS/p50/p99/recall row.  With `--gate`, the sweep enforces the scaling
contract — sharded recall must equal single-shard recall (exact merge, so
use `--index flat` where both sides are exact), and QPS at the highest
shard count must not lose to one shard — and exits non-zero on violation.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from typing import Dict, List

import numpy as np

from repro.api import QuantixarClient
from repro.core.hnsw_build import exact_knn
from repro.data.synthetic import gaussian_mixture
from repro.launch.serve import _recall_of, build_database
from repro.serving.http import QuantixarHTTPServer
from repro.serving.service import QuantixarService, ServiceConfig

K = 10


def run_bench(args, shards: int = 1) -> Dict:
    db, corpus = build_database(args.n, args.dim, args.index, args.quant,
                                max_batch=args.max_batch,
                                max_wait_ms=args.max_wait_ms,
                                shards=shards)
    col_embedded = db["corpus"]
    # build + kernel warm-up outside the timed window: the jitted search
    # kernels specialize on the query-count dimension, and the serving
    # batcher flushes power-of-two buckets — touch every (bucket, corpus)
    # shape each shard can see, else one-off XLA compiles (~100-400ms)
    # masquerade as serving p99
    warm = gaussian_mixture(args.max_batch, args.dim, seed=5)
    b = 1
    while b <= args.max_batch:
        col_embedded.search(warm[:b], K)
        b *= 2

    service = QuantixarService(db, ServiceConfig(
        default_max_batch=args.max_batch,
        default_max_wait_ms=args.max_wait_ms))
    server = QuantixarHTTPServer(service).start()
    client = QuantixarClient(server.url, timeout=60)
    col = client.collection("corpus")

    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    gt = exact_knn(queries, corpus, K, metric="cosine")

    latencies: List[float] = [0.0] * args.requests
    results: List = [None] * args.requests
    cursor = iter(range(args.requests))
    cursor_lock = threading.Lock()

    errors: List[str] = []

    def worker():
        while True:
            with cursor_lock:
                i = next(cursor, None)
            if i is None:
                return
            t0 = time.perf_counter()
            try:
                results[i] = col.query(queries[i]).top_k(K).run()
            except Exception as exc:          # noqa: BLE001 — keep measuring
                errors.append(f"request {i}: {exc}")
            latencies[i] = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker)
               for _ in range(args.concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    done = [(r, t, l) for r, t, l in zip(results, gt, latencies)
            if r is not None]
    if not done:
        raise RuntimeError(f"every request failed; first: {errors[:3]}")
    recall = _recall_of([r for r, _, _ in done], [t for _, t, _ in done], K)
    stats = col.stats()
    lat = np.asarray([l for _, _, l in done])
    out = {
        "bench": "serve_e2e",
        "n": args.n, "dim": args.dim, "index": args.index,
        "quant": args.quant, "k": K, "shards": shards,
        "requests": args.requests, "concurrency": args.concurrency,
        "max_batch": args.max_batch, "max_wait_ms": args.max_wait_ms,
        "wall_s": round(wall, 4),
        "qps": round(args.requests / wall, 1),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "mean_ms": round(float(lat.mean()), 3),
        "recall": round(recall, 4),
        "failed": len(errors),
        "batches_served": stats.get("serving_batches_served"),
        "requests_batched": stats.get("serving_requests_served"),
    }
    if errors:
        out["first_errors"] = errors[:3]
    server.shutdown()
    return out


def run_sweep(args, shard_counts: List[int]) -> Dict:
    """Re-serve the same corpus at each shard count; same queries, same
    ground truth, one row per configuration."""
    rows = [run_bench(args, shards=s) for s in shard_counts]
    out: Dict = {"bench": "serve_shard_sweep", "sweep": rows}
    if len(rows) > 1:
        base, top = rows[0], max(rows, key=lambda r: r["shards"])
        gates = {
            # the global merge is exact, so at an exact index sharding may
            # not change a single hit — recall must match to the digit
            "recall_parity": all(r["recall"] == base["recall"]
                                 for r in rows),
            # scaling contract: the widest fan-out must not lose to one
            # shard (5% jitter allowance for CI machines)
            "qps_scaling": top["qps"] >= 0.95 * base["qps"],
            "no_failures": all(r["failed"] == 0 for r in rows),
        }
        out["gates"] = gates
        out["gates_passed"] = all(gates.values())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat", "ivf"])
    ap.add_argument("--quant", default="none", choices=["none", "pq", "bq"])
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--concurrency", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--shards", default="1",
                    help="comma-separated shard counts to sweep, e.g. 1,2,4")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero unless sharded recall == "
                         "single-shard recall and QPS holds at max shards")
    args = ap.parse_args()
    shard_counts = [int(s) for s in args.shards.split(",") if s.strip()]

    if shard_counts == [1]:
        print(json.dumps(run_bench(args), indent=2))
        return 0
    out = run_sweep(args, shard_counts)
    print(json.dumps(out, indent=2))
    if args.gate and not out.get("gates_passed", True):
        print(f"[bench-serve] GATE FAILED: {out['gates']}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
