"""JAX/Pallas hygiene lint (rules PAL001-PAL004).

Analyzes functions that run under tracing — ``@jax.jit`` / ``@jit`` /
``functools.partial(jax.jit, ...)`` decorated functions, and Pallas kernel
bodies handed to ``pl.pallas_call`` — with a lightweight intraprocedural
taint pass: non-static parameters are *traced*; taint propagates through
assignments and expressions but dies at shape/dtype introspection
(``x.shape``, ``x.ndim``, ``x.dtype``, ``len(x)``), which is static under
tracing.

Rules:
  PAL001  host-side value extraction on a traced value inside a traced
          function: ``float()/int()/bool()`` calls, ``.item()`` /
          ``.tolist()``, or any ``np.*`` call taking a traced argument
          (silent device sync at best, tracer leak at worst)
  PAL002  Python control flow (``if``/``while``/``for``/ternary/``assert``)
          conditioned on a traced value — must be ``lax.cond`` /
          ``lax.while_loop`` / ``jnp.where`` (``x is None`` checks are
          trace-time structure and stay legal)
  PAL003  unhashable static argument: a static parameter with a mutable
          default, or a call site passing a list/dict/set literal for a
          static parameter (jit would raise at runtime — catch it in CI)
  PAL004  kernel-registry drift: a module under ``kernels/`` exports a
          ``*_kernel`` entry point with no ``*_ref`` reference
          implementation in ``ref.py`` or no ``force_ref`` dispatcher in
          ``ops.py`` routing between the two

``# pallas-ok: <reason>`` on the flagged line (or the ``def`` line for a
whole function) suppresses PAL001/PAL002; a reasonless hatch is itself a
violation (PAL001 with a dedicated message).
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set

from .report import (Source, Violation, const_str_tuple, dotted_name,
                     find_suppression, signature_lines, sort_violations)

# attribute reads that collapse a traced value to static python
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
# host-extraction method calls on traced arrays
_HOST_METHODS = {"item", "tolist", "numpy"}
# builtins that force concretization
_CONCRETIZERS = {"float", "int", "bool", "complex"}

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_PALLAS_CALL_NAMES = {"pl.pallas_call", "pallas_call"}


class _JitTarget:
    """One function to analyze + which of its params are static."""

    def __init__(self, fn: ast.FunctionDef, static: Set[str], kind: str):
        self.fn = fn
        self.static = static
        self.kind = kind                    # "jit" | "pallas-kernel"

    def param_names(self) -> List[str]:
        a = self.fn.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _jit_decoration(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """Static param names when fn is jit-decorated, else None."""
    for dec in fn.decorator_list:
        name = dotted_name(dec)
        if name in _JIT_NAMES:
            return set()
        if isinstance(dec, ast.Call):
            fname = dotted_name(dec.func)
            if fname in _JIT_NAMES:
                return _static_from_kwargs(fn, dec.keywords)
            if fname in _PARTIAL_NAMES and dec.args \
                    and dotted_name(dec.args[0]) in _JIT_NAMES:
                return _static_from_kwargs(fn, dec.keywords)
    return None


def _static_from_kwargs(fn: ast.FunctionDef,
                        keywords: List[ast.keyword]) -> Set[str]:
    static: Set[str] = set()
    a = fn.args
    positional = [p.arg for p in a.posonlyargs + a.args]
    for kw in keywords:
        if kw.arg == "static_argnames":
            names = const_str_tuple(kw.value)
            if names:
                static |= set(names)
        elif kw.arg == "static_argnums":
            nums: List[int] = []
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, int):
                nums = [kw.value.value]
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                nums = [e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
            for n in nums:
                if 0 <= n < len(positional):
                    static.add(positional[n])
    return static


def _pallas_kernel_names(tree: ast.Module) -> Set[str]:
    """Function names passed (possibly via functools.partial) as the first
    argument of a ``pl.pallas_call`` in this module."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func) in _PALLAS_CALL_NAMES
                and node.args):
            continue
        head = node.args[0]
        if isinstance(head, ast.Call) \
                and dotted_name(head.func) in _PARTIAL_NAMES and head.args:
            head = head.args[0]
        name = dotted_name(head)
        if name:
            out.add(name.split(".")[-1])
    return out


def _collect_targets(src: Source) -> List[_JitTarget]:
    targets = []
    kernel_names = _pallas_kernel_names(src.tree)
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        static = _jit_decoration(node)
        if static is not None:
            targets.append(_JitTarget(node, static, "jit"))
        elif node.name in kernel_names:
            # Pallas kernel body: positional params are Refs (traced);
            # keyword-only params are bound via functools.partial (static)
            a = node.args
            kw_static = {p.arg for p in a.kwonlyargs}
            targets.append(_JitTarget(node, kw_static, "pallas-kernel"))
    return targets


class _Taint(ast.NodeVisitor):
    """Single forward pass over a traced function body."""

    def __init__(self, src: Source, target: _JitTarget,
                 violations: List[Violation]):
        self.src = src
        self.target = target
        self.violations = violations
        self.tainted: Set[str] = {
            p for p in target.param_names() if p not in target.static}

    # ------------------------------------------------------------- taint expr
    def is_tainted(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False                  # static under tracing
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname == "len":
                return False                  # static length
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _STATIC_ATTRS:
                return False
            parts = [node.func] if not isinstance(node.func, ast.Name) else []
            parts += list(node.args) + [kw.value for kw in node.keywords]
            return any(self.is_tainted(p) for p in parts)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is trace-time structure
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return any(self.is_tainted(c)
                       for c in [node.left] + list(node.comparators))
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        return any(self.is_tainted(c) for c in ast.iter_child_nodes(node))

    # ----------------------------------------------------------- assignments
    def _assign_names(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._assign_names(elt, tainted)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        tainted = self.is_tainted(node.value)
        for target in node.targets:
            self._assign_names(target, tainted)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self.generic_visit(node)
            self._assign_names(node.target, self.is_tainted(node.value))

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        if self.is_tainted(node.value):
            self._assign_names(node.target, True)

    # -------------------------------------------------------------- nested fn
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs (scan/loop bodies, per-subspace closures): their own
        # params are traced by the enclosing combinator; closure taint rides
        # along.  Decorators like @pl.when(pred) are the sanctioned form of
        # traced branching — not flagged.
        inner_params = {p.arg for p in node.args.posonlyargs
                        + node.args.args + node.args.kwonlyargs}
        saved = set(self.tainted)
        self.tainted |= inner_params
        for stmt in node.body:
            self.visit(stmt)
        self.tainted = saved

    # ------------------------------------------------------------- violations
    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        reason = find_suppression(
            self.src, list(self.src.span_lines(node)), "pallas")
        if reason == "":
            self.violations.append(Violation(
                "PAL001", self.src.path, node.lineno,
                "'# pallas-ok:' needs a reason"))
            return
        if reason is not None:
            return
        self.violations.append(Violation(rule, self.src.path, node.lineno,
                                         message))

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        fname = dotted_name(node.func)
        where = f"in traced function {self.target.fn.name!r}"
        if fname in _CONCRETIZERS and node.args \
                and self.is_tainted(node.args[0]):
            self._flag(node, "PAL001",
                       f"{fname}() concretizes a traced value {where}")
            return
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _HOST_METHODS \
                and self.is_tainted(node.func.value):
            self._flag(node, "PAL001",
                       f".{node.func.attr}() pulls a traced value to host "
                       f"{where}")
            return
        if fname and fname.split(".")[0] in ("np", "numpy") \
                and len(fname.split(".")) > 1:
            args = list(node.args) + [kw.value for kw in node.keywords]
            if any(self.is_tainted(a) for a in args):
                self._flag(node, "PAL001",
                           f"{fname}() is host numpy on a traced value "
                           f"{where} — use jnp")

    def _flag_branch(self, node: ast.AST, test: ast.AST, kind: str) -> None:
        if self.is_tainted(test):
            self._flag(node, "PAL002",
                       f"Python {kind} on a traced value in "
                       f"{self.target.fn.name!r} — use lax.cond/"
                       f"lax.while_loop/jnp.where")

    def visit_If(self, node: ast.If) -> None:
        self._flag_branch(node, node.test, "if")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._flag_branch(node, node.test, "ternary")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._flag_branch(node, node.test, "while")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._flag_branch(node, node.test, "assert")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self.is_tainted(node.iter):
            self._flag(node, "PAL002",
                       f"Python for-loop over a traced value in "
                       f"{self.target.fn.name!r} — use lax.fori_loop/scan")
        # the loop variable binds elements of the iterable
        self._assign_names(node.target, self.is_tainted(node.iter))
        for stmt in node.body + node.orelse:
            self.visit(stmt)


def _check_static_hashability(src: Source, targets: List[_JitTarget],
                              violations: List[Violation]) -> None:
    static_by_fn: Dict[str, Set[str]] = {
        t.fn.name: t.static for t in targets if t.kind == "jit" and t.static}
    # mutable defaults on static params
    for t in targets:
        if t.kind != "jit" or not t.static:
            continue
        a = t.fn.args
        named = a.posonlyargs + a.args
        for param, default in zip(named[len(named) - len(a.defaults):],
                                  a.defaults):
            if param.arg in t.static \
                    and isinstance(default, (ast.List, ast.Dict, ast.Set)):
                violations.append(Violation(
                    "PAL003", src.path, default.lineno,
                    f"static arg {param.arg!r} of {t.fn.name!r} has an "
                    f"unhashable (mutable) default"))
        for param, default in zip(a.kwonlyargs, a.kw_defaults):
            if default is not None and param.arg in t.static \
                    and isinstance(default, (ast.List, ast.Dict, ast.Set)):
                violations.append(Violation(
                    "PAL003", src.path, default.lineno,
                    f"static arg {param.arg!r} of {t.fn.name!r} has an "
                    f"unhashable (mutable) default"))
    # call sites passing unhashable literals for known static params
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname is None:
            continue
        static = static_by_fn.get(fname.split(".")[-1])
        if not static:
            continue
        for kw in node.keywords:
            if kw.arg in static and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                               ast.DictComp, ast.SetComp)):
                violations.append(Violation(
                    "PAL003", src.path, kw.value.lineno,
                    f"call to {fname!r} passes an unhashable literal for "
                    f"static arg {kw.arg!r} — jit will raise; use a tuple"))


def check_jax_hygiene(paths: Sequence[str]) -> List[Violation]:
    """PAL001-PAL003 over the given Python files."""
    violations: List[Violation] = []
    for path in paths:
        src = Source.load(path)
        targets = _collect_targets(src)
        for target in targets:
            sig = list(signature_lines(target.fn))
            reason = find_suppression(src, sig, "pallas")
            if reason == "":
                violations.append(Violation(
                    "PAL001", src.path, target.fn.lineno,
                    f"'# pallas-ok:' on {target.fn.name!r} needs a reason"))
                continue
            if reason is not None:
                continue
            taint = _Taint(src, target, violations)
            for stmt in target.fn.body:
                taint.visit(stmt)
        _check_static_hashability(src, targets, violations)
    return sort_violations(violations)


def check_kernel_registry(kernels_dir: str) -> List[Violation]:
    """PAL004: every kernel module ships a reference implementation and a
    force_ref dispatcher."""
    violations: List[Violation] = []
    ref_path = os.path.join(kernels_dir, "ref.py")
    ops_path = os.path.join(kernels_dir, "ops.py")
    for required in (ref_path, ops_path):
        if not os.path.exists(required):
            violations.append(Violation(
                "PAL004", required, 1,
                "kernels/ must ship ref.py (oracles) and ops.py "
                "(force_ref dispatchers)"))
            return violations
    ref_src = Source.load(ref_path)
    ops_src = Source.load(ops_path)
    ref_fns = {n.name for n in ref_src.tree.body
               if isinstance(n, ast.FunctionDef)}
    # dispatchers: ops.py functions with a force_ref param; note every name
    # they call so kernel entry points can be matched against them
    dispatched: Set[str] = set()
    for node in ops_src.tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        params = {p.arg for p in node.args.args + node.args.kwonlyargs}
        if "force_ref" not in params:
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func)
                if name:
                    dispatched.add(name.split(".")[-1])
    for fname in sorted(os.listdir(kernels_dir)):
        stem, ext = os.path.splitext(fname)
        if ext != ".py" or stem in ("__init__", "ref", "ops"):
            continue
        src = Source.load(os.path.join(kernels_dir, fname))
        for node in src.tree.body:
            if not isinstance(node, ast.FunctionDef) \
                    or not node.name.endswith("_kernel") \
                    or node.name.startswith("_"):
                continue
            reason = find_suppression(src, [node.lineno], "pallas")
            if reason == "":
                violations.append(Violation(
                    "PAL001", src.path, node.lineno,
                    f"'# pallas-ok:' on {node.name!r} needs a reason"))
                continue
            if reason is not None:
                continue
            kernel_stem = node.name[: -len("_kernel")]
            if not any(r.startswith(kernel_stem) and r.endswith("_ref")
                       for r in ref_fns):
                violations.append(Violation(
                    "PAL004", src.path, node.lineno,
                    f"kernel {node.name!r} has no {kernel_stem}*_ref oracle "
                    f"in kernels/ref.py"))
            if node.name not in dispatched:
                violations.append(Violation(
                    "PAL004", ops_src.path, 1,
                    f"kernel {node.name!r} has no force_ref dispatcher in "
                    f"kernels/ops.py"))
    return sort_violations(violations)
