"""Lock-discipline checker (rules LOCK001-LOCK004).

Convention (see tools/qlint/README.md): a concurrency-critical class
declares which lock guards which attribute with a trailing comment on the
attribute's assignment —

    self._ids: List[str] = []          # guarded-by: _lock
    self._closed = False               # guarded-by: _lock|_batcher_init_lock

Lock attribute names must start with an underscore.  ``a|b`` means the
attribute may be touched while holding *either* lock (writers are expected
to hold all of them — enforce that by construction, e.g. ``close()``).

The checker then rejects any method that reads or writes a guarded
attribute outside a ``with self.<lock>`` block.  Escapes:

  * ``# unguarded-ok: <reason>`` on the access line (or on the ``def``
    line to exempt a whole method) — for deliberate racy reads;
  * ``# requires-lock: <lock>`` on the ``def`` line — the documented
    "caller holds the lock" contract for internal helpers; the method
    body is analyzed as if the named lock were held.

Rules:
  LOCK001  guarded attribute accessed without holding a declared lock
  LOCK002  guarded-by / requires-lock names a lock the class never creates
  LOCK003  escape hatch without a reason
  LOCK004  guarded-by annotation outside any class body (inert)
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Sequence, Set

from .report import (Source, Violation, find_suppression, self_attr,
                     signature_lines, sort_violations)

# lock lists: underscore-prefixed attribute names, separated by | or ,
_LOCKS = r"(_[A-Za-z0-9_]+(?:\s*[|,]\s*_[A-Za-z0-9_]+)*)"
GUARDED_RE = re.compile(rf"#\s*guarded-by:\s*{_LOCKS}")
REQUIRES_RE = re.compile(rf"#\s*requires-lock:\s*{_LOCKS}")


def _lock_names(spec: str) -> Set[str]:
    return {name.strip() for name in re.split(r"[|,]", spec) if name.strip()}


class _ClassInfo:
    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.guarded: Dict[str, Set[str]] = {}   # attr -> locks that guard it
        self.assigned: Set[str] = set()          # every self.<attr> ever set


def _collect_class(src: Source, node: ast.ClassDef) -> _ClassInfo:
    info = _ClassInfo(node)
    for sub in ast.walk(node):
        targets: List[ast.AST] = []
        if isinstance(sub, ast.Assign):
            targets = list(sub.targets)
        elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
            targets = [sub.target]
        else:
            continue
        attrs = [a for a in map(self_attr, targets) if a is not None]
        if not attrs:
            continue
        info.assigned.update(attrs)
        for lineno in src.span_lines(sub):
            m = GUARDED_RE.search(src.line(lineno))
            if m:
                locks = _lock_names(m.group(1))
                for attr in attrs:
                    info.guarded.setdefault(attr, set()).update(locks)
                break
    return info


class _MethodChecker(ast.NodeVisitor):
    """Walks one method body tracking which declared locks are lexically
    held (``with self.<lock>:``) at each guarded-attribute access."""

    def __init__(self, src: Source, cls: _ClassInfo, method: ast.AST,
                 held: Set[str], violations: List[Violation]):
        self.src = src
        self.cls = cls
        self.method = method
        self.held = set(held)
        self.violations = violations
        self.lock_attrs = set().union(*cls.guarded.values()) \
            if cls.guarded else set()

    # ------------------------------------------------------------ lock scope
    def visit_With(self, node: ast.With) -> None:
        acquired = set()
        for item in node.items:
            name = self_attr(item.context_expr)
            if name in self.lock_attrs and name not in self.held:
                acquired.add(name)    # re-entrant with: outer scope owns it
        self.held |= acquired
        self.generic_visit(node)
        self.held -= acquired

    # nested defs inherit the lexical lock scope (closures that escape the
    # block are out of scope for a static checker)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    # --------------------------------------------------------------- accesses
    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = self_attr(node)
        if attr is not None and attr in self.cls.guarded:
            locks = self.cls.guarded[attr]
            if not (locks & self.held):
                self._report_or_suppress(node, attr, locks)
        self.generic_visit(node)

    def _report_or_suppress(self, node: ast.Attribute, attr: str,
                            locks: Set[str]) -> None:
        reason = find_suppression(self.src, list(self.src.span_lines(node)),
                                  "unguarded")
        if reason == "":
            self.violations.append(Violation(
                "LOCK003", self.src.path, node.lineno,
                f"'# unguarded-ok:' on access to {attr!r} needs a reason"))
            return
        if reason is not None:
            return
        want = "|".join(sorted(locks))
        method = getattr(self.method, "name", "<module>")
        self.violations.append(Violation(
            "LOCK001", self.src.path, node.lineno,
            f"{self.cls.node.name}.{method} touches {attr!r} (guarded-by: "
            f"{want}) outside 'with self.{next(iter(sorted(locks)))}'"
            + ("" if len(locks) == 1 else " (any declared lock satisfies)")))


def _check_class(src: Source, info: _ClassInfo,
                 violations: List[Violation]) -> None:
    if not info.guarded:
        return
    # every named lock must actually exist on the class
    for attr, locks in sorted(info.guarded.items()):
        for lock in sorted(locks):
            if lock not in info.assigned:
                violations.append(Violation(
                    "LOCK002", src.path, info.node.lineno,
                    f"{info.node.name}.{attr} is guarded-by {lock!r}, but "
                    f"the class never assigns self.{lock}"))
    for method in info.node.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if method.name == "__init__":
            continue     # the object is not yet visible to other threads
        sig = list(signature_lines(method))
        reason = find_suppression(src, sig, "unguarded")
        if reason == "":
            violations.append(Violation(
                "LOCK003", src.path, method.lineno,
                f"'# unguarded-ok:' on {info.node.name}.{method.name} "
                f"needs a reason"))
            continue
        if reason is not None:
            continue     # whole method exempted
        held: Set[str] = set()
        for lineno in sig:
            m = REQUIRES_RE.search(src.line(lineno))
            if m:
                held |= _lock_names(m.group(1))
        for lock in sorted(held):
            if lock not in info.assigned:
                violations.append(Violation(
                    "LOCK002", src.path, method.lineno,
                    f"{info.node.name}.{method.name} requires-lock {lock!r}, "
                    f"but the class never assigns self.{lock}"))
        checker = _MethodChecker(src, info, method, held, violations)
        for stmt in method.body:
            checker.visit(stmt)


def check_lock_discipline(paths: Sequence[str]) -> List[Violation]:
    """Run the lock-discipline analyzer over the given Python files."""
    violations: List[Violation] = []
    for path in paths:
        src = Source.load(path)
        class_lines: Set[int] = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                class_lines.update(src.span_lines(node))
                _check_class(src, _collect_class(src, node), violations)
        # a guarded-by annotation outside any class is dead weight — flag it
        # so a stray paste can't look like coverage
        for lineno, line in enumerate(src.lines, start=1):
            if GUARDED_RE.search(line) and lineno not in class_lines:
                violations.append(Violation(
                    "LOCK004", src.path, lineno,
                    "guarded-by annotation outside a class body has no "
                    "effect"))
    return sort_violations(violations)
