"""qlint command line: run all analyzers, print violations, exit nonzero.

Usage (from the repo root)::

    PYTHONPATH=src:. python -m tools.qlint            # whole repo
    python -m tools.qlint --only locks src/repro/api/collection.py

Exit status is the number of violations (capped at 125) so ``make lint``
and CI fail on any finding.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Sequence

from .jaxlint import check_jax_hygiene, check_kernel_registry
from .locks import check_lock_discipline
from .report import Violation
from .wire import WirePaths, check_wire_protocol

_ANALYZERS = ("locks", "wire", "jax", "kernels")


def _repo_root() -> str:
    # tools/qlint/cli.py -> repo root is two levels up from tools/
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _python_files(root: str) -> List[str]:
    out: List[str] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                out.append(os.path.join(dirpath, fname))
    return sorted(out)


def main(argv: Sequence[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="qlint", description="Quantixar repo-custom static analysis")
    parser.add_argument(
        "paths", nargs="*",
        help="files to check (default: the whole serving/kernel tree)")
    parser.add_argument(
        "--only", choices=_ANALYZERS, action="append", default=None,
        help="run a subset of analyzers (repeatable)")
    parser.add_argument(
        "--root", default=None,
        help="repo root (default: derived from this file's location)")
    args = parser.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else _repo_root()
    src = os.path.join(root, "src", "repro")
    enabled = set(args.only) if args.only else set(_ANALYZERS)

    if args.paths:
        files = [os.path.abspath(p) for p in args.paths]
    else:
        files = _python_files(src)

    violations: List[Violation] = []
    if "locks" in enabled:
        violations += check_lock_discipline(files)
    if "jax" in enabled:
        violations += check_jax_hygiene(files)
    if "kernels" in enabled:
        kernels_dir = os.path.join(src, "kernels")
        if os.path.isdir(kernels_dir):
            violations += check_kernel_registry(kernels_dir)
    if "wire" in enabled and not args.paths:
        # the wire checker cross-references four fixed modules; it only
        # makes sense on the full tree, not on an ad-hoc file list
        violations += check_wire_protocol(WirePaths(
            requests_py=os.path.join(src, "api", "requests.py"),
            service_py=os.path.join(src, "serving", "service.py"),
            http_py=os.path.join(src, "serving", "http.py"),
            client_py=os.path.join(src, "api", "client.py"),
        ))

    rel = []
    for v in violations:
        path = os.path.relpath(v.path, root) \
            if os.path.isabs(v.path) else v.path
        rel.append(Violation(v.rule, path, v.line, v.message))
    for v in rel:
        print(v.format())
    n = len(rel)
    if n:
        print(f"qlint: {n} violation{'s' if n != 1 else ''}",
              file=sys.stderr)
    else:
        checked = ", ".join(sorted(enabled))
        print(f"qlint: clean ({len(files)} files; {checked})",
              file=sys.stderr)
    return min(n, 125)


if __name__ == "__main__":
    sys.exit(main())
