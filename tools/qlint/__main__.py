"""``python -m tools.qlint`` entry point."""

import sys

from .cli import main

sys.exit(main())
