"""qlint: repo-custom static analysis for the Quantixar serving and
kernel planes.

Three AST-based analyzers, run via ``make lint`` / ``python -m tools.qlint``:

  * :mod:`tools.qlint.locks`   — lock-discipline checker (``# guarded-by:``
    annotation convention; see tools/qlint/README.md);
  * :mod:`tools.qlint.wire`    — wire-protocol exhaustiveness checker
    (request dataclasses ↔ service dispatch ↔ HTTP routes ↔ client);
  * :mod:`tools.qlint.jaxlint` — JAX/Pallas hygiene (Python branching /
    host calls on traced values, unhashable static args, kernel
    reference-implementation registry).

Plus a runtime twin, :mod:`tools.qlint.runtime`: an instrumented
``TracedRLock`` that records the lock-acquisition-order graph across
threads, detects order cycles (potential deadlocks) and long holds, and
powers the thread-fuzz stress test.
"""

from .report import Violation
from .locks import check_lock_discipline
from .wire import check_wire_protocol
from .jaxlint import check_jax_hygiene, check_kernel_registry

__all__ = [
    "Violation",
    "check_lock_discipline",
    "check_wire_protocol",
    "check_jax_hygiene",
    "check_kernel_registry",
]
