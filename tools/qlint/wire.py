"""Wire-protocol exhaustiveness checker (rules WIRE001-WIRE005).

The request plane has four legs that must stay in lockstep for every
operation, or embedded-vs-remote parity silently drifts:

  1. the request dataclass in ``api/requests.py`` (with its ``op`` tag and
     membership in the ``AnyRequest`` codec union);
  2. a dispatch case in ``QuantixarService._HANDLERS``
     (``serving/service.py``);
  3. an HTTP route in ``serving/http.py`` that builds the dataclass;
  4. a client call in ``api/client.py`` hitting that route.

This analyzer cross-references all four by AST — adding a request type
without completing every leg fails ``make lint``.  A deliberately
transport-less op can carry ``# wire-ok: <reason>`` on its class line to
waive legs 3 and 4 (the typed service path and ``/v1/rpc`` still serve it).

Rules:
  WIRE001  request class missing from the AnyRequest union
  WIRE002  request class has no QuantixarService._HANDLERS entry
  WIRE003  request class is never built by an HTTP route
  WIRE004  route path for a request class never referenced by the client
  WIRE005  escape hatch without a reason
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from .report import Source, Violation, find_suppression, sort_violations

_GROUP_RE = re.compile(r"\([^)]*\)")


@dataclasses.dataclass
class WirePaths:
    """The four modules whose agreement the checker enforces."""

    requests_py: str
    service_py: str
    http_py: str
    client_py: str


@dataclasses.dataclass
class _RequestClass:
    name: str
    op: str
    lineno: int
    waived: bool          # wire-ok: HTTP/client legs not required
    waive_reasonless: bool


def _request_classes(src: Source) -> List[_RequestClass]:
    out = []
    for node in src.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        bases = {b.id for b in node.bases if isinstance(b, ast.Name)}
        if "Request" not in bases:
            continue
        op: Optional[str] = None
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "op"
                            for t in stmt.targets) \
                    and isinstance(stmt.value, ast.Constant):
                op = stmt.value.value
        if not isinstance(op, str) or op == "abstract":
            continue
        reason = find_suppression(src, [node.lineno], "wire")
        out.append(_RequestClass(
            name=node.name, op=op, lineno=node.lineno,
            waived=reason is not None, waive_reasonless=reason == ""))
    return out


def _union_members(src: Source, union_name: str) -> Set[str]:
    """Names inside ``AnyRequest = Union[...]``."""
    for node in src.tree.body:
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == union_name
                        for t in node.targets) \
                and isinstance(node.value, ast.Subscript):
            sl = node.value.slice
            elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
            return {e.id for e in elts if isinstance(e, ast.Name)}
    return set()


def _rq_refs(node: ast.AST) -> Set[str]:
    """Every ``rq.<Name>`` referenced under this node."""
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) \
                and isinstance(sub.value, ast.Name) and sub.value.id == "rq":
            out.add(sub.attr)
    return out


def _handler_keys(src: Source) -> Set[str]:
    """Keys of the ``_HANDLERS`` dict literal in the service module."""
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        if any(isinstance(t, ast.Name) and t.id == "_HANDLERS"
               for t in targets) \
                and isinstance(node.value, ast.Dict):
            out = set()
            for key in node.value.keys:
                if key is not None:
                    out |= _rq_refs(key)
            return out
    return set()


def _routes(src: Source) -> List[Tuple[str, Set[str]]]:
    """(pattern, request classes built) per ``@_route``-decorated builder."""
    out = []
    for node in src.tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        patterns = []
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and isinstance(dec.func, ast.Name) \
                    and dec.func.id == "_route" and len(dec.args) >= 2 \
                    and isinstance(dec.args[1], ast.Constant):
                patterns.append(dec.args[1].value)
        if not patterns:
            continue
        refs = _rq_refs(node)
        for pattern in patterns:
            out.append((pattern, refs))
    return out


def _route_discriminator(pattern: str) -> str:
    """The last static path chunk of a route regex — the string a client
    implementation cannot avoid spelling to reach the route."""
    static = pattern.strip("^$")
    parts = [p for p in _GROUP_RE.split(static) if p]
    return parts[-1] if parts else static


def _string_literals(src: Source) -> str:
    """All string constants in a module (f-string static parts included),
    concatenated for substring search."""
    chunks = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            chunks.append(node.value)
    return "\n".join(chunks)


def check_wire_protocol(paths: WirePaths) -> List[Violation]:
    """Cross-reference the four request-plane legs."""
    violations: List[Violation] = []
    rq_src = Source.load(paths.requests_py)
    service_src = Source.load(paths.service_py)
    http_src = Source.load(paths.http_py)
    client_src = Source.load(paths.client_py)

    classes = _request_classes(rq_src)
    union = _union_members(rq_src, "AnyRequest")
    handlers = _handler_keys(service_src)
    routes = _routes(http_src)
    routed: Dict[str, List[str]] = {}
    for pattern, refs in routes:
        for ref in refs:
            routed.setdefault(ref, []).append(pattern)
    client_strings = _string_literals(client_src)

    for cls in classes:
        if cls.waive_reasonless:
            violations.append(Violation(
                "WIRE005", rq_src.path, cls.lineno,
                f"'# wire-ok:' on {cls.name} needs a reason"))
        if union and cls.name not in union:
            violations.append(Violation(
                "WIRE001", rq_src.path, cls.lineno,
                f"request {cls.name} (op={cls.op!r}) is missing from the "
                f"AnyRequest union"))
        if cls.name not in handlers:
            violations.append(Violation(
                "WIRE002", service_src.path, 1,
                f"request {cls.name} (op={cls.op!r}) has no "
                f"QuantixarService._HANDLERS entry"))
        if cls.waived:
            continue
        patterns = routed.get(cls.name)
        if not patterns:
            violations.append(Violation(
                "WIRE003", http_src.path, 1,
                f"request {cls.name} (op={cls.op!r}) is never built by an "
                f"HTTP route"))
            continue
        if not any(_route_discriminator(p) in client_strings
                   for p in patterns):
            discs = sorted({_route_discriminator(p) for p in patterns})
            violations.append(Violation(
                "WIRE004", client_src.path, 1,
                f"no client call references route path {discs} for request "
                f"{cls.name} (op={cls.op!r})"))
    return sort_violations(violations)
