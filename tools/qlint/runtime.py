"""Runtime twin of the static lock checker: instrumented locks.

:class:`TracedRLock` is a drop-in ``threading.RLock`` replacement that
feeds a process-wide :class:`LockMonitor`:

  * **lock-order graph** — every time a thread acquires lock B while
    already holding lock A, the monitor records the edge A→B.  A cycle in
    this graph (A→B somewhere, B→A somewhere else) is a potential
    deadlock even if the schedules never collided in this run; the fuzz
    test fails on any cycle.
  * **live wait-for detection** — before blocking, an acquirer publishes
    "waiting for L"; if the owner chain of L leads back to the acquirer,
    :class:`DeadlockDetected` is raised instead of hanging the test.
  * **stall accounting** — holds or waits longer than ``stall_after``
    seconds are recorded (never raised: CI machines wobble) so stress
    tests can print the worst offenders.

Re-entrant acquires (depth > 0) are bookkeeping-only: they cannot change
the order graph or block, matching RLock semantics.

Usage::

    monitor = LockMonitor()
    col._lock = TracedRLock("collection", monitor)   # or instrument_collection
    ... hammer from threads ...
    monitor.assert_no_cycles()

The monitor's own ``_mu`` is a plain lock held only for short critical
sections and never while blocking on a user lock, so the instrumentation
cannot itself deadlock.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Set, Tuple


class DeadlockDetected(RuntimeError):
    """A blocking acquire would complete a wait-for cycle."""


@dataclasses.dataclass(frozen=True)
class Stall:
    """One hold/wait that exceeded the monitor's stall threshold."""

    kind: str          # "hold" | "wait"
    lock: str
    thread: str
    seconds: float


class LockMonitor:
    """Process-wide collector for a family of :class:`TracedRLock`."""

    def __init__(self, stall_after: float = 1.0):
        self.stall_after = stall_after
        self._mu = threading.Lock()
        # order edges: (held.name, acquired.name) -> first witness
        self._edges: Dict[Tuple[str, str], str] = {}
        # live state, keyed by thread ident / lock name
        self._holding: Dict[int, List[str]] = {}
        self._waiting: Dict[int, str] = {}
        self._owner: Dict[str, int] = {}
        self._stalls: List[Stall] = []
        self._acquires = 0

    # ------------------------------------------------------------ lock events
    def on_wait(self, lock: str, reentrant: bool) -> None:
        """Called before a (possibly) blocking acquire."""
        me = threading.get_ident()
        if reentrant:
            return
        with self._mu:
            held = list(self._holding.get(me, ()))
            for h in held:
                self._edges.setdefault(
                    (h, lock), threading.current_thread().name)
            self._check_wait_cycle(me, lock)
            self._waiting[me] = lock

    def on_acquired(self, lock: str, reentrant: bool,
                    waited: float) -> None:
        me = threading.get_ident()
        if reentrant:
            return
        with self._mu:
            self._acquires += 1
            self._waiting.pop(me, None)
            self._owner[lock] = me
            self._holding.setdefault(me, []).append(lock)
            if waited >= self.stall_after:
                self._stalls.append(Stall(
                    "wait", lock, threading.current_thread().name, waited))

    def on_released(self, lock: str, reentrant: bool, held: float) -> None:
        me = threading.get_ident()
        if reentrant:
            return
        with self._mu:
            stack = self._holding.get(me, [])
            if lock in stack:
                stack.remove(lock)
            if self._owner.get(lock) == me:
                del self._owner[lock]
            if held >= self.stall_after:
                self._stalls.append(Stall(
                    "hold", lock, threading.current_thread().name, held))

    def _check_wait_cycle(self, me: int, lock: str) -> None:
        """Follow owner->waiting links from `lock`; raise if they reach me.
        Caller holds self._mu."""
        seen: Set[str] = set()
        current: Optional[str] = lock
        chain = [lock]
        while current is not None and current not in seen:
            seen.add(current)
            owner = self._owner.get(current)
            if owner is None:
                return                      # unowned: we will get it
            if owner == me:
                raise DeadlockDetected(
                    "wait-for cycle: " + " -> ".join(chain)
                    + f" -> (held by requester {chain[0]!r} waiter)")
            current = self._waiting.get(owner)
            if current is not None:
                chain.append(current)

    # -------------------------------------------------------------- reporting
    def order_edges(self) -> Dict[Tuple[str, str], str]:
        with self._mu:
            return dict(self._edges)

    def order_cycles(self) -> List[List[str]]:
        """Cycles in the lock-order graph (each as the node list)."""
        edges = self.order_edges()
        graph: Dict[str, Set[str]] = {}
        for (a, b) in edges:
            graph.setdefault(a, set()).add(b)
        cycles: List[List[str]] = []
        # DFS with an explicit path; the graphs here are tiny (a handful of
        # named locks) so simplicity beats asymptotics
        def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
            for nxt in sorted(graph.get(node, ())):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    if not any(set(c) == set(cyc) for c in cycles):
                        cycles.append(cyc)
                    continue
                dfs(nxt, path + [nxt], on_path | {nxt})
        for start in sorted(graph):
            dfs(start, [start], {start})
        return cycles

    def stalls(self) -> List[Stall]:
        with self._mu:
            return list(self._stalls)

    @property
    def acquires(self) -> int:
        with self._mu:
            return self._acquires

    def assert_no_cycles(self) -> None:
        cycles = self.order_cycles()
        if cycles:
            lines = [" -> ".join(c) for c in cycles]
            raise AssertionError(
                "lock-order cycles (potential deadlocks):\n  "
                + "\n  ".join(lines))

    def report(self) -> str:
        edges = self.order_edges()
        parts = [f"{self.acquires} traced acquires",
                 f"{len(edges)} order edges"]
        for (a, b), witness in sorted(edges.items()):
            parts.append(f"  {a} -> {b}   (first: {witness})")
        for s in self.stalls():
            parts.append(f"  stall: {s.kind} {s.lock} by {s.thread} "
                         f"{s.seconds:.3f}s")
        return "\n".join(parts)


class TracedRLock:
    """``threading.RLock`` work-alike reporting to a :class:`LockMonitor`."""

    def __init__(self, name: str, monitor: LockMonitor):
        self.name = name
        self.monitor = monitor
        self._inner = threading.RLock()
        self._depth: Dict[int, int] = {}     # per-thread recursion depth
        self._since: Dict[int, float] = {}   # outermost-acquire timestamp

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        reentrant = self._depth.get(me, 0) > 0
        self.monitor.on_wait(self.name, reentrant)
        t0 = time.monotonic()
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._depth[me] = self._depth.get(me, 0) + 1
            if not reentrant:
                self._since[me] = time.monotonic()
            self.monitor.on_acquired(self.name, reentrant,
                                     time.monotonic() - t0)
        elif not reentrant:
            # failed try-acquire: clear the published wait
            self.monitor.on_acquired(self.name, False, 0.0)
            self.monitor.on_released(self.name, False, 0.0)
        return ok

    def release(self) -> None:
        me = threading.get_ident()
        depth = self._depth.get(me, 0)
        if depth <= 0:
            raise RuntimeError("release of un-acquired TracedRLock "
                               + self.name)
        self._depth[me] = depth - 1
        outermost = depth == 1
        held = time.monotonic() - self._since.pop(me, time.monotonic()) \
            if outermost else 0.0
        self._inner.release()
        self.monitor.on_released(self.name, not outermost, held)

    def __enter__(self) -> "TracedRLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


def instrument_collection(col, monitor: LockMonitor) -> None:
    """Swap a Collection's locks (and its batcher's) for traced ones.

    Call before any concurrent traffic.  Touching ``col.batcher`` first
    forces the worker to exist while it is still idle-parked on its queue,
    so swapping ``_state_lock`` is safe.
    """
    name = getattr(col, "name", "collection")
    col._lock = TracedRLock(f"{name}._lock", monitor)
    col._batcher_init_lock = TracedRLock(
        f"{name}._batcher_init_lock", monitor)
    batcher = col.batcher
    if batcher is not None:
        batcher._state_lock = TracedRLock(
            f"{name}.batcher._state_lock", monitor)
