"""Shared analyzer plumbing: violations, source loading, suppression.

Every analyzer reports :class:`Violation` records formatted as
``path:line: RULE message`` so editors and CI logs can jump straight to
the offending line.  Suppression is always explicit and always carries a
reason — bare escape hatches are themselves violations.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Violation:
    """One analyzer finding, pinned to a file and line."""

    rule: str          # e.g. "LOCK001"
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class Source:
    """A parsed module: AST + physical lines (for comment conventions)."""

    path: str
    text: str
    lines: List[str]
    tree: ast.Module

    @classmethod
    def load(cls, path: str) -> "Source":
        with open(path, "r", encoding="utf-8") as f:
            text = f.read()
        return cls(path=path, text=text, lines=text.splitlines(),
                   tree=ast.parse(text, filename=path))

    def line(self, lineno: int) -> str:
        """1-indexed physical line ('' when out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def span_lines(self, node: ast.AST) -> range:
        """1-indexed line range a node covers."""
        end = getattr(node, "end_lineno", None) or node.lineno
        return range(node.lineno, end + 1)


# One escape-hatch grammar shared by every analyzer: the marker word names
# the analyzer, the reason is mandatory.
#   # unguarded-ok: <reason>   (locks)
#   # pallas-ok: <reason>      (jax/pallas hygiene)
#   # wire-ok: <reason>        (wire exhaustiveness)
_SUPPRESS_RES: Dict[str, "re.Pattern[str]"] = {
    marker: re.compile(rf"#\s*{marker}-ok:(.*)$")
    for marker in ("unguarded", "pallas", "wire")
}


def suppression(line: str, marker: str) -> Optional[str]:
    """Returns the escape-hatch reason on this line, '' when the hatch is
    present but reasonless, or None when there is no hatch at all."""
    m = _SUPPRESS_RES[marker].search(line)
    if m is None:
        return None
    return m.group(1).strip()


def find_suppression(src: Source, linenos: Sequence[int],
                     marker: str) -> Optional[str]:
    """First escape hatch found on any of the given lines (see
    :func:`suppression` for the return convention)."""
    for n in linenos:
        reason = suppression(src.line(n), marker)
        if reason is not None:
            return reason
    return None


def signature_lines(fn: ast.AST) -> range:
    """Lines spanned by a def's signature (decorators excluded): where
    method-level markers like ``# requires-lock:`` live."""
    first_body = fn.body[0].lineno if getattr(fn, "body", None) else fn.lineno
    return range(fn.lineno, first_body + 1)


def sort_violations(violations: List[Violation]) -> List[Violation]:
    return sorted(violations, key=lambda v: (v.path, v.line, v.rule))


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute(Name('jax'), 'jit'), 'jit' for Name('jit')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr(node: ast.AST) -> Optional[str]:
    """'x' when node is ``self.x``, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def const_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """A tuple/list of string constants (e.g. static_argnames), else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return tuple(out)
    return None
