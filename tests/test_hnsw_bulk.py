"""Device-parallel bulk HNSW builder: invariants, recall parity vs the
incremental builder at equal ef, determinism, engine/collection wiring.

The contract under test (ISSUE 9): `bulk_build_device` produces a
`PackedHNSW` interchangeable with the incremental builder's — same graph
invariants (degree caps, no self-loops/dups, navigable base layer), search
recall within 0.02 of incremental at equal ef — while building in batched
device phases instead of one-at-a-time inserts.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HNSWConfig, bulk_build_device, exact_knn, recall_at_k
from repro.core.engine import EngineConfig, QuantixarEngine
from repro.core.hnsw_build import (PAD, build as incremental_build,
                                   knn_ids_dists, preprocess_vectors)
from repro.core.hnsw_bulk import MIN_DEVICE_N, _bfs_reachable
from repro.core.hnsw_search import search, to_device
from repro.data.synthetic import gaussian_mixture

N, DIM = 1200, 24
K = 10

# small coarse_cluster so the coarse mode actually multi-clusters at N=1200
LEVEL_CFG = dict(bulk_mode="level", build_batch=256)
COARSE_CFG = dict(bulk_mode="coarse", coarse_cluster=300)


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=20, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(40, DIM, n_clusters=20, scale=0.2, seed=9)


@pytest.fixture(scope="module", params=["level", "coarse"])
def packed(request, corpus):
    kw = LEVEL_CFG if request.param == "level" else COARSE_CFG
    return bulk_build_device(
        corpus, HNSWConfig(M=12, metric="cosine", seed=0, **kw))


def _search_recall(packed, corpus, queries, metric, ef=64):
    g, max_level, dev_metric = to_device(packed)
    qn = preprocess_vectors(queries, metric)
    _, ids = search(g, jnp.asarray(qn), k=K, ef=ef, max_level=max_level,
                    metric=dev_metric)
    gt = exact_knn(queries, corpus, K, metric=metric)
    return recall_at_k(np.asarray(ids), gt)


class TestGraphInvariants:
    """Parametrized over both bulk modes via the `packed` fixture."""

    def test_degrees_bounded(self, packed):
        assert (packed.adj0 != PAD).sum(1).max() <= packed.config.m0
        assert (packed.upper_adj != PAD).sum(-1).max() <= packed.config.M

    def test_no_duplicate_neighbours(self, packed):
        """Required by the device search's scatter-add visited trick."""
        for row in packed.adj0:
            real = row[row != PAD]
            assert len(set(real.tolist())) == len(real)

    def test_no_self_loops(self, packed):
        for i, row in enumerate(packed.adj0):
            assert i not in row[row != PAD]

    def test_neighbour_ids_in_range(self, packed):
        real = packed.adj0[packed.adj0 != PAD]
        assert real.min() >= 0 and real.max() < packed.n

    def test_entry_point_valid(self, packed):
        assert 0 <= packed.entry_global < packed.n
        assert packed.levels[packed.entry_global] == packed.max_level

    def test_connected_at_base(self, packed):
        """Post-repair the base layer must be >=99% reachable from entry."""
        seen = _bfs_reachable(packed.adj0, packed.entry_global)
        assert seen.mean() >= 0.99

    def test_level_distribution_geometric(self, packed):
        share_upper = (packed.levels >= 1).mean()
        assert 0.02 < share_upper < 0.25   # ~1/M ± slack

    def test_build_info_populated(self, packed):
        info = packed.build_info
        assert info["builder_mode"] in ("level", "coarse")
        assert info["build_repaired"] >= 0


class TestRecallParity:
    """Bulk recall within 0.02 of incremental at equal ef (the ISSUE gate)."""

    @pytest.mark.parametrize("metric", ["l2", "cosine"])
    def test_vs_incremental(self, corpus, queries, metric):
        """Default auto config (what `builder="bulk"` users get)."""
        cfg = dict(M=12, ef_construction=80, metric=metric, seed=0)
        inc = incremental_build(corpus, HNSWConfig(**cfg))
        blk = bulk_build_device(corpus, HNSWConfig(**cfg))
        r_inc = _search_recall(inc, corpus, queries, metric)
        r_blk = _search_recall(blk, corpus, queries, metric)
        assert r_blk >= r_inc - 0.02, (r_blk, r_inc)

    @pytest.mark.parametrize("kw", [LEVEL_CFG, COARSE_CFG],
                             ids=["level", "coarse"])
    def test_forced_mode_recall_floor(self, corpus, queries, kw):
        blk = bulk_build_device(
            corpus, HNSWConfig(M=12, metric="cosine", seed=0, **kw))
        assert _search_recall(blk, corpus, queries, "cosine") > 0.9


class TestDeterminism:
    @pytest.mark.parametrize("kw", [LEVEL_CFG, COARSE_CFG],
                             ids=["level", "coarse"])
    def test_same_seed_same_graph(self, corpus, kw):
        cfg = HNSWConfig(M=12, metric="l2", seed=3, **kw)
        a = bulk_build_device(corpus, cfg)
        b = bulk_build_device(corpus, cfg)
        assert (a.adj0 == b.adj0).all()
        assert (a.levels == b.levels).all()
        assert (a.upper_adj == b.upper_adj).all()
        assert a.entry_global == b.entry_global


class TestModeSelection:
    def test_auto_picks_coarse_above_threshold(self, corpus):
        p = bulk_build_device(
            corpus, HNSWConfig(M=12, seed=0, coarse_threshold=1000,
                               coarse_cluster=300))
        assert p.build_info["builder_mode"] == "coarse"
        assert p.build_info["build_clusters"] >= 2

    def test_auto_picks_level_below_threshold(self, corpus):
        p = bulk_build_device(
            corpus[:400], HNSWConfig(M=12, seed=0, coarse_threshold=1000,
                                     build_batch=128))
        assert p.build_info["builder_mode"] == "level"
        assert p.build_info["build_batches"] >= 2

    def test_tiny_corpus_falls_back_to_reference(self, corpus):
        tiny = corpus[:MIN_DEVICE_N - 2]
        p = bulk_build_device(tiny, HNSWConfig(M=8, seed=0))
        assert p.build_info["builder_mode"] == "ref_small_n"
        assert p.n == len(tiny)

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError):
            HNSWConfig(bulk_mode="turbo")


class TestProgressCallback:
    @pytest.mark.parametrize("kw", [LEVEL_CFG, COARSE_CFG],
                             ids=["level", "coarse"])
    def test_phases_reported_monotone(self, corpus, kw):
        calls = []
        bulk_build_device(corpus, HNSWConfig(M=12, seed=0, **kw),
                          progress=lambda *a: calls.append(a))
        assert calls, "progress callback never fired"
        for phase, done, total in calls:
            assert isinstance(phase, str) and 0 <= done <= total
        per_phase = {}
        for phase, done, _ in calls:
            assert done >= per_phase.get(phase, 0)   # monotone within phase
            per_phase[phase] = done

    def test_incremental_build_progress(self, corpus):
        calls = []
        incremental_build(corpus[:300],
                          HNSWConfig(M=8, ef_construction=40, seed=0),
                          progress=lambda *a: calls.append(a))
        assert calls and calls[-1][1] == 300


class TestChunkedExactKnn:
    """`knn_ids_dists` must be exact regardless of chunking (the fix for
    the seed builder's O(n^2)-memory self-join)."""

    def test_matches_unchunked(self):
        rng = np.random.RandomState(5)
        q = rng.randn(70, 16).astype(np.float32)
        x = rng.randn(450, 16).astype(np.float32)
        ref_ids, ref_d = knn_ids_dists(q, x, 9, metric="l2",
                                       chunk=4096, corpus_chunk=10 ** 9)
        for chunk, cchunk in [(16, 64), (70, 33), (7, 450), (70, 1)]:
            ids, d = knn_ids_dists(q, x, 9, metric="l2", chunk=chunk,
                                   corpus_chunk=cchunk)
            np.testing.assert_allclose(d, ref_d, rtol=1e-5, atol=1e-5)
            assert (ids == ref_ids).mean() > 0.999  # ties may reorder

    def test_dot_metric(self):
        rng = np.random.RandomState(6)
        q = rng.randn(20, 8).astype(np.float32)
        x = rng.randn(100, 8).astype(np.float32)
        ids, d = knn_ids_dists(q, x, 5, metric="dot", chunk=8,
                               corpus_chunk=17)
        want = -(q @ x.T)
        np.testing.assert_allclose(
            d, np.sort(want, axis=1)[:, :5], rtol=1e-5, atol=1e-5)
        assert (np.take_along_axis(want, ids, axis=1)
                == np.sort(want, axis=1)[:, :5]).all()


class TestEngineWiring:
    @pytest.mark.parametrize("quant", ["none", "pq", "bq"])
    def test_bulk_builder_with_quantization(self, corpus, queries, quant):
        from repro.core.pq import PQConfig
        eng = QuantixarEngine(EngineConfig(
            dim=DIM, metric="cosine", quantization=quant, builder="bulk",
            pq=PQConfig(m=8),
            hnsw=HNSWConfig(M=12, seed=0, **COARSE_CFG)))
        eng.add(corpus)
        eng.build()
        d, ids = eng.search(queries, k=K)
        gt = exact_knn(queries, corpus, K, metric="cosine")
        floor = 0.9 if quant == "none" else 0.7
        assert recall_at_k(np.asarray(ids), gt) > floor
        st = eng.stats()
        assert st["builder"] == "bulk"
        assert st["builder_mode"] == "coarse"

    def test_bulk_ref_builder_selectable(self, corpus, queries):
        eng = QuantixarEngine(EngineConfig(
            dim=DIM, metric="cosine", builder="bulk_ref",
            hnsw=HNSWConfig(M=12, seed=0)))
        eng.add(corpus[:300])
        eng.build()
        _, ids = eng.search(queries, k=5)
        gt = exact_knn(queries, corpus[:300], 5, metric="cosine")
        assert recall_at_k(np.asarray(ids), gt) > 0.85

    def test_invalid_builder_rejected(self):
        with pytest.raises(ValueError):
            EngineConfig(dim=8, builder="magic")

    def test_build_progress_threaded(self, corpus):
        phases = []
        eng = QuantixarEngine(EngineConfig(
            dim=DIM, builder="bulk",
            hnsw=HNSWConfig(M=12, seed=0, **COARSE_CFG)))
        eng.add(corpus)
        eng.build(progress=lambda ph, d, t: phases.append(ph))
        assert phases, "Engine.build() dropped the progress callback"


class TestCollectionCompact:
    def test_compact_rebuilds_through_bulk(self, corpus, queries):
        from repro.api import Collection, CollectionSchema, VectorField
        col = Collection(CollectionSchema(
            name="bulk-compact",
            vector=VectorField(dim=DIM, metric="cosine", builder="bulk",
                               hnsw=HNSWConfig(M=12, seed=0, **COARSE_CFG))))
        try:
            ids = [f"e{i}" for i in range(N)]
            col.upsert(ids, corpus)
            col.delete(ids[::10])
            assert col.tombstones == len(ids[::10])
            reclaimed = col.compact()
            assert reclaimed == len(ids[::10])
            assert col.tombstones == 0
            d, rows = col.search(queries[:4], k=5)
            assert rows.shape == (4, 5) and (rows >= 0).all()
            # stats after the (lazy) rebuild expose the bulk build_info
            st = col.stats()
            assert st["builder"] == "bulk"
            assert st["builder_mode"] == "coarse"
        finally:
            col.close()
