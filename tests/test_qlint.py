"""qlint analyzer tests: every rule fires on a known-bad fixture, every
escape hatch suppresses, and the repo itself stays clean.

Each fixture is a deliberately broken snippet written to tmp_path; the
assertion is always (rule id, file, line) so a rule that silently stops
firing — or fires on the wrong line — fails loudly here.
"""

import os
import textwrap

from tools.qlint import (check_jax_hygiene, check_kernel_registry,
                         check_lock_discipline, check_wire_protocol)
from tools.qlint.cli import main as qlint_main
from tools.qlint.wire import WirePaths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write(tmp_path, name, text):
    path = tmp_path / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(text))
    return str(path)


def _rules(violations):
    return [v.rule for v in violations]


def _by_rule(violations, rule):
    return [v for v in violations if v.rule == rule]


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------

class TestLockRules:
    def test_lock001_unguarded_access_fires_with_line(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._items = []   # guarded-by: _lock

                def size(self):
                    return len(self._items)
            """)
        out = check_lock_discipline([path])
        assert _rules(out) == ["LOCK001"]
        assert out[0].path == path and out[0].line == 9
        assert "_items" in out[0].message and "_lock" in out[0].message

    def test_lock001_write_outside_lock_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0   # guarded-by: _lock

                def bump(self):
                    self._n += 1
            """)
        assert _rules(check_lock_discipline([path])) == ["LOCK001"]

    def test_with_lock_satisfies(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._items = []   # guarded-by: _lock

                def size(self):
                    with self._lock:
                        return len(self._items)
            """)
        assert check_lock_discipline([path]) == []

    def test_any_of_multiple_locks(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._a = threading.Lock()
                    self._b = threading.Lock()
                    self._flag = False   # guarded-by: _a|_b

                def via_b(self):
                    with self._b:
                        return self._flag
            """)
        assert check_lock_discipline([path]) == []

    def test_requires_lock_contract_trusted(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._items = []   # guarded-by: _lock

                def _drain(self):   # requires-lock: _lock
                    self._items.clear()
            """)
        assert check_lock_discipline([path]) == []

    def test_unguarded_ok_with_reason_suppresses(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0   # guarded-by: _lock

                def peek(self):
                    return self._n  # unguarded-ok: racy stat read is fine
            """)
        assert check_lock_discipline([path]) == []

    def test_lock003_reasonless_hatch_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0   # guarded-by: _lock

                def peek(self):
                    return self._n  # unguarded-ok:
            """)
        out = check_lock_discipline([path])
        assert _rules(out) == ["LOCK003"]

    def test_lock002_nonexistent_lock_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            class Store:
                def __init__(self):
                    self._n = 0   # guarded-by: _mutex
            """)
        out = check_lock_discipline([path])
        assert "LOCK002" in _rules(out)
        assert "_mutex" in _by_rule(out, "LOCK002")[0].message

    def test_lock004_annotation_outside_class_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import threading
            _lock = threading.Lock()
            COUNTER = 0   # guarded-by: _lock
            """)
        out = check_lock_discipline([path])
        assert _rules(out) == ["LOCK004"] and out[0].line == 3

    def test_init_is_exempt(self, tmp_path):
        # __init__ publishes the object; pre-publication writes are safe
        path = _write(tmp_path, "good.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0   # guarded-by: _lock
                    self._n = 1
            """)
        assert check_lock_discipline([path]) == []


# ---------------------------------------------------------------------------
# wire-protocol exhaustiveness
# ---------------------------------------------------------------------------

_WIRE_REQUESTS = """\
from typing import Union

class Request:
    op = "abstract"

class Ping(Request):
    op = "ping"

class Flush(Request):{flush_comment}
    op = "flush"

AnyRequest = Union[{union}]
"""

_WIRE_SERVICE = """\
from . import requests as rq

class Service:
    def _ping(self, req):
        return "pong"

    def _flush(self, req):
        return "ok"

    _HANDLERS = {{
        {handlers}
    }}
"""

_WIRE_HTTP = """\
from . import requests as rq

def _route(method, pattern):
    def deco(fn):
        return fn
    return deco

@_route("POST", r"^/v1/ping$")
def _r_ping(body):
    return rq.Ping()
{flush_route}
"""

_WIRE_CLIENT = """\
class Client:
    def ping(self):
        return self._post("/v1/ping")
{flush_call}
"""


def _wire_fixture(tmp_path, *, union="Ping, Flush",
                  handlers='rq.Ping: Service._ping, rq.Flush: Service._flush',
                  flush_route="""
    @_route("POST", r"^/v1/flush$")
    def _r_flush(body):
        return rq.Flush()
    """,
                  flush_call="""
    def flush(self):
        return self._post("/v1/flush")
    """,
                  flush_comment=""):
    return WirePaths(
        requests_py=_write(tmp_path, "requests.py", _WIRE_REQUESTS.format(
            union=union, flush_comment=flush_comment)),
        service_py=_write(tmp_path, "service.py", _WIRE_SERVICE.format(
            handlers=handlers)),
        http_py=_write(tmp_path, "http.py", _WIRE_HTTP.format(
            flush_route=textwrap.dedent(flush_route))),
        client_py=_write(tmp_path, "client.py", _WIRE_CLIENT.format(
            flush_call=textwrap.indent(textwrap.dedent(flush_call), "    "))),
    )


class TestWireRules:
    def test_complete_protocol_is_clean(self, tmp_path):
        assert check_wire_protocol(_wire_fixture(tmp_path)) == []

    def test_wire001_missing_from_union(self, tmp_path):
        paths = _wire_fixture(tmp_path, union="Ping")
        out = check_wire_protocol(paths)
        assert _rules(out) == ["WIRE001"]
        assert "Flush" in out[0].message and out[0].path == paths.requests_py

    def test_wire002_missing_handler(self, tmp_path):
        paths = _wire_fixture(tmp_path, handlers="rq.Ping: Service._ping,")
        out = check_wire_protocol(paths)
        assert _rules(out) == ["WIRE002"]
        assert "Flush" in out[0].message and out[0].path == paths.service_py

    def test_wire003_missing_route(self, tmp_path):
        paths = _wire_fixture(tmp_path, flush_route="")
        out = check_wire_protocol(paths)
        assert _rules(out) == ["WIRE003"]
        assert "Flush" in out[0].message

    def test_wire004_client_never_calls_route(self, tmp_path):
        paths = _wire_fixture(tmp_path, flush_call="")
        out = check_wire_protocol(paths)
        assert _rules(out) == ["WIRE004"]
        assert "/v1/flush" in out[0].message

    def test_wire_ok_waives_http_and_client_legs(self, tmp_path):
        paths = _wire_fixture(
            tmp_path, flush_route="", flush_call="",
            flush_comment="  # wire-ok: rpc-only op, no REST surface")
        assert check_wire_protocol(paths) == []

    def test_wire005_reasonless_waiver_fires(self, tmp_path):
        paths = _wire_fixture(
            tmp_path, flush_route="", flush_call="",
            flush_comment="  # wire-ok:")
        out = check_wire_protocol(paths)
        assert "WIRE005" in _rules(out)

    def test_wire_ok_still_requires_handler(self, tmp_path):
        # the waiver only covers transport legs, not the dispatch table
        paths = _wire_fixture(
            tmp_path, handlers="rq.Ping: Service._ping,",
            flush_route="", flush_call="",
            flush_comment="  # wire-ok: rpc-only op")
        assert _rules(check_wire_protocol(paths)) == ["WIRE002"]


# ---------------------------------------------------------------------------
# jax/pallas hygiene
# ---------------------------------------------------------------------------

class TestJaxRules:
    def test_pal001_float_on_traced_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import jax

            @jax.jit
            def f(x):
                return float(x)
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL001"] and out[0].line == 5

    def test_pal001_item_and_numpy_fire(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                y = x.item()
                return np.sum(x)
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL001", "PAL001"]

    def test_pal002_branch_on_traced_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL002"] and out[0].line == 5

    def test_pal002_loop_over_traced_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import jax

            @jax.jit
            def f(x):
                total = 0
                for v in x:
                    total = total + v
                return total
            """)
        assert _rules(check_jax_hygiene([path])) == ["PAL002"]

    def test_static_args_shape_and_none_checks_are_clean(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import functools
            import jax
            import jax.numpy as jnp

            @functools.partial(jax.jit, static_argnames=("k", "mode"))
            def f(x, mask, k, mode="l2"):
                n, d = x.shape
                if mode == "dot":         # static: fine
                    x = -x
                if mask is not None:      # structural: fine
                    x = jnp.where(mask[:, None], x, jnp.inf)
                if n > 4:                 # shape is static under tracing
                    k = min(k, n)
                return jax.lax.top_k(-x.sum(-1), k)
            """)
        assert check_jax_hygiene([path]) == []

    def test_pallas_kernel_body_checked(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import functools
            import jax
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, blk):
                v = x_ref[...]
                if v.sum() > 0:
                    o_ref[...] = v
                else:
                    o_ref[...] = -v

            def run(x):
                return pl.pallas_call(
                    functools.partial(_k, blk=8),
                    out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL002"] and out[0].line == 7

    def test_pallas_ok_with_reason_suppresses(self, tmp_path):
        path = _write(tmp_path, "good.py", """\
            import jax

            @jax.jit
            def f(x):  # pallas-ok: debug-only helper, never traced in prod
                return float(x)
            """)
        assert check_jax_hygiene([path]) == []

    def test_pallas_ok_reasonless_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import jax

            @jax.jit
            def f(x):  # pallas-ok:
                return float(x)
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL001"]
        assert "needs a reason" in out[0].message

    def test_pal003_mutable_default_on_static_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("tiles",))
            def f(x, tiles=[8, 128]):
                return x
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL003"]

    def test_pal003_unhashable_literal_at_call_site_fires(self, tmp_path):
        path = _write(tmp_path, "bad.py", """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("tiles",))
            def f(x, tiles=(8, 128)):
                return x

            def caller(x):
                return f(x, tiles=[8, 128])
            """)
        out = check_jax_hygiene([path])
        assert _rules(out) == ["PAL003"] and out[0].line == 9

    def test_pal004_kernel_without_ref_or_dispatcher(self, tmp_path):
        kdir = tmp_path / "kernels"
        _write(tmp_path, "kernels/ref.py", """\
            def other_ref(x):
                return x
            """)
        _write(tmp_path, "kernels/ops.py", """\
            from .mykern import my_fused_kernel

            def my_fused(x, *, force_ref=None):
                # references the kernel but there is no *_ref oracle
                return my_fused_kernel(x)
            """)
        _write(tmp_path, "kernels/mykern.py", """\
            def my_fused_kernel(x):
                return x
            """)
        out = check_kernel_registry(str(kdir))
        assert _rules(out) == ["PAL004"]
        assert "my_fused*_ref" in out[0].message

    def test_pal004_missing_dispatcher(self, tmp_path):
        kdir = tmp_path / "kernels"
        _write(tmp_path, "kernels/ref.py", """\
            def my_fused_ref(x):
                return x
            """)
        _write(tmp_path, "kernels/ops.py", """\
            def unrelated(x):
                return x
            """)
        _write(tmp_path, "kernels/mykern.py", """\
            def my_fused_kernel(x):
                return x
            """)
        out = check_kernel_registry(str(kdir))
        assert _rules(out) == ["PAL004"]
        assert "force_ref dispatcher" in out[0].message

    def test_pal004_complete_registry_is_clean(self, tmp_path):
        kdir = tmp_path / "kernels"
        _write(tmp_path, "kernels/ref.py", """\
            def my_fused_ref(x):
                return x
            """)
        _write(tmp_path, "kernels/ops.py", """\
            from . import ref
            from .mykern import my_fused_kernel

            def my_fused(x, *, force_ref=None):
                if force_ref:
                    return ref.my_fused_ref(x)
                return my_fused_kernel(x)
            """)
        _write(tmp_path, "kernels/mykern.py", """\
            def my_fused_kernel(x):
                return x
            """)
        assert check_kernel_registry(str(kdir)) == []


# ---------------------------------------------------------------------------
# the repo itself + CLI plumbing
# ---------------------------------------------------------------------------

class TestRepoIsClean:
    def test_full_repo_run_is_clean(self, capsys):
        rc = qlint_main(["--root", REPO])
        captured = capsys.readouterr()
        assert rc == 0, f"qlint found violations:\n{captured.out}"
        assert "clean" in captured.err

    def test_cli_exit_code_counts_violations(self, tmp_path, capsys):
        bad = _write(tmp_path, "bad.py", """\
            import threading

            class Store:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._n = 0   # guarded-by: _lock

                def peek(self):
                    return self._n
            """)
        rc = qlint_main(["--root", REPO, "--only", "locks", bad])
        captured = capsys.readouterr()
        assert rc == 1
        assert "LOCK001" in captured.out and ":9:" in captured.out
