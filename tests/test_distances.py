"""Metric registry correctness + invariance properties."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # deterministic shim keeps properties runnable
    from _hypothesis_fallback import given, settings, st

from repro.core import distances as D

RNG = np.random.RandomState(0)


def _rand(n, d, seed=0):
    return np.random.RandomState(seed).randn(n, d).astype(np.float32)


class TestMetrics:
    def test_l2_matches_numpy(self):
        q, x = _rand(7, 33, 1), _rand(19, 33, 2)
        got = np.asarray(D.pairwise_l2(jnp.asarray(q), jnp.asarray(x)))
        want = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_cosine_range_and_self_distance(self):
        x = _rand(11, 16)
        d = np.asarray(D.pairwise_cosine(jnp.asarray(x), jnp.asarray(x)))
        assert (d > -1e-5).all() and (d < 2 + 1e-5).all()
        np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-5)

    def test_dot_is_negative_inner_product(self):
        q, x = _rand(3, 8), _rand(5, 8)
        got = np.asarray(D.pairwise_dot(jnp.asarray(q), jnp.asarray(x)))
        np.testing.assert_allclose(got, -(q @ x.T), rtol=1e-5, atol=1e-5)

    def test_hamming_exact(self):
        q = np.array([[0b1011, 0b0001]], dtype=np.uint32)
        x = np.array([[0b1000, 0b0001], [0b0100, 0b0000]], dtype=np.uint32)
        d = np.asarray(D.pairwise_hamming(jnp.asarray(q), jnp.asarray(x)))
        # q^x0 = [0b0011, 0b0000] -> 2 bits; q^x1 = [0b1111, 0b0001] -> 5
        assert d.tolist() == [[2, 5]]

    def test_registry(self):
        assert set(D.available_metrics()) >= {"l2", "cosine", "dot", "hamming"}
        with pytest.raises(ValueError):
            D.get_metric("nope")

    def test_brute_force_topk_sorted_ascending(self):
        q, x = _rand(4, 12), _rand(50, 12)
        d, idx = D.brute_force_topk(jnp.asarray(q), jnp.asarray(x), 5, "l2")
        d = np.asarray(d)
        assert (np.diff(d, axis=1) >= -1e-6).all()
        # indices consistent with distances
        full = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(
            d, np.take_along_axis(full, np.asarray(idx), axis=1),
            rtol=1e-4, atol=1e-4)


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 8), st.integers(2, 24), st.integers(0, 10_000))
    def test_l2_symmetry_and_triangle_of_zero(self, q, d, seed):
        x = np.random.RandomState(seed).randn(q, d).astype(np.float32)
        dist = np.asarray(D.pairwise_l2(jnp.asarray(x), jnp.asarray(x)))
        np.testing.assert_allclose(dist, dist.T, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.diag(dist), 0.0, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_cosine_scale_invariance(self, seed):
        rng = np.random.RandomState(seed)
        q = rng.randn(3, 9).astype(np.float32)
        x = rng.randn(5, 9).astype(np.float32)
        d1 = np.asarray(D.pairwise_cosine(jnp.asarray(q), jnp.asarray(x)))
        d2 = np.asarray(D.pairwise_cosine(jnp.asarray(q * 7.5),
                                          jnp.asarray(x * 0.3)))
        np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_hamming_symmetric_and_bounded(self, seed):
        rng = np.random.RandomState(seed)
        c = rng.randint(0, 2 ** 31, (6, 4)).astype(np.uint32)
        d = np.asarray(D.pairwise_hamming(jnp.asarray(c), jnp.asarray(c)))
        assert (d == d.T).all()
        assert (np.diag(d) == 0).all()
        assert d.max() <= 4 * 32
