"""End-to-end training driver: loss goes down, checkpoint/restart works."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import train


@pytest.mark.slow
def test_train_smoke_loss_decreases(tmp_path):
    cfg = get_smoke_config("qwen2-1.5b")
    out = train(cfg, steps=8, global_batch=4, seq_len=32, lr=5e-3,
                log_every=1)
    losses = [m["loss"] for m in out["metrics"]]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_checkpoint_restart_resumes_step(tmp_path):
    """Kill at step 6, restart, verify resume from the step-4 checkpoint and
    completion — the fault-tolerance contract."""
    cfg = get_smoke_config("qwen2-1.5b")
    ckpt = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="simulated"):
        train(cfg, steps=10, global_batch=4, seq_len=32, ckpt_dir=ckpt,
              checkpoint_every=2, simulate_failure_at=6)
    out = train(cfg, steps=10, global_batch=4, seq_len=32, ckpt_dir=ckpt,
                checkpoint_every=2)
    # resumed: fewer than 10 steps of fresh metrics; run completed
    steps_logged = [m["step"] for m in out["metrics"]]
    assert steps_logged[0] > 1          # did not restart from scratch
    assert steps_logged[-1] == 10


@pytest.mark.slow
def test_enc_dec_driver():
    cfg = get_smoke_config("seamless-m4t-medium")
    out = train(cfg, steps=3, global_batch=2, seq_len=16)
    assert np.isfinite([m["loss"] for m in out["metrics"]]).all()
