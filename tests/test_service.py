"""Service plane: wire protocol, QuantixarService, HTTP server, client.

The core contract: the same CRUD/search/filter scenarios pass embedded
(`Database`) and over the wire (`QuantixarClient` -> live ThreadingHTTPServer
-> `QuantixarService`), single-vector wire searches coalesce through the
`RequestBatcher`, and every error path returns a structured `ErrorInfo` —
never a traceback body.
"""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.api import (And, BatcherConfig, BoolField, Database, KeywordField,
                       Not, NumericField, Predicate, QuantixarClient,
                       SchemaError, TextField, VectorField)
from repro.api import requests as rq
from repro.api.collection import CollectionClosed, QueryRetriesExhausted
from repro.data.synthetic import gaussian_mixture
from repro.serving.http import QuantixarHTTPServer
from repro.serving.service import QuantixarService, ServiceConfig

N, DIM = 400, 24


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=6, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(6, DIM, n_clusters=6, scale=0.2, seed=3)


@pytest.fixture()
def server():
    srv = QuantixarHTTPServer(QuantixarService(Database())).start()
    yield srv
    srv.shutdown()


@pytest.fixture()
def client(server):
    return QuantixarClient(server.url, timeout=30)


@pytest.fixture(params=["embedded", "wire"])
def backend(request, server):
    """Either API entry point; the scenarios below must pass on both."""
    if request.param == "embedded":
        db = Database()
        yield db
        db.close()
    else:
        yield QuantixarClient(server.url, timeout=30)


def _ids(n=N):
    return [f"item-{i}" for i in range(n)]


def _payloads(n=N):
    return [{"category": f"cat-{i % 4}", "price": float(i % 50),
             "in_stock": i % 3 == 0} for i in range(n)]


def _make(backend, corpus, name="items", n=N, batcher=None, shards=1,
          replicas=1, **vector_kw):
    vector_kw.setdefault("dim", DIM)
    vector_kw.setdefault("index", "flat")
    col = backend.create_collection(
        name=name, vector=VectorField(**vector_kw),
        fields=(KeywordField("category"), NumericField("price"),
                BoolField("in_stock")),
        batcher=batcher, shards=shards, replicas=replicas)
    col.upsert(_ids(n), corpus[:n], _payloads(n))
    return col


# ---------------------------------------------------------------- scenarios
# Each test here runs twice: once against Database, once against
# QuantixarClient -> HTTP -> QuantixarService.
class TestBackendParity:
    def test_crud_roundtrip(self, backend, corpus):
        col = _make(backend, corpus)
        e = col.get("item-7")
        assert e.id == "item-7" and e.payload["category"] == "cat-3"
        np.testing.assert_allclose(e.vector, corpus[7])
        assert col.get("missing") is None
        assert "item-7" in col and "missing" not in col

        col.upsert("item-7", corpus[0], [{"category": "cat-0", "price": 1.0}])
        e2 = col.get("item-7")
        np.testing.assert_allclose(e2.vector, corpus[0])
        assert e2.payload["category"] == "cat-0"

        assert col.delete("item-7") == 1
        assert col.delete("item-7") == 0
        assert col.get("item-7") is None
        assert len(col) == N - 1

    def test_filtered_search(self, backend, corpus, queries):
        col = _make(backend, corpus)
        hits = (col.query(queries[0])
                .filter(category="cat-1")
                .where("price", "lt", 30)
                .top_k(5)
                .run())
        assert 0 < len(hits) <= 5
        for h in hits:
            assert h.payload["category"] == "cat-1"
            assert h.payload["price"] < 30
        # full tree (And of predicates) survives the codec
        flt = And((Predicate("category", "eq", "cat-2"),
                   Predicate("in_stock", "eq", True)))
        for h in col.query(queries[1]).filter(flt).top_k(4).run():
            assert h.payload["category"] == "cat-2"
            assert h.payload["in_stock"] is True

    def test_batch_query_and_include_vector(self, backend, corpus, queries):
        col = _make(backend, corpus)
        rows = col.query(queries).top_k(3).run()          # 2-D -> batch
        assert len(rows) == len(queries)
        single = col.query(queries[2]).top_k(3).include("vector").run()
        assert [h.id for h in single] == [h.id for h in rows[2]]
        assert all(h.vector is not None and h.vector.shape == (DIM,)
                   for h in single)

    def test_empty_collection_returns_empty(self, backend, queries):
        col = backend.create_collection(
            name="fresh", vector=VectorField(dim=DIM, index="flat"))
        assert col.query(queries[0]).top_k(5).run() == []
        batch = col.query(queries[:3]).top_k(5).run()
        assert batch == [[], [], []]

    def test_compact_preserves_results(self, backend, corpus, queries):
        col = _make(backend, corpus)
        col.delete([f"item-{i}" for i in range(40)])
        before = [h.id for h in col.query(queries[2]).top_k(10).run()]
        assert col.compact() == 40
        after = [h.id for h in col.query(queries[2]).top_k(10).run()]
        assert after == before

    def test_error_parity(self, backend, corpus, queries):
        col = _make(backend, corpus)
        with pytest.raises(SchemaError):
            col.query(queries[0][:8])                     # wrong dim
        with pytest.raises(SchemaError):
            col.query(queries[0]).filter(unknown=1)       # unknown field
        with pytest.raises(SchemaError):                  # lt on keyword
            col.query(queries[0]).where("category", "lt", "x")
        with pytest.raises(SchemaError):
            col.upsert([""], corpus[:1])                  # empty id
        with pytest.raises(SchemaError):                  # duplicate create
            backend.create_collection(
                name="items", vector=VectorField(dim=DIM))
        with pytest.raises(KeyError):
            backend.drop_collection("never-existed")
        with pytest.raises(KeyError):
            backend.collection("never-existed")

    def test_management(self, backend):
        backend.create_collection(name="a", vector=VectorField(dim=4))
        backend.create_collection(name="b", vector=VectorField(dim=4))
        assert set(backend.list_collections()) >= {"a", "b"}
        assert backend["a"].name == "a" and "a" in backend
        backend.drop_collection("a")
        assert "a" not in backend.list_collections()


# -------------------------------------------------------------- wire details
class TestWire:
    def test_wire_matches_embedded_hit_for_hit(self, client, corpus, queries):
        remote = _make(client, corpus, index="hnsw")
        db = Database()
        embedded = _make(db, corpus, index="hnsw")
        flt = And((Predicate("category", "eq", "cat-1"),
                   Predicate("price", "lt", 30)))
        for qi in range(3):
            wire = remote.query(queries[qi]).filter(flt).top_k(5).run()
            local = embedded.query(queries[qi]).filter(flt).top_k(5).run()
            assert [(h.id, pytest.approx(h.score, rel=1e-5)) for h in wire] \
                == [(h.id, h.score) for h in local]
        db.close()

    def test_single_vector_searches_coalesce(self, server, client, corpus,
                                             queries):
        remote = _make(client, corpus,
                       batcher=BatcherConfig(max_batch=16, max_wait_ms=20.0))
        n_requests, per = 4, 8
        results = [None] * (n_requests * per)

        def worker(base):
            for j in range(per):
                results[base + j] = (remote.query(queries[base % len(queries)])
                                     .top_k(5).run())

        threads = [threading.Thread(target=worker, args=(i * per,))
                   for i in range(n_requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert all(r is not None for r in results)

        stats = remote.stats()
        served = stats["serving_requests_served"]
        batches = stats["serving_batches_served"]
        assert served >= n_requests * per
        assert batches < served          # coalescing actually happened
        # and the server-side collection object confirms the same counters
        col = server.service.db.collection("items")
        assert col.batcher.batches_served == batches

    def test_batcher_config_reaches_server(self, server, client):
        client.create_collection(
            name="tuned", vector=VectorField(dim=8, index="flat"),
            batcher=BatcherConfig(max_batch=7, max_wait_ms=11.0))
        col = server.service.db.collection("tuned")
        assert col.schema.batcher == BatcherConfig(max_batch=7,
                                                   max_wait_ms=11.0)
        assert col.batcher.max_batch == 7
        assert col.batcher.max_wait == pytest.approx(0.011)

    def test_service_default_batcher_applied(self):
        service = QuantixarService(
            config=ServiceConfig(default_max_batch=5, default_max_wait_ms=9.0))
        schema = {"name": "c", "vector": {"dim": 4, "index": "flat"}}
        out = service.dispatch(rq.CreateCollection(schema=schema))
        assert isinstance(out, rq.CollectionInfo)
        assert service.db.collection("c").schema.batcher == BatcherConfig(
            max_batch=5, max_wait_ms=9.0)
        service.close()

    def test_snapshot_restore_over_api(self, client, corpus, queries,
                                       tmp_path):
        remote = _make(client, corpus)
        remote.delete(["item-0", "item-1"])
        before = [h.id for h in remote.query(queries[0]).top_k(5).run()]
        gen = client.snapshot(str(tmp_path), step=2)
        assert gen == 1

        remote.delete([f"item-{i}" for i in range(2, 50)])   # post-snapshot
        assert client.restore(str(tmp_path)) == ["items"]
        restored = client.collection("items")
        assert len(restored) == N - 2                        # damage undone
        assert [h.id for h in
                restored.query(queries[0]).top_k(5).run()] == before

    def test_serving_stats_exposed(self, client, corpus, queries):
        remote = _make(client, corpus)
        for _ in range(3):
            remote.query(queries[0]).top_k(3).run()
        stats = remote.stats()
        for key in ("serving_batches_served", "serving_requests_served",
                    "serving_carried_requests", "serving_queue_depth"):
            assert key in stats
        assert stats["serving_requests_served"] >= 3
        assert stats["serving_batches_served"] >= 1
        # whole-database stats include the per-collection block
        assert client.stats()["items"]["live"] == N


class TestStructuredErrors:
    """Every failure must be a JSON ErrorInfo envelope — never a traceback."""

    @staticmethod
    def _raw(server, method, path, body=None):
        data = None if body is None else body.encode()
        req = urllib.request.Request(server.url + path, data=data,
                                     method=method)
        try:
            with urllib.request.urlopen(req, timeout=30) as resp:
                return resp.status, json.loads(resp.read().decode())
        except urllib.error.HTTPError as exc:
            return exc.code, json.loads(exc.read().decode())

    @pytest.mark.parametrize("method,path,body,status,code", [
        ("GET", "/nope", None, 404, rq.NOT_FOUND),
        ("GET", "/v1/collections/ghost", None, 404, rq.NOT_FOUND),
        ("POST", "/v1/collections/ghost/search", '{"vector": [1, 2]}',
         404, rq.NOT_FOUND),
        ("POST", "/v1/collections", '{"schema": "not-a-dict"}',
         400, rq.INVALID_ARGUMENT),
        # missing "name" in the schema is a bad request, not a 404
        ("POST", "/v1/collections", '{"schema": {"vector": {"dim": 4}}}',
         400, rq.INVALID_ARGUMENT),
        ("POST", "/v1/collections", 'not json at all',
         400, rq.INVALID_ARGUMENT),
        ("POST", "/v1/snapshot", '{"bogus_key": 1}',
         400, rq.INVALID_ARGUMENT),
        ("POST", "/v1/rpc", '{"op": "no_such_op"}',
         400, rq.INVALID_ARGUMENT),
        ("POST", "/v1/rpc", '{"v": 99, "op": "health"}',
         400, rq.INVALID_ARGUMENT),
    ])
    def test_error_envelopes(self, server, method, path, body, status, code):
        got_status, envelope = self._raw(server, method, path, body)
        assert got_status == status
        assert envelope["ok"] is False
        assert envelope["error"]["code"] == code
        assert "Traceback" not in json.dumps(envelope)

    def test_schema_error_is_400(self, server, client, corpus):
        _make(client, corpus, n=50)
        status, envelope = self._raw(
            server, "POST", "/v1/collections/items/search",
            json.dumps({"vector": [1.0, 2.0], "k": 3}))   # wrong dim
        assert status == 400
        assert envelope["error"]["code"] == rq.SCHEMA_ERROR
        # malformed filter node (missing "column") is 400, not 404/500
        status, envelope = self._raw(
            server, "POST", "/v1/collections/items/search",
            json.dumps({"vector": [0.0] * DIM, "k": 3,
                        "filter": {"pred": {"op": "eq"}}}))
        assert status == 400
        assert envelope["error"]["code"] == rq.INVALID_ARGUMENT

    def test_rpc_envelope_roundtrip(self, server, client, corpus):
        _make(client, corpus, n=50)
        status, envelope = self._raw(
            server, "POST", "/v1/rpc",
            json.dumps(rq.Stats(collection="items").to_dict()))
        assert status == 200 and envelope["ok"] is True
        assert envelope["result"]["stats"]["live"] == 50


class TestServerLifecycle:
    def test_shutdown_without_start_does_not_hang(self):
        srv = QuantixarHTTPServer(QuantixarService(Database()))
        srv.shutdown()                       # never started: must return

    def test_closed_collection_does_not_resurrect_batcher(self, corpus,
                                                          queries):
        """A query racing close()/drop must fail typed, not leak a fresh
        batcher worker against a dropped collection."""
        db = Database()
        col = db.create_collection(
            name="doomed", vector=VectorField(dim=DIM, index="flat"))
        col.upsert(_ids(20), corpus[:20], None)
        col.query(queries[0]).top_k(2).run()     # batcher alive
        db.drop_collection("doomed")
        with pytest.raises(CollectionClosed):
            col.query(queries[0]).top_k(2).run()
        assert col._batcher is None               # nothing resurrected
        db.close()

    def test_client_timeout_forwarded(self, client, corpus, queries):
        col = _make(client, corpus, n=50)
        # generous per-query timeout must still succeed end to end
        hits = col.query(queries[0]).top_k(3).run(timeout=30.0)
        assert len(hits) == 3


class TestProtocolCodec:
    def test_filter_tree_roundtrip(self):
        flt = And((Predicate("category", "in", ("a", "b")),
                   Not(Predicate("price", "ge", 10.0))))
        d = rq.filter_to_dict(flt)
        assert rq.filter_from_dict(json.loads(json.dumps(d))) == flt

    def test_request_envelope_roundtrip(self):
        req = rq.Search(collection="c", vector=[1.0, 2.0], k=3,
                        filter=rq.filter_to_dict(Predicate("x", "eq", "y")),
                        ef=32, include_vector=True)
        decoded = rq.decode_request(json.loads(json.dumps(req.to_dict())))
        assert decoded == req and not decoded.batched
        batch = rq.Search(collection="c", vector=[[1.0], [2.0]], k=1)
        assert rq.decode_request(batch.to_dict()).batched

    def test_decode_rejects_garbage(self):
        with pytest.raises(rq.ApiError) as err:
            rq.decode_request({"op": "search", "body": {"bogus": 1}})
        assert err.value.code == rq.INVALID_ARGUMENT
        with pytest.raises(rq.ApiError):
            rq.decode_request([1, 2, 3])

    def test_error_info_taxonomy(self):
        info = rq.ErrorInfo("SOMETHING_ELSE", "x")
        assert info.code == rq.INTERNAL       # unknown codes degrade safely
        exc = rq.error_to_exception(rq.ErrorInfo(rq.SCHEMA_ERROR, "bad"))
        assert isinstance(exc, SchemaError)
        exc = rq.error_to_exception(rq.ErrorInfo(rq.NOT_FOUND, "gone"))
        assert isinstance(exc, KeyError)


class TestConcurrentStress:
    def test_epoch_retry_never_returns_stale_ids(self, corpus):
        """Queries racing upserts and compactions must never surface a stale
        row translation: every hit's payload tag must equal its id."""
        n = 120
        db = Database()
        col = db.create_collection(
            name="stress", vector=VectorField(dim=DIM, index="flat"),
            fields=(KeywordField("tag"),),
            batcher=BatcherConfig(max_batch=8, max_wait_ms=1.0))
        ids = [f"p-{i}" for i in range(n)]
        col.upsert(ids, corpus[:n], [{"tag": i} for i in ids])

        stop = threading.Event()
        errors = []
        retries_exhausted = [0]

        def querier(seed):
            rng = np.random.RandomState(seed)
            while not stop.is_set():
                vec = corpus[rng.randint(n)]
                try:
                    hits = col.query(vec).top_k(8).run(timeout=30)
                except QueryRetriesExhausted:
                    retries_exhausted[0] += 1         # allowed: no stale data
                    continue
                except RuntimeError as exc:
                    errors.append(repr(exc))
                    return
                for h in hits:
                    if h.payload.get("tag") != h.id:
                        errors.append(
                            f"stale hit: id={h.id} tag={h.payload.get('tag')}")
                        return

        def writer():
            rng = np.random.RandomState(7)
            while not stop.is_set():
                i = rng.randint(n)
                try:
                    col.upsert(ids[i], rng.randn(DIM).astype(np.float32),
                               [{"tag": ids[i]}])
                except Exception as exc:              # noqa: BLE001
                    errors.append(f"writer: {exc!r}")
                    return

        def compactor():
            while not stop.is_set():
                try:
                    col.compact()
                except Exception as exc:              # noqa: BLE001
                    errors.append(f"compactor: {exc!r}")
                    return
                stop.wait(0.02)

        threads = ([threading.Thread(target=querier, args=(s,))
                    for s in range(3)]
                   + [threading.Thread(target=writer),
                      threading.Thread(target=compactor)])
        for t in threads:
            t.start()
        import time
        time.sleep(2.5)
        stop.set()
        for t in threads:
            t.join(timeout=30)
        db.close()
        assert errors == []


# ---------------------------------------------------------------- query plans
class TestQueryPlans:
    """PR 5 acceptance: coarse-to-fine plans, explain, fusion, count —
    identical embedded and over the wire."""

    def test_coarse_to_fine_ge_rescore_recall(self, backend, corpus,
                                              queries):
        """A coarse-to-fine plan on a PQ collection reaches >= the recall
        of the legacy rescore=True path at equal k (in fact reproduces it
        hit for hit at coarse_k == rescore_multiplier * k)."""
        from repro.core import PQConfig
        from repro.core.hnsw_build import exact_knn
        col = _make(backend, corpus, quantization="pq",
                    pq=PQConfig(m=8, k=32, iters=6))
        k = 10
        gt = exact_knn(queries, corpus, k, metric="cosine")

        def recall(rows):
            return sum(len({h.id for h in r} & {f"item-{j}" for j in t})
                       for r, t in zip(rows, gt)) / (len(queries) * k)

        legacy = [col.query(q).top_k(k).rescore(True).run()
                  for q in queries]
        staged = [col.query(q).top_k(k).stages(coarse_k=4 * k).run()
                  for q in queries]
        assert recall(staged) >= recall(legacy)
        assert [[h.id for h in r] for r in staged] \
            == [[h.id for h in r] for r in legacy]

    def test_explain_both_sides(self, backend, corpus, queries):
        col = _make(backend, corpus)
        ex = col.query(queries[0]).top_k(5).stages(coarse_k=20).explain()
        assert [s["stage"] for s in ex.stages] == ["ann", "rescore"]
        assert ex.stages[0]["candidates_out"] == 20
        assert ex.stages[1]["candidates_out"] == 5
        assert all(s["seconds"] >= 0 for s in ex.stages)
        assert [s["op"] for s in ex.plan["stages"]] == ["ann", "rescore"]
        assert len(ex.hits) == 5

    def test_prefetch_fusion(self, backend, corpus, queries):
        col = _make(backend, corpus)
        fused = (col.query(queries[0]).top_k(6)
                 .prefetch(category="cat-1")
                 .prefetch(category="cat-2")
                 .fuse("rrf")
                 .run())
        assert 0 < len(fused) <= 6
        assert {h.payload["category"] for h in fused} <= {"cat-1", "cat-2"}

    def test_count(self, backend, corpus):
        col = _make(backend, corpus)
        assert col.count() == N
        assert col.count(Predicate("category", "eq", "cat-2")) == N // 4
        col.delete(["item-2"])                    # a cat-2 item
        assert col.count(Predicate("category", "eq", "cat-2")) == N // 4 - 1


class TestPlanWireParity:
    """Wire and embedded execution of the SAME multi-stage plan must agree
    on hits, scores, and the explain() echo."""

    def _pair(self, client, corpus, **vector_kw):
        remote = _make(client, corpus, **vector_kw)
        db = Database()
        embedded = _make(db, corpus, **vector_kw)
        return remote, embedded, db

    def test_multi_stage_and_fused_hit_for_hit(self, client, corpus,
                                               queries):
        remote, embedded, db = self._pair(client, corpus, index="hnsw")
        builders = [
            lambda c, q: c.query(q).top_k(6).stages(coarse_k=24).ef(64),
            lambda c, q: (c.query(q).top_k(6)
                          .prefetch(category="cat-1")
                          .prefetch(vector=q, category="cat-2")
                          .fuse("rrf")),
            lambda c, q: (c.query(q).top_k(4)
                          .prefetch(category="cat-0")
                          .prefetch(category="cat-3")
                          .fuse("linear", weights=[0.7, 0.3])),
        ]
        for build in builders:
            for qi in range(2):
                wire = build(remote, queries[qi]).run()
                local = build(embedded, queries[qi]).run()
                assert [(h.id, pytest.approx(h.score, rel=1e-5))
                        for h in wire] \
                    == [(h.id, h.score) for h in local]
        db.close()

    def test_explain_same_plan_echo(self, client, corpus, queries):
        remote, embedded, db = self._pair(client, corpus)
        we = remote.query(queries[0]).top_k(5).stages(oversample=4).explain()
        le = embedded.query(queries[0]).top_k(5).stages(oversample=4) \
            .explain()
        assert we.plan == le.plan                  # identical compiled plan
        assert [h.id for h in we.hits] == [h.id for h in le.hits]
        assert [(s["stage"], s["k"], s["candidates_out"])
                for s in we.stages] \
            == [(s["stage"], s["k"], s["candidates_out"])
                for s in le.stages]
        db.close()

    def test_batched_multi_stage_parity(self, client, corpus, queries):
        remote, embedded, db = self._pair(client, corpus)
        wire = remote.query(queries[:3]).top_k(4).stages(coarse_k=16).run()
        local = embedded.query(queries[:3]).top_k(4).stages(coarse_k=16) \
            .run()
        assert [[h.id for h in row] for row in wire] \
            == [[h.id for h in row] for row in local]
        db.close()

    def test_count_routes(self, server, client, corpus):
        remote = _make(client, corpus, n=60)
        assert remote.count() == 60
        assert remote.count(Predicate("category", "eq", "cat-1")) == 15
        # raw GET (count everything) and POST (filtered) both route
        status, env = TestStructuredErrors._raw(
            server, "GET", "/v1/collections/items/count")
        assert status == 200 and env["result"]["count"] == 60
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/items/count",
            json.dumps({"filter": rq.filter_to_dict(
                Predicate("in_stock", "eq", True))}))
        assert status == 200 and env["result"]["count"] == 20


class TestPlanCodec:
    def test_round_trip_every_stage_type(self):
        from repro.api import (AnnStage, FusionStage, PrefetchStage,
                               QueryPlan, RescoreStage, plan_from_dict,
                               plan_to_dict)
        vec = np.arange(4, dtype=np.float32)
        nested = QueryPlan(k=4, vector=None, stages=(
            PrefetchStage(plans=(
                QueryPlan(k=4, vector=vec, stages=(AnnStage(k=4),)),)),
            FusionStage(k=4, method="linear", weights=(1.0,))))
        plan = QueryPlan(k=5, vector=vec, stages=(
            PrefetchStage(plans=(
                QueryPlan(k=8, vector=vec + 1, stages=(
                    AnnStage(k=32, ef=64, expansion_width=2,
                             filter=Predicate("category", "eq", "x"),
                             rescore=False),
                    RescoreStage(k=8))),
                nested)),                     # nested prefetch round-trips
            FusionStage(k=20, method="rrf", rrf_k=10),
            RescoreStage(k=5)))
        d = plan_to_dict(plan)
        rebuilt = plan_to_dict(plan_from_dict(json.loads(json.dumps(d))))
        assert rebuilt == d

    @pytest.mark.parametrize("bad", [
        "not-a-dict",
        {"k": 5},                                       # no stages
        {"k": 5, "stages": []},                         # empty stages
        {"k": 0, "stages": [{"op": "ann", "k": 5}]},    # bad k
        {"k": 5, "stages": [{"op": "warp", "k": 5}]},   # unknown op
        {"k": 5, "stages": [{"op": "ann", "k": 0}]},    # bad stage k
        {"k": 5, "stages": [{"op": "prefetch"}]},       # prefetch w/o plans
        {"v": 99, "k": 5, "stages": [{"op": "ann", "k": 5}]},  # bad version
        {"k": 5, "stages": [{"op": "fusion", "k": 5, "method": "max"}]},
    ])
    def test_malformed_plans_raise_schema_error(self, bad):
        from repro.api import plan_from_dict
        with pytest.raises(SchemaError):
            plan_from_dict(bad)

    @pytest.mark.parametrize("stages", [
        [{"op": "rescore", "k": 5}],                    # rescore first
        [{"op": "ann", "k": 5}, {"op": "ann", "k": 5}],  # ann not first
        [{"op": "ann", "k": 5}, {"op": "prefetch", "plans": [
            {"k": 5, "stages": [{"op": "ann", "k": 5}],
             "vector": [0.0] * DIM}]}],                 # prefetch not first
        [{"op": "prefetch", "plans": [
            {"k": 5, "stages": [{"op": "ann", "k": 5}],
             "vector": [0.0] * DIM}]}],                 # prefetch w/o fusion
        [{"op": "ann", "k": 3}],                        # final k < plan k
    ])
    def test_invalid_stage_orderings_rejected(self, stages, corpus):
        from repro.api import plan_from_dict
        db = Database()
        col = _make(db, corpus, n=30)
        plan = plan_from_dict({"k": 5, "vector": [0.0] * DIM,
                               "stages": stages})
        with pytest.raises(SchemaError):
            col.execute_plan(plan)
        db.close()

    def test_malformed_plan_wire_error_envelope(self, server, client,
                                                corpus):
        _make(client, corpus, n=30)
        for plan in ({"k": 3, "stages": [{"op": "bogus"}]},
                     {"v": 9, "k": 3, "stages": [{"op": "ann", "k": 3}]},
                     {"k": 3, "stages": [{"op": "rescore", "k": 3}],
                      "vector": [0.0] * DIM}):
            status, envelope = TestStructuredErrors._raw(
                server, "POST", "/v1/collections/items/search",
                json.dumps({"plan": plan}))
            assert status == 400
            assert envelope["error"]["code"] == rq.SCHEMA_ERROR
            assert "Traceback" not in json.dumps(envelope)
        # neither vector nor plan
        status, envelope = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/items/search", "{}")
        assert status == 400
        assert envelope["error"]["code"] == rq.INVALID_ARGUMENT

    def test_batched_root_vector_rejected_on_prefetch_plans(self, corpus):
        """A hand-authored wire plan with a 2-D root vector + prefetch must
        fail validation (400), not silently fuse one row or crash an
        INTERNAL on a trailing rescore stage."""
        from repro.api import plan_from_dict
        db = Database()
        col = _make(db, corpus, n=30)
        plan = plan_from_dict({
            "k": 3, "vector": [[0.0] * DIM, [1.0] * DIM],
            "stages": [
                {"op": "prefetch", "plans": [
                    {"k": 3, "vector": [0.0] * DIM,
                     "stages": [{"op": "ann", "k": 3}]}]},
                {"op": "fusion", "k": 3}]})
        with pytest.raises(SchemaError):
            col.execute_plan(plan)
        db.close()

    @pytest.mark.parametrize("bad_plan", [
        {"k": 3, "vector": [[0.1], [0.2, 0.3]],        # ragged vector
         "stages": [{"op": "ann", "k": 3}]},
        {"k": 3, "vector": [0.0] * 4, "stages": [
            {"op": "prefetch", "plans": [
                {"k": 3, "vector": [0.0] * 4,
                 "stages": [{"op": "ann", "k": 3}]}]},
            {"op": "fusion", "k": 3, "weights": 5}]},   # non-list weights
        {"k": 3, "vector": [0.0] * 4, "stages": [
            {"op": "prefetch", "plans": [
                {"k": 3, "vector": [0.0] * 4,
                 "stages": [{"op": "ann", "k": 3}]}]},
            {"op": "fusion", "k": 3, "rrf_k": "abc"}]},  # bad rrf_k
        {"k": 3, "vector": [0.0] * 4,
         "stages": [{"op": "ann", "k": 3, "ef": "fast"}]},   # bad ef
        {"k": 3, "vector": [0.0] * 4,
         "stages": [{"op": "ann", "k": 3, "rescore": "yes"}]},
    ])
    def test_codec_rejects_malformed_fields_as_schema_error(self, bad_plan):
        """Interpreter errors (TypeError/ValueError) must never escape the
        codec: every malformed plan is a SchemaError -> SCHEMA_ERROR."""
        from repro.api import plan_from_dict
        with pytest.raises(SchemaError):
            plan_from_dict(bad_plan)


# ----------------------------------------------------------- hybrid / sparse
_TEXTS = ["quick brown fox jumps high", "lazy dog sleeps all day",
          "quick fox and quick hare race", "vector database systems scale",
          "sparse retrieval uses bm25 scoring", "dense vectors meet keywords",
          "fox dens and fox kits", "ranking quality over speed"]


def _make_text(backend, corpus, name="textcol"):
    col = backend.create_collection(
        name=name, vector=VectorField(dim=DIM, index="flat"),
        fields=(TextField("body"), KeywordField("category")))
    n = len(_TEXTS)
    col.upsert([f"doc-{i}" for i in range(n)], corpus[:n],
               [{"body": t, "category": f"cat-{i % 2}"}
                for i, t in enumerate(_TEXTS)])
    return col


class TestSparseBackendParity:
    """Keyword and hybrid searches behave identically embedded and remote."""

    def test_keyword_search(self, backend, corpus):
        col = _make_text(backend, corpus)
        hits = col.query().text("quick fox").top_k(3).run()
        assert [h.id for h in hits] == ["doc-2", "doc-0", "doc-6"]
        assert all(h.score < 0 for h in hits)     # negated BM25

    def test_filtered_keyword_search(self, backend, corpus):
        col = _make_text(backend, corpus)
        hits = (col.query().text("quick fox")
                .filter(category="cat-0").top_k(5).run())
        assert hits and all(h.payload["category"] == "cat-0" for h in hits)

    def test_hybrid_explain_structure(self, backend, corpus, queries):
        col = _make_text(backend, corpus)
        ex = col.query(queries[0]).text("quick fox").top_k(4).explain()
        assert [s["stage"] for s in ex.stages] == ["prefetch", "fusion"]
        children = ex.stages[0]["children"]
        assert [c[0]["stage"] for c in children] == ["ann", "sparse"]
        assert children[1][0]["candidates_out"] > 0
        assert len(ex.hits) == 4

    def test_sparse_stats(self, backend, corpus):
        col = _make_text(backend, corpus)
        stats = col.stats()
        assert stats["sparse_fields"] == 1
        assert stats["sparse_docs_indexed"] == len(_TEXTS)
        assert stats["sparse_vocab"] > 0
        assert stats["sparse_postings"] == stats["sparse_sealed_postings"] \
            + stats["sparse_delta_postings"]


class TestSparseWireParity:
    """The SAME hybrid plan must return the same hits with the same explain
    structure embedded and over the wire."""

    def test_hybrid_hit_for_hit(self, client, corpus, queries):
        remote = _make_text(client, corpus)
        db = Database()
        embedded = _make_text(db, corpus)
        builders = [
            lambda c, q: c.query().text("quick fox").top_k(3),
            lambda c, q: (c.query().text("fox bm25")
                          .filter(category="cat-0").top_k(4)),
            lambda c, q: c.query(q).text("quick fox").top_k(4),
            lambda c, q: (c.query(q).top_k(4)
                          .prefetch(k=8)
                          .prefetch(text="sparse bm25 scoring", k=8)
                          .fuse("rrf")),
        ]
        for build in builders:
            wire = build(remote, queries[0]).run()
            local = build(embedded, queries[0]).run()
            assert [(h.id, pytest.approx(h.score, rel=1e-5)) for h in wire] \
                == [(h.id, h.score) for h in local]
        we = remote.query(queries[0]).text("quick fox").top_k(3).explain()
        le = embedded.query(queries[0]).text("quick fox").top_k(3).explain()
        assert we.plan == le.plan
        assert [h.id for h in we.hits] == [h.id for h in le.hits]
        assert [(s["stage"], s["candidates_out"]) for s in we.stages] \
            == [(s["stage"], s["candidates_out"]) for s in le.stages]
        db.close()

    def test_legacy_text_form(self, server, client, corpus):
        _make_text(client, corpus)
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/textcol/search",
            json.dumps({"text": "quick fox", "k": 3}))
        assert status == 200
        assert [h["id"] for h in env["result"]["hits"]] \
            == ["doc-2", "doc-0", "doc-6"]
        # neither vector nor text nor plan is INVALID_ARGUMENT
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/textcol/search", "{}")
        assert status == 400
        assert env["error"]["code"] == rq.INVALID_ARGUMENT
        assert "'text'" in env["error"]["message"]

    def test_sparse_stats_over_wire(self, client, corpus):
        remote = _make_text(client, corpus)
        stats = remote.stats()
        assert stats["sparse_docs_indexed"] == len(_TEXTS)
        assert stats["sparse_vocab"] > 0


class TestSparsePlanCodec:
    def test_sparse_stage_round_trip(self):
        from repro.api import (FusionStage, PrefetchStage, QueryPlan,
                               SparseStage, plan_from_dict, plan_to_dict)
        plan = QueryPlan(k=3, vector=None, stages=(
            SparseStage(text="quick fox", k=3, field="body",
                        filter=Predicate("category", "eq", "cat-0")),))
        d = plan_to_dict(plan)
        assert plan_to_dict(plan_from_dict(json.loads(json.dumps(d)))) == d
        # and inside a prefetch sub-plan next to a dense leg
        vec = np.arange(DIM, dtype=np.float32)
        hybrid = QueryPlan(k=4, vector=vec, stages=(
            PrefetchStage(plans=(
                QueryPlan(k=8, vector=None, stages=(
                    SparseStage(text="quick fox", k=8),)),
                QueryPlan(k=8, vector=None, stages=(
                    __import__("repro.api", fromlist=["AnnStage"])
                    .AnnStage(k=8),)),)),
            FusionStage(k=4)))
        d = plan_to_dict(hybrid)
        assert plan_to_dict(plan_from_dict(json.loads(json.dumps(d)))) == d

    @pytest.mark.parametrize("bad", [
        {"k": 3, "stages": [{"op": "sparse", "k": 3}]},          # no text
        {"k": 3, "stages": [{"op": "sparse", "k": 3, "text": ""}]},
        {"k": 3, "stages": [{"op": "sparse", "k": 3, "text": "  "}]},
        {"k": 3, "stages": [{"op": "sparse", "k": 0, "text": "x"}]},
        {"k": 3, "stages": [{"op": "sparse", "k": -2, "text": "x"}]},
        {"k": 3, "stages": [{"op": "sparse", "k": 3, "text": "x",
                             "field": 7}]},                      # bad field
    ])
    def test_malformed_sparse_stages_raise_schema_error(self, bad):
        from repro.api import plan_from_dict
        with pytest.raises(SchemaError):
            plan_from_dict(bad)

    def test_sparse_validation_against_schema(self, corpus):
        from repro.api import plan_from_dict
        db = Database()
        col = _make_text(db, corpus)
        # unknown text field
        plan = plan_from_dict({"k": 3, "stages": [
            {"op": "sparse", "k": 3, "text": "x", "field": "nope"}]})
        with pytest.raises(SchemaError):
            col.execute_plan(plan)
        # sparse stage not at position 0
        plan = plan_from_dict({"k": 3, "vector": [0.0] * DIM, "stages": [
            {"op": "ann", "k": 3},
            {"op": "sparse", "k": 3, "text": "x"}]})
        with pytest.raises(SchemaError):
            col.execute_plan(plan)
        # sparse against a text-less collection
        plain = _make(db, corpus, n=20, name="plain")
        plan = plan_from_dict({"k": 3, "stages": [
            {"op": "sparse", "k": 3, "text": "x"}]})
        with pytest.raises(SchemaError, match="no text fields"):
            plain.execute_plan(plan)
        db.close()

    def test_malformed_text_stage_wire_error(self, server, client, corpus):
        _make_text(client, corpus)
        for plan in ({"k": 3, "stages": [{"op": "sparse", "k": 3,
                                          "text": ""}]},
                     {"k": 3, "stages": [{"op": "sparse", "k": 0,
                                          "text": "x"}]},
                     {"k": 3, "stages": [{"op": "sparse", "k": 3, "text": "x",
                                          "field": "nope"}]}):
            status, envelope = TestStructuredErrors._raw(
                server, "POST", "/v1/collections/textcol/search",
                json.dumps({"plan": plan}))
            assert status == 400
            assert envelope["error"]["code"] == rq.SCHEMA_ERROR
            assert "Traceback" not in json.dumps(envelope)


# ------------------------------------------------------------------ sharding
# PR 10: `ShardedCollection` must be hit-for-hit identical to a single-shard
# `Collection` over the same rows — for every quantization, dense / hybrid /
# filtered, embedded and over the wire — and must survive rebalance, replica
# failure, and save/load.
SH_N = 160                       # smaller corpus keeps the 3-quant matrix fast

_SH_QUANTS = {
    "none": {},
    "pq": {"quantization": "pq"},
    "bq": {"quantization": "bq"},
}


def _make_sharded_pair(backend, corpus, shards=3, replicas=1, n=SH_N,
                       **vector_kw):
    """Build (sharded, single-shard) twins over identical rows, with both
    keyword/numeric fields (filtered legs) and a text field (hybrid legs)."""
    from repro.core import PQConfig
    vector_kw.setdefault("dim", DIM)
    vector_kw.setdefault("index", "flat")
    if vector_kw.get("quantization") == "pq":
        vector_kw.setdefault("pq", PQConfig(m=8, k=16, iters=4))
    fields = (KeywordField("category"), NumericField("price"),
              BoolField("in_stock"), TextField("body"))
    payloads = [{"category": f"cat-{i % 4}", "price": float(i % 50),
                 "in_stock": i % 3 == 0, "body": _TEXTS[i % len(_TEXTS)]}
                for i in range(n)]
    cols = []
    for name, s, r in (("sharded_tw", shards, replicas),
                       ("single_tw", 1, 1)):
        col = backend.create_collection(
            name=name, vector=VectorField(**vector_kw), fields=fields,
            shards=s, replicas=r)
        col.upsert(_ids(n), corpus[:n], payloads)
        cols.append(col)
    return cols


def _sh_builders(n=SH_N):
    """Query builders exact under every quantization: coarse_k covers the
    whole corpus, so the final exact rescore fully determines the ranking
    on both sides (per-shard PQ/BQ codebooks differ by construction)."""
    return {
        "dense": lambda c, q: c.query(q).top_k(8).stages(coarse_k=n),
        "filtered": lambda c, q: (c.query(q).filter(category="cat-1")
                                  .where("price", "lt", 30).top_k(8)
                                  .stages(coarse_k=n)),
        "hybrid": lambda c, q: (c.query(q).top_k(6)
                                .prefetch(k=n, coarse_k=n)
                                .prefetch(text="quick fox", k=n)
                                .fuse("rrf")),
    }


def _same_hits(got, want, tag=""):
    assert [(h.id, pytest.approx(h.score, rel=1e-5)) for h in got] \
        == [(h.id, h.score) for h in want], tag


class TestShardedParity:
    """Runs twice per quantization: embedded and over the wire."""

    @pytest.mark.parametrize("quant", sorted(_SH_QUANTS))
    def test_sharded_matches_single_hit_for_hit(self, backend, corpus,
                                                queries, quant):
        sharded, single = _make_sharded_pair(backend, corpus,
                                             **_SH_QUANTS[quant])
        for mode, build in _sh_builders().items():
            for qi in range(2):
                _same_hits(build(sharded, queries[qi]).run(),
                           build(single, queries[qi]).run(),
                           f"{quant}/{mode}/q{qi}")
        # batched (2-D) queries take the direct scatter path
        wide = sharded.query(queries[:3]).top_k(5).stages(coarse_k=SH_N).run()
        ref = single.query(queries[:3]).top_k(5).stages(coarse_k=SH_N).run()
        for w_row, r_row in zip(wide, ref):
            _same_hits(w_row, r_row, f"{quant}/batched")

    def test_sharded_crud_and_stats(self, backend, corpus):
        sharded, single = _make_sharded_pair(backend, corpus)
        assert len(sharded) == len(single) == SH_N
        e = sharded.get("item-7")
        assert e.id == "item-7" and e.payload["category"] == "cat-3"
        assert sharded.get("missing") is None
        assert sharded.delete(["item-7", "item-8", "missing"]) == 2
        assert len(sharded) == SH_N - 2
        assert sharded.count(Predicate("category", "eq", "cat-1")) \
            == single.count(Predicate("category", "eq", "cat-1"))
        ss = sharded.shard_stats()
        assert len(ss) == 3
        assert sum(s["rows"] for s in ss) == SH_N
        assert sum(s["tombstones"] for s in ss) == 2
        assert sharded.compact() == 2
        assert len(single.shard_stats()) == 1     # uniform surface

    def test_per_shard_compact_and_seal(self, backend, corpus):
        sharded, _ = _make_sharded_pair(backend, corpus)
        sharded.delete([f"item-{i}" for i in range(20)])
        per_shard = [s["tombstones"] for s in sharded.shard_stats()]
        assert sum(per_shard) == 20
        reclaimed = sharded.compact(shard=0)
        assert reclaimed == per_shard[0]
        rest = sharded.compact()                  # the other shards
        assert reclaimed + rest == 20
        assert all(s["tombstones"] == 0 for s in sharded.shard_stats())


class TestShardedTopology:
    """Rebalance / split / slot-move / replication — embedded API."""

    def test_rebalance_preserves_results(self, corpus, queries, tmp_path):
        db = Database()
        sharded, single = _make_sharded_pair(db, corpus, shards=3)
        build = _sh_builders()["hybrid"]
        want = [build(single, q).run() for q in queries[:2]]

        for step, mutate in (
                ("grow", lambda: sharded.rebalance(shards=5)),
                ("shrink", lambda: sharded.rebalance(
                    shards=2, snapshot_dir=str(tmp_path / "shrink"))),
                ("split", lambda: sharded.split(0)),
                ("replicate", lambda: sharded.rebalance(replicas=2))):
            info = mutate()
            assert info["rows"] == SH_N, step
            for qi in range(2):
                _same_hits(build(sharded, queries[qi]).run(), want[qi],
                           f"after {step}")
        assert sharded.num_shards == 3            # 2 + split
        # writes still land correctly after all the topology churn
        sharded.upsert("item-0", corpus[1], [{"category": "cat-9",
                                              "body": "quick fox"}])
        assert sharded.get("item-0").payload["category"] == "cat-9"
        db.close()

    def test_move_slot(self, corpus, queries):
        from repro.cluster import slot_of
        db = Database()
        sharded, single = _make_sharded_pair(db, corpus, shards=2)
        before = [h.id for h in sharded.query(queries[0]).top_k(10).run()]
        slot = slot_of("item-0")
        owner = sharded._router.slot_map[slot]
        sharded.move_slot(slot, 1 - owner)
        assert sharded._router.slot_map[slot] == 1 - owner
        assert sharded.get("item-0") is not None
        assert [h.id for h in
                sharded.query(queries[0]).top_k(10).run()] == before
        db.close()

    def test_replica_failover(self, corpus, queries):
        from repro.api import ShardUnavailable
        db = Database()
        sharded, single = _make_sharded_pair(db, corpus, shards=2,
                                             replicas=2)
        want = single.query(queries[0]).top_k(8).run()
        _same_hits(sharded.query(queries[0]).top_k(8).run(), want, "healthy")
        sharded.set_replica_health(0, 0, False)   # primary of shard 0 down
        _same_hits(sharded.query(queries[0]).top_k(8).run(), want,
                   "one replica down")
        assert sharded.get("item-0") is not None
        sharded.set_replica_health(0, 1, False)   # whole shard dark
        with pytest.raises(ShardUnavailable):
            sharded.query(queries[0]).top_k(8).run()
        sharded.set_replica_health(0, 0, True)    # recovery
        _same_hits(sharded.query(queries[0]).top_k(8).run(), want,
                   "recovered")
        db.close()

    def test_sharded_save_load_roundtrip(self, corpus, queries, tmp_path):
        from repro.api import ShardedCollection
        db = Database(str(tmp_path))
        sharded, single = _make_sharded_pair(db, corpus, shards=3,
                                             replicas=2)
        sharded.delete(["item-3"])
        want = [h.id for h in sharded.query(queries[0]).top_k(8).run()]
        db.save()
        db.close()

        db2 = Database.load(str(tmp_path))
        col = db2.collection("sharded_tw")
        assert isinstance(col, ShardedCollection)
        assert col.num_shards == 3 and col.schema.replicas == 2
        assert len(col) == SH_N - 1 and col.get("item-3") is None
        assert [h.id for h in
                col.query(queries[0]).top_k(8).run()] == want
        # restored collection is fully live: writes and topology changes work
        col.upsert("item-new", corpus[0], [{"category": "cat-0",
                                            "body": "quick fox"}])
        col.rebalance(shards=2)
        assert col.get("item-new") is not None
        db2.close()


class TestShardedWire:
    """The new wire ops (Rebalance / ShardStats / per-shard Compact) and
    sharded snapshot/restore over HTTP."""

    def test_sharded_ops_over_wire(self, server, client, corpus, queries):
        remote = _make(client, corpus, name="swire", n=SH_N, shards=3)
        want = [h.id for h in remote.query(queries[0]).top_k(8).run()]

        ss = remote.shard_stats()
        assert len(ss) == 3 and sum(s["rows"] for s in ss) == SH_N
        assert all(s["health"] == [True] for s in ss)

        info = remote.rebalance(shards=2)
        assert info["shards"] == 2 and info["rows"] == SH_N
        assert len(remote.shard_stats()) == 2
        assert [h.id for h in
                remote.query(queries[0]).top_k(8).run()] == want

        remote.delete([f"item-{i}" for i in range(10)])
        reclaimed = remote.compact(shard=0) + remote.compact(shard=1)
        assert reclaimed == 10
        assert remote.compact() == 0

        # raw envelopes: GET /shards routes; rebalance on an unsharded
        # collection is INVALID_ARGUMENT, not a 500
        status, env = TestStructuredErrors._raw(
            server, "GET", "/v1/collections/swire/shards")
        assert status == 200 and len(env["result"]["shards"]) == 2
        _make(client, corpus, name="unsharded", n=20)
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/unsharded/rebalance",
            json.dumps({"shards": 2}))
        assert status == 400
        assert env["error"]["code"] == rq.INVALID_ARGUMENT
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/collections/unsharded/compact",
            json.dumps({"shard": 0}))
        assert status == 400
        # and the raw rpc envelope speaks the new ops too
        status, env = TestStructuredErrors._raw(
            server, "POST", "/v1/rpc",
            json.dumps(rq.ShardStats(collection="swire").to_dict()))
        assert status == 200
        assert len(env["result"]["shards"]) == 2

    def test_sharded_wire_matches_embedded(self, client, corpus, queries):
        remote_pair = _make_sharded_pair(client, corpus, shards=3)
        db = Database()
        local_pair = _make_sharded_pair(db, corpus, shards=3)
        build = _sh_builders()["hybrid"]
        _same_hits(build(remote_pair[0], queries[0]).run(),
                   build(local_pair[0], queries[0]).run(), "wire vs embedded")
        # explain carries per-shard timings over the wire
        ex = remote_pair[0].query(queries[0]).top_k(5).explain()
        ann = next(s for s in ex.stages if s["stage"] == "ann")
        assert len(ann["shards"]) == 3
        assert all(s["seconds"] >= 0 for s in ann["shards"])
        db.close()

    def test_sharded_snapshot_restore_over_wire(self, client, corpus,
                                                queries, tmp_path):
        remote = _make(client, corpus, name="snapme", n=SH_N, shards=3)
        remote.delete(["item-0"])
        want = [h.id for h in remote.query(queries[1]).top_k(8).run()]
        gen = client.snapshot(str(tmp_path))
        remote.delete([f"item-{i}" for i in range(1, 40)])  # post-snapshot
        assert "snapme" in client.restore(str(tmp_path), generation=gen)
        restored = client.collection("snapme")
        assert restored.schema.shards == 3
        assert len(restored) == SH_N - 1                    # damage undone
        assert [h.id for h in
                restored.query(queries[1]).top_k(8).run()] == want
        assert len(restored.shard_stats()) == 3

    def test_sharded_stats_over_wire(self, client, corpus):
        remote = _make(client, corpus, name="statsy", n=SH_N, shards=2,
                       replicas=2)
        stats = remote.stats()
        assert stats["shards"] == 2 and stats["replicas"] == 2
        assert stats["live"] == SH_N
        assert len(stats["per_shard"]) == 2
