"""Wide-beam HNSW traversal: width parity, code-domain dispatch, regression.

Covers the PR-4 acceptance surface:
  * device vs numpy-reference parity across expansion_width ∈ {1, 2, 4}
    for all three quantization modes;
  * width=1 reproduces the seed single-pop traversal bit-for-bit (the seed
    loop is re-implemented verbatim below as the golden);
  * filtered (masked) search under wide beams;
  * dispatch-level proof that quantized traversal routes distances through
    the fused beam-gather kernel path (adc / hamming), not float32
    reconstruction gathers;
  * iteration-counter drop (the perf claim's mechanism) and the
    expansion_width knob across engine / Query / wire protocol.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HNSWConfig, build, exact_knn, recall_at_k
from repro.core import bq as bq_mod
from repro.core import pq as pq_mod
from repro.core.engine import EngineConfig, QuantixarEngine
from repro.core.hnsw_build import PAD, preprocess_vectors
import repro.core.hnsw_search as hs
from repro.core.hnsw_search import search, search_numpy_reference, to_device
from repro.data.synthetic import gaussian_mixture

N, DIM = 900, 24
WIDTHS = (1, 2, 4)


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=15, scale=0.25, seed=3)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(24, DIM, n_clusters=15, scale=0.25, seed=11)


@pytest.fixture(scope="module")
def packed(corpus):
    return build(corpus, HNSWConfig(M=10, ef_construction=64,
                                    metric="cosine", seed=0))


# ---------------------------------------------------------------------------
# Traversal-level: width parity, iteration counters, bit-for-bit regression
# ---------------------------------------------------------------------------

class TestWideBeamTraversal:
    @pytest.mark.parametrize("width", WIDTHS)
    def test_matches_numpy_reference(self, packed, queries, width):
        g, ml, metric = to_device(packed)
        qn = preprocess_vectors(queries, "cosine")
        _, ids = search(g, jnp.asarray(qn), k=10, ef=48, max_level=ml,
                        metric=metric, expansion_width=width)
        _, ids_np = search_numpy_reference(packed, queries, 10, 48,
                                           expansion_width=width)
        overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                           for a, b in zip(np.asarray(ids), ids_np)])
        assert overlap > 0.95, (width, overlap)

    def test_recall_stable_across_widths(self, packed, corpus, queries):
        g, ml, metric = to_device(packed)
        qn = preprocess_vectors(queries, "cosine")
        gt = exact_knn(queries, corpus, 10, metric="cosine")

        def rec(width):
            _, ids = search(g, jnp.asarray(qn), k=10, ef=64, max_level=ml,
                            metric=metric, expansion_width=width)
            return recall_at_k(np.asarray(ids), gt)

        base = rec(1)
        for w in WIDTHS[1:]:
            assert abs(rec(w) - base) <= 0.01, (w, rec(w), base)

    def test_iteration_counter_drops(self, packed, queries):
        g, ml, metric = to_device(packed)
        qn = jnp.asarray(preprocess_vectors(queries, "cosine"))

        def iters(width):
            _, _, it = search(g, qn, k=10, ef=64, max_level=ml,
                              metric=metric, expansion_width=width,
                              with_iters=True)
            return np.asarray(it)

        i1, i4 = iters(1), iters(4)
        assert i1.shape == (len(qn),)
        assert i4.mean() * 2 <= i1.mean(), (i1.mean(), i4.mean())

    def test_width1_bitforbit_matches_seed_loop(self, packed, queries):
        """The seed's single-pop loop, re-implemented verbatim, must equal
        width=1 of the wide-beam loop — distances and ids exactly."""
        g, ml, metric = to_device(packed)
        ef, k = 48, 10
        max_iters = 4 * ef
        n = g.vectors.shape[0]
        n_words = (n + 31) // 32

        def seed_beam(q, ep_global):           # seed _beam_search_base
            cand_d = jnp.full((ef,), jnp.inf).at[0].set(
                hs._dist_rows(q, g.vectors[ep_global][None, :], metric)[0])
            cand_id = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(
                ep_global)
            expanded = jnp.zeros((ef,), dtype=bool)
            visited = jnp.zeros((n_words,), dtype=jnp.uint32).at[
                ep_global // 32].set(
                jnp.uint32(1) << (ep_global % 32).astype(jnp.uint32))

            def cond(state):
                cand_d, _, expanded, _, it = state
                frontier = jnp.any(~expanded & jnp.isfinite(cand_d))
                return frontier & (it < max_iters)

            def body(state):
                cand_d, cand_id, expanded, visited, it = state
                masked = jnp.where(~expanded, cand_d, jnp.inf)
                c = jnp.argmin(masked)
                expanded = expanded.at[c].set(True)
                node = cand_id[c]
                nbrs = g.adj0[node]
                valid = nbrs != PAD
                safe = jnp.maximum(nbrs, 0)
                word = safe // 32
                bit = (safe % 32).astype(jnp.uint32)
                seen = (visited[word] >> bit) & jnp.uint32(1)
                fresh = valid & (seen == 0)
                add_val = jnp.where(fresh, jnp.uint32(1) << bit,
                                    jnp.uint32(0))
                visited = visited.at[word].add(add_val)
                rows = g.vectors[safe]
                d = jnp.where(fresh, hs._dist_rows(q, rows, metric), jnp.inf)
                new_id = jnp.where(fresh, nbrs, -1)
                merged_d = jnp.concatenate([cand_d, d])
                merged_id = jnp.concatenate([cand_id, new_id])
                merged_exp = jnp.concatenate([expanded, ~fresh])
                neg_top, sel = jax.lax.top_k(-merged_d, ef)
                return (-neg_top, merged_id[sel], merged_exp[sel], visited,
                        it + 1)

            state = (cand_d, cand_id, expanded, visited,
                     jnp.array(0, jnp.int32))
            cand_d, cand_id, _, _, _ = jax.lax.while_loop(cond, body, state)
            return cand_d, cand_id

        @jax.jit
        def seed_search(qs):                   # seed search(), ml/metric fixed
            def one(q):
                slot = g.entry_upper
                for layer in range(ml, 0, -1):
                    slot = hs._descend(q, g, layer - 1, slot, metric)
                ep = jnp.where(jnp.asarray(ml > 0),
                               g.upper_ids[slot], g.entry_global)
                d, ids = seed_beam(q, ep)
                return d[:k], ids[:k]

            return jax.vmap(one)(qs)

        qn = jnp.asarray(preprocess_vectors(queries, "cosine"))
        d_seed, ids_seed = seed_search(qn)
        d_new, ids_new = search(g, qn, k=k, ef=ef, max_level=ml,
                                metric=metric, expansion_width=1)
        assert (np.asarray(ids_seed) == np.asarray(ids_new)).all()
        assert np.array_equal(np.asarray(d_seed), np.asarray(d_new))

    def test_adc_hamming_require_codes(self, packed, queries):
        g, ml, _ = to_device(packed)           # no codes shipped
        qn = jnp.asarray(preprocess_vectors(queries, "cosine"))
        with pytest.raises(ValueError, match="needs g.codes"):
            search(g, qn, k=5, ef=16, max_level=ml, metric="adc")


# ---------------------------------------------------------------------------
# Quantized traversal: device vs reference per width, code-domain dispatch
# ---------------------------------------------------------------------------

def _quantized_engine(corpus, quant):
    eng = QuantixarEngine(EngineConfig(
        dim=corpus.shape[1], quantization=quant, builder="bulk",
        pq=pq_mod.PQConfig(m=8, k=16, iters=5),
        bq=bq_mod.BQConfig(bits=64)))
    eng.add(corpus)
    eng.build()
    return eng


def _proxy_queries(eng, queries):
    """The float-proxy queries + code payload engine._hnsw_pass derives."""
    cfg = eng.config
    if cfg.quantization == "pq":
        q = preprocess_vectors(queries, "cosine")
        lut = pq_mod.build_adc_lut(jnp.asarray(queries), eng._pq.codebooks,
                                   normalize_inputs=True)
        return q, lut, "adc"
    if cfg.quantization == "bq":
        packed_q = eng._bq.encode(jnp.asarray(queries))
        signs = np.asarray(bq_mod.unpack_bits(packed_q, cfg.bq.bits),
                           dtype=np.float32) * 2.0 - 1.0
        return signs, packed_q, "hamming"
    return preprocess_vectors(queries, "cosine"), None, None


class TestQuantizedWideBeam:
    @pytest.mark.parametrize("quant", ["none", "pq", "bq"])
    @pytest.mark.parametrize("width", WIDTHS)
    def test_device_matches_reference(self, corpus, queries, quant, width):
        """Code-domain device traversal == float-proxy numpy oracle: the ADC
        identity (PQ) and the Hamming/-dot affine map (BQ) make the orderings
        equal, so per-width id overlap with the width-aware oracle is high
        for every quantization mode."""
        eng = _quantized_engine(corpus, quant)
        g, ml, metric = eng._device_graph
        q, q_codes, mode = _proxy_queries(eng, queries)
        _, ids = search(g, jnp.asarray(q), k=10, ef=48, max_level=ml,
                        metric=mode or metric, expansion_width=width,
                        q_codes=q_codes)
        _, ids_np = search_numpy_reference(eng._packed, q, 10, 48,
                                           expansion_width=width)
        overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                           for a, b in zip(np.asarray(ids), ids_np)])
        assert overlap > 0.9, (quant, width, overlap)

    @pytest.mark.parametrize("quant", ["pq", "bq"])
    def test_engine_recall_across_widths(self, corpus, queries, quant):
        eng = _quantized_engine(corpus, quant)
        gt = exact_knn(queries, corpus, 10, metric="cosine")
        recalls = {}
        for w in WIDTHS:
            _, ids = eng.search(queries, 10, expansion_width=w)
            recalls[w] = recall_at_k(ids, gt)
        assert recalls[4] >= recalls[1] - 0.01, recalls

    @pytest.mark.parametrize("quant,op_name", [
        ("pq", "beam_gather_adc"), ("bq", "beam_gather_hamming")])
    def test_dispatches_through_fused_kernel_path(self, corpus, queries,
                                                  quant, op_name,
                                                  monkeypatch):
        """Quantized traversal must route every layer-0 distance block
        through the fused gather kernel dispatcher (ref oracle on CPU,
        Pallas on TPU) — never the float path."""
        calls = {"fused": 0, "float": 0}
        fused = getattr(hs.ops, op_name)
        float_path = hs.ops.beam_gather_distances

        def spy_fused(*a, **kw):
            calls["fused"] += 1
            return fused(*a, **kw)

        def spy_float(*a, **kw):
            calls["float"] += 1
            return float_path(*a, **kw)

        monkeypatch.setattr(hs.ops, op_name, spy_fused)
        monkeypatch.setattr(hs.ops, "beam_gather_distances", spy_float)
        search.clear_cache()                   # force a fresh trace
        eng = _quantized_engine(corpus, quant)
        eng.search(queries, 5, rescore=False)
        assert calls["fused"] > 0, calls       # counted at trace time
        assert calls["float"] == 0, calls

    def test_float_engine_dispatches_float_path(self, corpus, queries,
                                                monkeypatch):
        calls = {"float": 0}
        float_path = hs.ops.beam_gather_distances

        def spy(*a, **kw):
            calls["float"] += 1
            return float_path(*a, **kw)

        monkeypatch.setattr(hs.ops, "beam_gather_distances", spy)
        search.clear_cache()
        eng = _quantized_engine(corpus, "none")
        eng.search(queries, 5)
        assert calls["float"] > 0

    def test_graph_ships_codes(self, corpus):
        for quant, dtype in (("pq", np.uint8), ("bq", np.uint32)):
            eng = _quantized_engine(corpus, quant)
            g = eng._device_graph[0]
            assert g.codes is not None
            assert g.codes.dtype == dtype
            assert g.codes.shape[0] == eng._packed.n


# ---------------------------------------------------------------------------
# Filtered (masked) search under wide beams
# ---------------------------------------------------------------------------

class TestFilteredWideBeam:
    @pytest.mark.parametrize("width", [1, 4])
    def test_masked_search_respects_mask(self, corpus, queries, width):
        eng = QuantixarEngine(EngineConfig(dim=DIM, builder="bulk"))
        eng.add(corpus)
        eng.build()
        rng = np.random.RandomState(0)
        mask = rng.rand(N) < 0.4               # above the flat-route cutoff
        d, ids = eng.search(queries, 10, mask=mask, expansion_width=width)
        live = ids[ids >= 0]
        assert mask[live].all()                # nothing masked leaks out
        # recall vs the exact masked ground truth
        allowed = np.where(mask)[0]
        gt_local = exact_knn(queries, corpus[allowed], 10, metric="cosine")
        gt = allowed[gt_local]
        assert recall_at_k(ids, gt) > 0.85

    def test_width_override_wires_through_engine(self, corpus, queries):
        eng = QuantixarEngine(EngineConfig(dim=DIM, builder="bulk"))
        assert eng.effective_expansion_width() == 4          # hnsw default
        assert eng.effective_expansion_width(2) == 2         # per-query
        eng.config.expansion_width = 3                       # engine-level
        assert eng.effective_expansion_width() == 3
        with pytest.raises(ValueError, match="expansion_width"):
            eng.effective_expansion_width(0)


# ---------------------------------------------------------------------------
# Config / wire-protocol threading
# ---------------------------------------------------------------------------

class TestWidthThreading:
    def test_hnsw_config_validates(self):
        with pytest.raises(ValueError, match="expansion_width"):
            HNSWConfig(expansion_width=0)

    def test_schema_roundtrip(self):
        from repro.api.schema import CollectionSchema, VectorField
        schema = CollectionSchema(
            name="c", vector=VectorField(
                dim=8, hnsw=HNSWConfig(expansion_width=2)))
        restored = CollectionSchema.from_dict(schema.to_dict())
        assert restored.vector.hnsw.expansion_width == 2

    def test_search_request_roundtrip(self):
        from repro.api import requests as rq
        req = rq.Search(collection="c", vector=[0.0, 1.0], k=3,
                        expansion_width=2)
        decoded = rq.decode_request(req.to_dict())
        assert decoded.expansion_width == 2
        # absent on the wire -> None (schema default applies server-side)
        d = req.to_dict()
        del d["body"]["expansion_width"]
        assert rq.decode_request(d).expansion_width is None

    def test_query_builder_validates(self):
        from repro.api import CollectionSchema, Database, VectorField
        from repro.api.schema import SchemaError
        db = Database()
        col = db.create_collection(CollectionSchema(
            name="t", vector=VectorField(dim=4, builder="bulk")))
        col.upsert(["a", "b"], np.eye(4, dtype=np.float32)[:2])
        with pytest.raises(SchemaError, match="expansion_width"):
            col.query(np.ones(4)).expansion_width(0)
        hits = col.query(np.eye(4)[0]).top_k(1).expansion_width(2).run()
        assert hits[0].id == "a"
        db.close()
