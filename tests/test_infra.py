"""Data pipeline, optimizer, serving batcher, HLO cost analyzer."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (Prefetcher, fashion_mnist_like, host_slice,
                        lm_batches, sift_like, zipf_tokens)
from repro.optim import AdamWConfig, adamw
from repro.serving.batcher import QuorumFanout, RequestBatcher


class TestData:
    def test_generators_deterministic(self):
        a, b = sift_like(100, seed=3), sift_like(100, seed=3)
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, sift_like(100, seed=4))

    def test_sift_like_statistics(self):
        x = sift_like(500)
        assert x.shape == (500, 128) and (x >= 0).all()
        norms = np.linalg.norm(x, axis=1)
        np.testing.assert_allclose(norms, 512.0, rtol=0.05)

    def test_fashion_mnist_like_statistics(self):
        x = fashion_mnist_like(300)
        assert x.shape == (300, 784) and (x >= 0).all()
        assert 0 < x.mean() < 255

    def test_zipf_tokens_bounded_and_skewed(self):
        rng = np.random.RandomState(0)
        t = zipf_tokens(rng, (10_000,), vocab=1000)
        assert t.min() >= 0 and t.max() < 1000
        counts = np.bincount(t, minlength=1000)
        assert counts[:10].sum() > counts[500:510].sum()

    def test_lm_batches_shapes(self):
        it = lm_batches(500, batch=4, seq_len=16)
        b = next(it)
        assert b.tokens.shape == b.targets.shape == (4, 16)
        # next-token alignment
        rawstream_ok = (b.tokens[:, 1:] == b.targets[:, :-1]).all()
        assert rawstream_ok

    def test_host_slice_partitions(self):
        slices = [host_slice(64, 4, h) for h in range(4)]
        rows = np.concatenate([np.arange(64)[s] for s in slices])
        np.testing.assert_array_equal(np.sort(rows), np.arange(64))
        with pytest.raises(ValueError):
            host_slice(10, 3, 0)

    def test_prefetcher_order_and_errors(self):
        assert list(Prefetcher(iter(range(10)), depth=3)) == list(range(10))

        def boom():
            yield 1
            raise RuntimeError("io error")

        pf = Prefetcher(boom())
        assert next(pf) == 1
        with pytest.raises(RuntimeError):
            next(pf)
            next(pf)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=100,
                          warmup_steps=1, schedule="constant")
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(60):
            grads = {"w": 2.0 * params["w"]}
            params, state, _ = adamw.apply_updates(params, grads, state, cfg)
        # Adam oscillates near the optimum at this lr; far from [5, -3]
        assert float(jnp.abs(params["w"]).max()) < 0.6

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip_norm=1.0, total_steps=10)
        params = {"w": jnp.ones(4)}
        state = adamw.init(params)
        _, _, gnorm = adamw.apply_updates(
            params, {"w": jnp.full(4, 100.0)}, state, cfg)
        assert float(gnorm) == pytest.approx(200.0)

    @pytest.mark.parametrize("sched", ["cosine", "linear", "constant"])
    def test_schedules(self, sched):
        cfg = AdamWConfig(lr=1.0, schedule=sched, warmup_steps=10,
                          total_steps=100, min_lr_ratio=0.1)
        f = adamw.make_schedule(cfg)
        assert float(f(jnp.array(0))) == pytest.approx(0.0)
        assert float(f(jnp.array(10))) == pytest.approx(1.0, rel=0.1)
        if sched != "constant":
            assert float(f(jnp.array(100))) == pytest.approx(0.1, rel=0.05)

    def test_weight_decay_only_on_matrices(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, total_steps=10,
                          warmup_steps=1, schedule="constant")
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = adamw.init(params)
        p2, _, _ = adamw.apply_updates(
            params, {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))},
            state, cfg)
        assert float(p2["w"][0, 0]) < 1.0      # decayed
        assert float(p2["b"][0]) == 1.0        # not decayed


class TestServing:
    def test_batcher_batches_and_answers(self):
        calls = []

        def search(q, k):
            calls.append(len(q))
            d = np.zeros((len(q), k), np.float32)
            ids = np.tile(np.arange(k), (len(q), 1))
            return d, ids

        b = RequestBatcher(search, max_batch=8, max_wait_ms=20)
        futs = [b.submit(np.zeros(4, np.float32), 3) for _ in range(10)]
        outs = [f.result(timeout=5) for f in futs]
        b.close()
        assert all(ids.shape == (3,) for _, ids in outs)
        assert b.requests_served == 10
        assert b.batches_served <= 10    # some batching happened

    def test_batcher_counters_consistent_under_concurrency(self):
        # regression: counters are mutated by the worker under _state_lock
        # and read via stats() under the same lock, so a snapshot can never
        # show more requests resolved than counted
        import threading

        def search(q, k):
            return (np.zeros((len(q), k), np.float32),
                    np.tile(np.arange(k), (len(q), 1)))

        b = RequestBatcher(search, max_batch=4, max_wait_ms=1)
        done = []

        def client():
            for _ in range(25):
                fut = b.submit(np.zeros(4, np.float32), 2)
                fut.result(timeout=5)
                done.append(1)

        readers_ok = []

        def reader():
            deadline = time.time() + 20
            while len(done) < 100 and time.time() < deadline:
                s = b.stats()
                readers_ok.append(s["requests_served"] >= 0
                                  and s["batches_served"]
                                  <= s["requests_served"])

        threads = [threading.Thread(target=client) for _ in range(4)] \
            + [threading.Thread(target=reader)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        b.close()
        assert len(done) == 100
        assert all(readers_ok)
        assert b.stats()["requests_served"] == 100

    def test_quorum_fanout_tolerates_straggler(self):
        def fast(q, k):
            return np.zeros((len(q), k)), np.zeros((len(q), k), np.int32)

        def slow(q, k):
            time.sleep(1.0)
            return np.zeros((len(q), k)), np.ones((len(q), k), np.int32)

        qf = QuorumFanout([fast, fast, slow], deadline_ms=150, min_quorum=2)
        d, ids = qf.search(np.zeros((2, 4), np.float32), 3)
        assert qf.last_responders >= 2
        assert d.shape == (2, 3)

    def test_quorum_raises_below_minimum(self):
        def dead(q, k):
            raise RuntimeError("shard down")

        qf = QuorumFanout([dead, dead], deadline_ms=50, min_quorum=1)
        with pytest.raises(TimeoutError):
            qf.search(np.zeros((1, 4), np.float32), 2)


class TestHloCost:
    """Calibration: the trip-count-aware analyzer vs known programs."""

    def test_single_matmul_flops_exact(self):
        from benchmarks import hlo_cost
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
        h = hlo_cost.analyze(c.as_text())
        assert h.flops == pytest.approx(2 * 256 ** 3, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        from benchmarks import hlo_cost
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)

        def f(x, ws):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            return jax.lax.scan(body, x, ws)[0]

        c = jax.jit(f).lower(a, w).compile()
        h = hlo_cost.analyze(c.as_text())
        assert h.flops == pytest.approx(12 * 2 * 128 ** 3, rel=0.05)
        assert any(t == 12 for _, t in h.loops)

    def test_nested_scan(self):
        from benchmarks import hlo_cost
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)

        def g(x, ws):
            def outer(cc, wi):
                def inner(c2, _):
                    return jnp.tanh(c2 @ wi), None
                return jax.lax.scan(inner, cc, None, length=5)[0], None
            return jax.lax.scan(outer, x, ws)[0]

        c = jax.jit(g).lower(a, w).compile()
        h = hlo_cost.analyze(c.as_text())
        assert h.flops == pytest.approx(30 * 2 * 64 ** 3, rel=0.05)

    def test_xla_cost_analysis_undercounts_loops(self):
        """The reason hlo_cost exists — documents the XLA-CPU behaviour."""
        from benchmarks import hlo_cost
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)

        def f(x, ws):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            return jax.lax.scan(body, x, ws)[0]

        c = jax.jit(f).lower(a, w).compile()
        xla_flops = hlo_cost.xla_cost_dict(c).get("flops", 0)
        assert xla_flops < 0.2 * (12 * 2 * 128 ** 3)


class TestGradCompression:
    """int8 + error feedback (DCN gradient compression, DESIGN.md §6)."""

    def test_roundtrip_error_bounded(self):
        from repro.optim import compress_decompress, init_error_feedback
        g = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64))}
        ef = init_error_feedback(g)
        deq, ef2 = compress_decompress(g, ef)
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.51

    def test_error_feedback_carries_residual(self):
        from repro.optim import compress_decompress, init_error_feedback
        g = {"w": jnp.full((8,), 0.001)}     # below one quantization step?
        ef = init_error_feedback(g)
        total = jnp.zeros((8,))
        for _ in range(10):
            deq, ef = compress_decompress(g, ef)
            total = total + deq["w"]
        # EF ensures the long-run average is unbiased
        np.testing.assert_allclose(np.asarray(total), 0.01, rtol=0.05)

    def test_converges_with_compression(self):
        from repro.optim import compress_decompress, init_error_feedback
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=100,
                          warmup_steps=1, schedule="constant")
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        ef = init_error_feedback(params)
        for _ in range(80):
            grads = {"w": 2.0 * params["w"]}
            grads, ef = compress_decompress(grads, ef)
            params, state, _ = adamw.apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.6

    def test_ratio(self):
        from repro.optim import compression_ratio
        assert compression_ratio({"w": jnp.ones((1000, 1000))}) > 3.9
