import os
import sys

# src layout + repo root (benchmarks/) importable without install
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

# tests must see exactly the real device count (dryrun sets 512 in ITS process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
