"""QuantixarEngine: the composition matrix, MEVS, rescore, persistence."""

import numpy as np
import pytest

from repro.core import (And, EngineConfig, Not, Or, Predicate,
                        QuantixarEngine, exact_knn)
from repro.core.bq import BQConfig
from repro.core.hnsw_build import HNSWConfig
from repro.core.pq import PQConfig
from repro.data.synthetic import gaussian_mixture

N, DIM = 1000, 32


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=10, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(16, DIM, n_clusters=10, scale=0.2, seed=3)


@pytest.fixture(scope="module")
def meta():
    return [{"cat": int(i % 5), "score": float(i) / N} for i in range(N)]


def _recall(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / gt.shape[1]
                    for a, b in zip(ids, gt)])


def _engine(corpus, meta, **kw):
    kw.setdefault("hnsw", HNSWConfig(M=12, ef_construction=60))
    kw.setdefault("pq", PQConfig(m=8, k=32, iters=8))
    kw.setdefault("bq", BQConfig(bits=256))
    kw.setdefault("builder", "bulk")
    eng = QuantixarEngine(EngineConfig(dim=DIM, **kw))
    eng.add(corpus, meta)
    eng.build()
    return eng


@pytest.mark.parametrize("index", ["flat", "hnsw", "ivf"])
@pytest.mark.parametrize("quant", ["none", "pq", "bq"])
def test_composition_matrix(corpus, queries, meta, index, quant):
    """Every index × quantization combination reaches sane recall."""
    eng = _engine(corpus, meta, index=index, quantization=quant)
    _, ids = eng.search(queries, 10)
    gt = exact_knn(queries, corpus, 10, metric="cosine")
    floor = 1.0 if (index, quant) == ("flat", "none") else \
        0.6 if index == "ivf" else 0.7
    r = _recall(ids, gt)
    assert r >= floor - 1e-9, (index, quant, r)


class TestMEVS:
    def test_equality_filter(self, corpus, queries, meta):
        eng = _engine(corpus, meta, index="hnsw")
        _, ids = eng.search(queries, 5, flt=Predicate("cat", "eq", 2))
        valid = ids[ids >= 0]
        assert len(valid) and all(meta[i]["cat"] == 2 for i in valid)

    def test_filter_then_search_is_exact_at_low_selectivity(
            self, corpus, queries, meta):
        """The paper's MEVS semantics: filter first, then exact search."""
        eng = _engine(corpus, meta, index="hnsw")
        flt = And([Predicate("cat", "eq", 1),
                   Predicate("score", "lt", 0.2)])   # ~4% selectivity
        d, ids = eng.search(queries, 5, flt=flt)
        mask = eng.metadata.evaluate(flt)
        allowed = np.where(mask)[0]
        sub = corpus[allowed]
        gt_local = exact_knn(queries, sub, 5, metric="cosine")
        gt = allowed[gt_local]
        assert _recall(ids, gt) > 0.99

    def test_boolean_operators(self, corpus, meta):
        eng = _engine(corpus, meta, index="flat")
        m_or = eng.metadata.evaluate(Or([Predicate("cat", "eq", 0),
                                         Predicate("cat", "eq", 1)]))
        m_not = eng.metadata.evaluate(Not(Predicate("cat", "eq", 0)))
        assert m_or.sum() == sum(1 for r in meta if r["cat"] in (0, 1))
        assert m_not.sum() == sum(1 for r in meta if r["cat"] != 0)

    def test_in_and_range_ops(self, corpus, meta):
        eng = _engine(corpus, meta, index="flat")
        m = eng.metadata.evaluate(Predicate("cat", "in", [2, 3]))
        assert m.sum() == sum(1 for r in meta if r["cat"] in (2, 3))
        m2 = eng.metadata.evaluate(Predicate("score", "ge", 0.5))
        assert m2.sum() == sum(1 for r in meta if r["score"] >= 0.5)


class TestRescore:
    def test_rescore_improves_bq_recall(self, corpus, queries, meta):
        gt = exact_knn(queries, corpus, 10, metric="cosine")
        base_cfg = dict(index="flat", quantization="bq",
                        bq=BQConfig(bits=64))
        eng_no = _engine(corpus, meta, rescore=False, **base_cfg)
        eng_yes = _engine(corpus, meta, rescore=True, **base_cfg)
        _, ids_no = eng_no.search(queries, 10)
        _, ids_yes = eng_yes.search(queries, 10)
        assert _recall(ids_yes, gt) >= _recall(ids_no, gt)


class TestPersistence:
    def test_state_roundtrip_identical_results(self, corpus, queries, meta):
        eng = _engine(corpus, meta, index="hnsw", quantization="pq")
        d1, i1 = eng.search(queries, 10)
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        d2, i2 = eng2.search(queries, 10)
        assert (i1 == i2).all()
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)

    def test_metadata_survives_roundtrip(self, corpus, queries, meta):
        eng = _engine(corpus, meta, index="flat")
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        _, ids = eng2.search(queries, 5, flt=Predicate("cat", "eq", 4))
        valid = ids[ids >= 0]
        assert len(valid) and all(meta[i]["cat"] == 4 for i in valid)


class TestValidation:
    def test_dim_mismatch_rejected(self, corpus):
        eng = QuantixarEngine(EngineConfig(dim=16))
        with pytest.raises(ValueError):
            eng.add(corpus)   # 32-dim into 16-dim engine

    def test_empty_build_rejected(self):
        eng = QuantixarEngine(EngineConfig(dim=8))
        with pytest.raises(RuntimeError):
            eng.build()

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError):
            EngineConfig(dim=8, index="lsh-forest")
        with pytest.raises(ValueError):
            EngineConfig(dim=8, quantization="int4")

    def test_stats(self, corpus, meta):
        eng = _engine(corpus, meta, index="hnsw", quantization="pq")
        s = eng.stats()
        assert s["n"] == N and s["compression"] == 16.0
        assert s["build_seconds"] > 0


class TestIVF:
    """Beyond-paper IVF index (+ IVF-PQ composition)."""

    def test_nprobe_recall_knob(self, corpus, queries):
        from repro.core import IVFConfig
        gt = exact_knn(queries, corpus, 10, metric="cosine")

        def recall_at(nprobe):
            eng = QuantixarEngine(EngineConfig(
                dim=DIM, index="ivf",
                ivf=IVFConfig(nlist=32, nprobe=nprobe)))
            eng.add(corpus)
            eng.build()
            _, ids = eng.search(queries, 10)
            return _recall(ids, gt)

        low, high = recall_at(2), recall_at(16)
        assert high > low and high > 0.9, (low, high)

    def test_ivf_pq_composition(self, corpus, queries):
        eng = QuantixarEngine(EngineConfig(dim=DIM, index="ivf",
                                           quantization="pq"))
        eng.add(corpus)
        eng.build()
        gt = exact_knn(queries, corpus, 10, metric="cosine")
        _, ids = eng.search(queries, 10)
        assert _recall(ids, gt) > 0.5

    def test_ivf_mevs_filter(self, corpus, queries, meta):
        eng = QuantixarEngine(EngineConfig(dim=DIM, index="ivf"))
        eng.add(corpus, meta)
        eng.build()
        _, ids = eng.search(queries, 5, flt=Predicate("cat", "eq", 1))
        valid = ids[ids >= 0]
        assert len(valid) and all(meta[i]["cat"] == 1 for i in valid)

    def test_ivf_lists_cover_corpus(self, corpus):
        from repro.core import IVFConfig
        from repro.core.ivf import IVFIndex, PAD
        import jax.numpy as jnp
        ivf = IVFIndex(IVFConfig(nlist=16))
        ivf.train(jnp.asarray(corpus))
        ivf.build_lists(jnp.asarray(corpus))
        lists = np.asarray(ivf.lists)
        members = lists[lists != PAD]
        assert len(members) == len(corpus)            # every row assigned
        assert len(set(members.tolist())) == len(corpus)   # exactly once

    def test_ivf_persistence(self, corpus, queries):
        eng = QuantixarEngine(EngineConfig(dim=DIM, index="ivf"))
        eng.add(corpus)
        eng.build()
        d1, i1 = eng.search(queries, 10)
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        d2, i2 = eng2.search(queries, 10)
        assert (i1 == i2).all()
