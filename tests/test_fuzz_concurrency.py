"""Thread-fuzz stress tests under instrumented (traced) locks.

One collection is hammered by concurrent upsert / search / delete /
compact / stats / checkpoint traffic while every collection lock is a
`TracedRLock` feeding a `LockMonitor`.  The suite asserts three things:

  * no worker thread died (exceptions other than the typed transient
    retry/closed errors fail the test);
  * the observed lock-acquisition-order graph is acyclic — a cycle is a
    potential deadlock even if this run's schedule never collided;
  * the live wait-for detector stayed quiet (a real deadlock raises
    `DeadlockDetected` inside an acquire instead of hanging CI).

The small sizes keep this suite fast; the CI `fuzz-smoke` step runs it
under a hard pytest timeout so a real deadlock can never wedge a runner.
"""

import threading
import time

import numpy as np
import pytest

from repro.api import CollectionSchema, Database, KeywordField, VectorField
from repro.api.collection import CollectionClosed, QueryRetriesExhausted
from repro.api.schema import BatcherConfig
from repro.serving.batcher import BatcherClosed
from tools.qlint.runtime import (DeadlockDetected, LockMonitor, TracedRLock,
                                 instrument_collection)

DIM = 16


def _make_collection(monitor, name="fuzz"):
    schema = CollectionSchema(
        name=name, vector=VectorField(dim=DIM, index="flat"),
        fields=(KeywordField("tag"),),
        batcher=BatcherConfig(max_batch=8, max_wait_ms=1.0))
    col = Database().create_collection(schema)
    rng = np.random.default_rng(0)
    col.upsert([f"seed-{i}" for i in range(64)],
               rng.normal(size=(64, DIM)).astype(np.float32),
               [{"tag": f"t{i % 4}"} for i in range(64)])
    instrument_collection(col, monitor)
    return col


class TestTracedLockPrimitives:
    def test_reentrant_acquire_is_not_an_edge(self):
        mon = LockMonitor()
        lock = TracedRLock("a", mon)
        with lock:
            with lock:          # RLock semantics: depth 2, no new edge
                pass
        assert mon.order_edges() == {}
        assert mon.acquires == 1

    def test_order_edges_recorded(self):
        mon = LockMonitor()
        a, b = TracedRLock("a", mon), TracedRLock("b", mon)
        with a:
            with b:
                pass
        assert set(mon.order_edges()) == {("a", "b")}
        mon.assert_no_cycles()

    def test_order_cycle_detected_across_threads(self):
        mon = LockMonitor()
        a, b = TracedRLock("a", mon), TracedRLock("b", mon)
        with a:
            with b:
                pass

        def reverse():
            with b:
                with a:
                    pass

        t = threading.Thread(target=reverse)
        t.start()
        t.join()
        assert [set(c) for c in mon.order_cycles()] == [{"a", "b"}]
        with pytest.raises(AssertionError, match="lock-order cycles"):
            mon.assert_no_cycles()

    def test_live_wait_for_cycle_raises_instead_of_hanging(self):
        # classic ABBA: T1 holds a and wants b, T2 holds b and wants a.
        # Whichever publishes its wait second must see the cycle and raise
        # (the detector's check+publish is atomic under the monitor mutex),
        # which unblocks the other thread — no hang, no timeout.
        mon = LockMonitor()
        a, b = TracedRLock("a", mon), TracedRLock("b", mon)
        barrier = threading.Barrier(2)
        detected = []

        def worker(first, second):
            with first:
                barrier.wait(timeout=5)
                try:
                    with second:
                        pass
                except DeadlockDetected as exc:
                    detected.append(exc)

        t1 = threading.Thread(target=worker, args=(a, b), daemon=True)
        t2 = threading.Thread(target=worker, args=(b, a), daemon=True)
        t1.start()
        t2.start()
        t1.join(timeout=10)
        t2.join(timeout=10)
        assert not t1.is_alive() and not t2.is_alive()
        assert detected and "wait-for cycle" in str(detected[0])

    def test_stall_recorded_not_raised(self):
        mon = LockMonitor(stall_after=0.01)
        lock = TracedRLock("slow", mon)
        with lock:
            time.sleep(0.03)
        stalls = mon.stalls()
        assert stalls and stalls[0].kind == "hold" \
            and stalls[0].lock == "slow"

    def test_release_unheld_raises(self):
        mon = LockMonitor()
        lock = TracedRLock("x", mon)
        with pytest.raises(RuntimeError, match="un-acquired"):
            lock.release()


class TestCollectionFuzz:
    def test_concurrent_traffic_no_deadlock(self):
        mon = LockMonitor(stall_after=30.0)
        col = _make_collection(mon)
        stop = time.monotonic() + 2.0
        errors = []
        rng_lock = threading.Lock()
        rng = np.random.default_rng(7)

        def vecs(n):
            with rng_lock:      # Generator is not thread-safe
                return rng.normal(size=(n, DIM)).astype(np.float32)

        def guard(fn):
            def run():
                i = 0
                while time.monotonic() < stop:
                    try:
                        fn(i)
                    except (QueryRetriesExhausted, TimeoutError):
                        pass    # transient: compact churn / queue pressure
                    except Exception as exc:     # noqa: BLE001
                        errors.append(exc)
                        return
                    i += 1
            return run

        def upserter(i):
            col.upsert([f"u-{i % 97}"], vecs(1), [{"tag": "u"}])

        def searcher(i):
            hits = col.query(vecs(1)[0]).top_k(3).run(timeout=10.0)
            assert isinstance(hits, list)

        def direct_searcher(i):
            col.search(vecs(2), k=3)    # 2-D: direct path under the lock

        def deleter(i):
            col.delete([f"u-{(i * 13) % 97}"])

        def compactor(i):
            col.compact()
            time.sleep(0.01)    # let writes accumulate between rebuilds

        def statser(i):
            s = col.stats()
            assert s["live"] >= 0 and s["serving_queue_depth"] >= 0
            len(col), col.tombstones, "u-1" in col

        workers = ([threading.Thread(target=guard(upserter), daemon=True)
                    for _ in range(2)]
                   + [threading.Thread(target=guard(searcher), daemon=True)
                      for _ in range(3)]
                   + [threading.Thread(target=guard(f), daemon=True)
                      for f in (direct_searcher, deleter, compactor,
                                statser)])
        for w in workers:
            w.start()
        for w in workers:
            w.join(timeout=30)
        assert not any(w.is_alive() for w in workers), "fuzz worker hung"
        assert not errors, f"fuzz worker raised: {errors[:3]}"
        # the whole point: the traffic above exercised every lock pair and
        # the observed acquisition-order graph must be acyclic
        mon.assert_no_cycles()
        assert mon.acquires > 100, mon.report()
        # searches actually flowed through the traced batcher path
        assert col.stats()["serving_requests_served"] > 0

    def test_close_race_is_typed_and_acyclic(self):
        mon = LockMonitor()
        col = _make_collection(mon, name="fuzz-close")
        errors = []
        started = threading.Event()

        def searcher():
            started.set()
            while True:
                try:
                    col.query(np.zeros(DIM, np.float32)).top_k(2) \
                        .run(timeout=10.0)
                except (CollectionClosed, BatcherClosed,
                        QueryRetriesExhausted):
                    return      # the documented post-close contract
                except Exception as exc:     # noqa: BLE001
                    errors.append(exc)
                    return

        threads = [threading.Thread(target=searcher, daemon=True)
                   for _ in range(4)]
        for t in threads:
            t.start()
        started.wait(timeout=5)
        time.sleep(0.05)        # let queries flow before the rug-pull
        col.close()
        for t in threads:
            t.join(timeout=10)
        assert not any(t.is_alive() for t in threads)
        assert not errors, f"close race leaked untyped error: {errors[:3]}"
        # close() holds _lock then _batcher_init_lock; nothing may have
        # taken them in the reverse order
        mon.assert_no_cycles()
        with pytest.raises(CollectionClosed):
            col.count()
