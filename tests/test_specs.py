"""Dry-run cell specs: the 40-cell matrix, skip rules, spec shapes.

Validates the assignment's cell accounting without compiling anything
(repro.launch.dryrun itself is never imported here — it sets the 512-device
XLA flag for its own process only).
"""

import jax.numpy as jnp
import pytest

from repro.configs import arch_ids, get_config
from repro.launch import specs as SP

LONG_RUNNERS = {"recurrentgemma-9b", "mixtral-8x7b", "xlstm-1.3b"}


def test_cell_matrix_is_40():
    assert len(arch_ids()) == 10
    assert len(SP.SHAPES) == 4
    assert len(arch_ids()) * len(SP.SHAPES) == 40


def test_long_500k_skip_rules_match_assignment():
    runs, skips = set(), set()
    for arch in arch_ids():
        ok, why = SP.cell_supported(get_config(arch), "long_500k")
        (runs if ok else skips).add(arch)
        if not ok:
            assert "full-attention" in why     # skips carry their reason
    assert runs == LONG_RUNNERS
    assert len(skips) == 7


@pytest.mark.parametrize("arch", arch_ids())
def test_every_other_shape_supported(arch):
    cfg = get_config(arch)
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        ok, _ = SP.cell_supported(cfg, shape)
        assert ok, (arch, shape)


@pytest.mark.parametrize("arch", arch_ids())
def test_train_specs_shapes(arch):
    cfg = get_config(arch)
    cell = SP.SHAPES["train_4k"]
    specs = SP.lm_train_specs(cfg, cell)
    assert specs["tokens"].shape == (256, 4096)
    assert specs["tokens"].dtype == jnp.int32
    if cfg.is_enc_dec:   # audio frontend stub: precomputed frame embeddings
        assert specs["frames"].shape == (256, 4096, cfg.d_model)


@pytest.mark.parametrize("arch", arch_ids())
def test_decode_specs_state_is_bounded_for_windowed_archs(arch):
    import jax
    cfg = get_config(arch)
    cell = SP.SHAPES["decode_32k"]
    tokens, state = SP.lm_decode_specs(cfg, cell)
    assert tokens.shape == (128, 1)
    # every leaf is abstract (no allocation) and KV caches respect windows
    leaves = jax.tree_util.tree_leaves(state)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    if arch == "mixtral-8x7b":
        kv = [l for l in leaves if l.ndim == 5]
        assert kv and all(l.shape[2] <= cfg.window for l in kv)  # ring cache


def test_long_500k_states_stay_small():
    """The sub-quadratic archs must not allocate 500k-token buffers."""
    import jax
    for arch in LONG_RUNNERS:
        cfg = get_config(arch)
        tokens, state = SP.lm_decode_specs(cfg, SP.SHAPES["long_500k"])
        nbytes = sum(
            int(jnp.prod(jnp.array(l.shape))) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(state))
        # xlstm matrix states are the biggest legitimate state (B=1)
        assert nbytes < 2 << 30, (arch, nbytes)


def test_db_specs_row_padding():
    from repro.configs.quantixar_db import CONFIG
    sp = SP.db_specs(CONFIG, "flat", row_multiple=512)
    assert sp["corpus"].shape[0] % 512 == 0
    assert sp["corpus"].shape[0] >= CONFIG.n_vectors


def test_model_flops_ordering():
    """train > prefill > decode for the same arch; MoE active < total."""
    from benchmarks import roofline as RL
    cfg = get_config("mixtral-8x7b")
    n_active = cfg.active_param_count()
    train = RL.train_model_flops(n_active, 256 * 4096)
    prefill = 2.0 * n_active * 32 * 32768
    decode = RL.decode_model_flops(n_active, 128)
    assert train > prefill > decode > 0
