"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Every kernel is executed with interpret=True (kernel body evaluated on CPU)
and asserted allclose against ref.py — the correctness contract required for
each kernel (assignment deliverable c).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # deterministic shim keeps properties runnable
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.beam_gather import (beam_gather_adc_kernel,
                                       beam_gather_hamming_kernel,
                                       beam_gather_kernel)
from repro.kernels.hamming import hamming_kernel
from repro.kernels.l2 import l2_distance_kernel
from repro.kernels.pq_adc import pq_adc_kernel

RNG = np.random.RandomState(0)


class TestL2Kernel:
    @pytest.mark.parametrize("q,n,d", [
        (8, 128, 64),         # tile-aligned
        (7, 300, 130),        # padding on every axis
        (64, 1024, 784),      # fashion-mnist dims
        (1, 33, 128),         # single query, sift dims
        (3, 50, 16),          # tiny
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, q, n, d, dtype):
        qs = jnp.asarray(RNG.randn(q, d), dtype)
        xs = jnp.asarray(RNG.randn(n, d), dtype)
        got = l2_distance_kernel(qs, xs, tq=16, tn=128, tk=64, interpret=True)
        want = ref.l2_distance_ref(qs, xs)
        tol = 2e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("q,n,d", [(9, 200, 96), (16, 128, 128)])
    def test_dot_mode(self, q, n, d):
        qs = jnp.asarray(RNG.randn(q, d), jnp.float32)
        xs = jnp.asarray(RNG.randn(n, d), jnp.float32)
        got = l2_distance_kernel(qs, xs, mode="dot", tq=8, tn=64, tk=32,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot_distance_ref(qs, xs)),
                                   rtol=2e-4, atol=2e-4)

    def test_tile_shape_independence(self):
        """Result must not depend on the BlockSpec tiling chosen."""
        qs = jnp.asarray(RNG.randn(13, 70), jnp.float32)
        xs = jnp.asarray(RNG.randn(111, 70), jnp.float32)
        a = l2_distance_kernel(qs, xs, tq=4, tn=32, tk=16, interpret=True)
        b = l2_distance_kernel(qs, xs, tq=16, tn=256, tk=70, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


class TestPQADCKernel:
    @pytest.mark.parametrize("q,n,m,k", [
        (5, 700, 8, 64),
        (2, 100, 16, 256),    # uint8 full range
        (9, 333, 4, 16),      # fast-scan-like small k
        (1, 64, 32, 256),
    ])
    def test_matches_ref(self, q, n, m, k):
        lut = jnp.asarray(RNG.rand(q, m, k), jnp.float32)
        codes = jnp.asarray(RNG.randint(0, k, (n, m)), jnp.uint8)
        got = pq_adc_kernel(lut, codes, tq=4, tn=256, interpret=True)
        want = ref.pq_adc_ref(lut, codes)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_uint16_codes(self):
        lut = jnp.asarray(RNG.rand(2, 4, 512), jnp.float32)
        codes = jnp.asarray(RNG.randint(0, 512, (50, 4)), jnp.uint16)
        got = pq_adc_kernel(lut, codes, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.pq_adc_ref(lut, codes)),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 6), st.integers(10, 80), st.integers(1, 3),
           st.integers(0, 1000))
    def test_property_sweep(self, q, n, m_exp, seed):
        m = 2 ** m_exp
        rng = np.random.RandomState(seed)
        lut = jnp.asarray(rng.rand(q, m, 16), jnp.float32)
        codes = jnp.asarray(rng.randint(0, 16, (n, m)), jnp.uint8)
        got = pq_adc_kernel(lut, codes, tq=2, tn=128, m_chunk=2,
                            interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.pq_adc_ref(lut, codes)),
                                   rtol=1e-5, atol=1e-5)


class TestHammingKernel:
    @pytest.mark.parametrize("q,n,w", [
        (5, 700, 8), (33, 129, 4), (2, 50, 16), (1, 1, 1),
    ])
    def test_matches_ref(self, q, n, w):
        qc = jnp.asarray(RNG.randint(0, 2 ** 31, (q, w)), jnp.uint32)
        xc = jnp.asarray(RNG.randint(0, 2 ** 31, (n, w)), jnp.uint32)
        got = hamming_kernel(qc, xc, tq=16, tn=128, interpret=True)
        want = ref.hamming_ref(qc, xc)
        assert (np.asarray(got) == np.asarray(want)).all()

    def test_all_ones_and_zeros(self):
        z = jnp.zeros((3, 4), jnp.uint32)
        o = jnp.full((5, 4), 0xFFFFFFFF, jnp.uint32)
        got = np.asarray(hamming_kernel(z, o, interpret=True))
        assert (got == 128).all()


class TestBeamGatherKernel:
    """Fused gather-distance kernels (wide-beam traversal) vs refs."""

    @pytest.mark.parametrize("n,d,l,tb", [
        (256, 64, 128, 32),    # tile-aligned
        (100, 48, 37, 16),     # padding on the id axis
        (50, 16, 1, 8),        # single id (the entry-point init call)
        (33, 130, 65, 64),
    ])
    @pytest.mark.parametrize("mode", ["l2", "dot"])
    def test_matches_ref(self, n, d, l, tb, mode):
        corpus = jnp.asarray(RNG.randn(n, d), jnp.float32)
        q = jnp.asarray(RNG.randn(d), jnp.float32)
        ids = jnp.asarray(RNG.randint(0, n, l), jnp.int32)
        got = beam_gather_kernel(q, ids, corpus, mode=mode, tb=tb,
                                 interpret=True)
        want = (ref.beam_gather_l2_ref(q, ids, corpus) if mode == "l2"
                else ref.beam_gather_dot_ref(q, ids, corpus))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_duplicate_and_boundary_ids(self):
        """Gathers are arbitrary: repeated rows and rows 0 / N-1 must work."""
        corpus = jnp.asarray(RNG.randn(40, 24), jnp.float32)
        q = jnp.asarray(RNG.randn(24), jnp.float32)
        ids = jnp.asarray([0, 39, 7, 7, 7, 0, 39, 13], jnp.int32)
        got = beam_gather_kernel(q, ids, corpus, tb=4, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(ref.beam_gather_l2_ref(q, ids, corpus)),
            rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("n,m,k,l", [
        (200, 8, 64, 48), (77, 4, 16, 13), (64, 16, 256, 128),
    ])
    def test_adc_matches_ref(self, n, m, k, l):
        lut = jnp.asarray(RNG.rand(m, k), jnp.float32)
        codes = jnp.asarray(RNG.randint(0, k, (n, m)), jnp.uint8)
        ids = jnp.asarray(RNG.randint(0, n, l), jnp.int32)
        got = beam_gather_adc_kernel(lut, ids, codes, tb=16, m_chunk=4,
                                     interpret=True)
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(ref.beam_gather_adc_ref(lut, ids, codes)),
            rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n,w,l", [(150, 8, 40), (64, 4, 7), (20, 1, 20)])
    def test_hamming_matches_ref(self, n, w, l):
        qc = jnp.asarray(RNG.randint(0, 2 ** 31, w), jnp.uint32)
        xc = jnp.asarray(RNG.randint(0, 2 ** 31, (n, w)), jnp.uint32)
        ids = jnp.asarray(RNG.randint(0, n, l), jnp.int32)
        got = beam_gather_hamming_kernel(qc, ids, xc, tb=16, interpret=True)
        want = ref.beam_gather_hamming_ref(qc, ids, xc)
        assert (np.asarray(got) == np.asarray(want)).all()

    def test_ops_dispatch_parity(self):
        """force_ref=True and the interpret-mode kernel agree through the
        public dispatchers."""
        corpus = jnp.asarray(RNG.randn(60, 32), jnp.float32)
        q = jnp.asarray(RNG.randn(32), jnp.float32)
        ids = jnp.asarray(RNG.randint(0, 60, 21), jnp.int32)
        for mode in ("l2", "dot"):
            a = ops.beam_gather_distances(q, ids, corpus, mode=mode,
                                          force_ref=True)
            b = ops.beam_gather_distances(q, ids, corpus, mode=mode,
                                          force_ref=False, tb=8)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


class TestPairGatherKernel:
    """Fused candidate-pair distance kernel (bulk-build Alg-4 prune)."""

    @pytest.mark.parametrize("n,d,c", [
        (128, 32, 64),      # aligned
        (100, 48, 37),      # id-axis padding (37 -> 40 lanes)
        (50, 16, 1),        # single candidate
        (33, 130, 19),
    ])
    @pytest.mark.parametrize("mode", ["l2", "dot"])
    def test_matches_ref(self, n, d, c, mode):
        from repro.kernels.bulk_prune import pair_gather_kernel
        corpus = jnp.asarray(RNG.randn(n, d), jnp.float32)
        ids = jnp.asarray(RNG.randint(0, n, c), jnp.int32)
        got = pair_gather_kernel(ids, corpus, mode=mode, interpret=True)
        want = (ref.pair_gather_l2_ref(ids, corpus) if mode == "l2"
                else ref.pair_gather_dot_ref(ids, corpus))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_duplicate_ids_give_zero_l2(self):
        from repro.kernels.bulk_prune import pair_gather_kernel
        corpus = jnp.asarray(RNG.randn(30, 24), jnp.float32)
        ids = jnp.asarray([5, 5, 0, 29, 5], jnp.int32)
        got = np.asarray(pair_gather_kernel(ids, corpus, interpret=True))
        assert got.shape == (5, 5)
        np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-4)
        np.testing.assert_allclose(got[0, 1], 0.0, atol=1e-4)  # dup pair

    def test_ops_dispatch_parity(self):
        corpus = jnp.asarray(RNG.randn(60, 32), jnp.float32)
        ids = jnp.asarray(RNG.randint(0, 60, 21), jnp.int32)
        for mode in ("l2", "dot"):
            a = ops.pair_gather_distances(ids, corpus, mode=mode,
                                          force_ref=True)
            b = ops.pair_gather_distances(ids, corpus, mode=mode,
                                          force_ref=False)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


class TestSLSTMKernel:
    """Fused weight-resident sLSTM kernel vs the scan oracle (§Perf 4.4)."""

    @pytest.mark.parametrize("b,s,d,h,chunk", [
        (2, 64, 32, 4, 16),
        (1, 32, 16, 2, 32),     # single chunk
        (3, 96, 64, 8, 24),
    ])
    def test_matches_ref(self, b, s, d, h, chunk):
        from repro.kernels.slstm import slstm_sequence_kernel
        rng = np.random.RandomState(b + s)
        blk = d // h
        gates = jnp.asarray(rng.randn(b, s, 4 * d), jnp.float32)
        r = jnp.asarray(0.3 * rng.randn(4, h, blk, blk), jnp.float32)
        bias = jnp.asarray(rng.randn(4 * d), jnp.float32)
        got = slstm_sequence_kernel(gates, r, bias, n_heads=h, chunk=chunk,
                                    interpret=True)
        want = ref.slstm_sequence_ref(gates, r, bias, n_heads=h)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_chunk_invariance(self):
        from repro.kernels.slstm import slstm_sequence_kernel
        rng = np.random.RandomState(7)
        gates = jnp.asarray(rng.randn(2, 48, 64), jnp.float32)
        r = jnp.asarray(0.3 * rng.randn(4, 4, 4, 4), jnp.float32)
        bias = jnp.asarray(rng.randn(64), jnp.float32)
        a = slstm_sequence_kernel(gates, r, bias, n_heads=4, chunk=12,
                                  interpret=True)
        b = slstm_sequence_kernel(gates, r, bias, n_heads=4, chunk=48,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)

    def test_matches_model_cell(self):
        """The kernel's semantics == the model's recurrent cell."""
        from repro.kernels.slstm import slstm_sequence_kernel
        from repro.models.config import ModelConfig
        from repro.models.recurrent import (_slstm_cell, init_slstm,
                                            slstm_init_state)
        import jax
        cfg = ModelConfig(name="t", family="ssm", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=16,
                          block_pattern=("slstm", "slstm"))
        p = init_slstm(jax.random.PRNGKey(0), cfg)
        gates = jnp.asarray(RNG.randn(2, 24, 128), jnp.float32)
        state = slstm_init_state(cfg, 2)
        hs = []
        for t in range(24):
            h, state = _slstm_cell(p, gates[:, t], state, 4)
            hs.append(h)
        want = jnp.stack(hs, axis=1)
        got = slstm_sequence_kernel(gates, p["r"], p["b"], n_heads=4,
                                    chunk=8, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_ops_dispatcher_force_ref_parity(self):
        """`ops.slstm_sequence` routes ref vs kernel per the registry
        contract (the qlint PAL004 rule requires this dispatcher)."""
        rng = np.random.RandomState(11)
        gates = jnp.asarray(rng.randn(2, 24, 64), jnp.float32)
        r = jnp.asarray(0.3 * rng.randn(4, 4, 4, 4), jnp.float32)
        bias = jnp.asarray(rng.randn(64), jnp.float32)
        a = ops.slstm_sequence(gates, r, bias, n_heads=4, force_ref=True)
        b = ops.slstm_sequence(gates, r, bias, n_heads=4, chunk=8,
                               force_ref=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-5)
        from repro.kernels import slstm_sequence as exported
        assert exported is ops.slstm_sequence


class TestOpsDispatch:
    def test_force_ref_matches_kernel(self):
        qs = jnp.asarray(RNG.randn(4, 32), jnp.float32)
        xs = jnp.asarray(RNG.randn(40, 32), jnp.float32)
        a = ops.l2_distances(qs, xs, force_ref=True)
        b = ops.l2_distances(qs, xs, force_ref=False, tq=4, tn=32, tk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_all_ops_callable(self):
        qs = jnp.asarray(RNG.randn(2, 16), jnp.float32)
        xs = jnp.asarray(RNG.randn(8, 16), jnp.float32)
        assert ops.dot_distances(qs, xs).shape == (2, 8)
        lut = jnp.asarray(RNG.rand(2, 4, 8), jnp.float32)
        codes = jnp.asarray(RNG.randint(0, 8, (9, 4)), jnp.uint8)
        assert ops.pq_adc_distances(lut, codes).shape == (2, 9)
        qc = jnp.asarray(RNG.randint(0, 2 ** 31, (2, 2)), jnp.uint32)
        xc = jnp.asarray(RNG.randint(0, 2 ** 31, (5, 2)), jnp.uint32)
        assert ops.hamming_distances(qc, xc).shape == (2, 5)
