"""Checkpoint store: atomic commits, WAL replay, async, elastic reshard."""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, ShardedCheckpoint,
                              replay_wal_into, reshard_rows)


@pytest.fixture
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "ckpt"), keep=2)


class TestCommits:
    def test_save_load_roundtrip(self, store):
        state = {"a": np.arange(10), "b": np.random.rand(3, 4)}
        gen = store.save(state, step=7)
        out = store.load(gen)
        np.testing.assert_array_equal(out["a"], state["a"])
        np.testing.assert_allclose(out["b"], state["b"])
        assert store.manifest().step == 7

    def test_generations_monotonic_and_gc(self, store):
        for i in range(4):
            store.save({"x": np.array([i])}, step=i)
        gens = store.generations()
        assert len(gens) == 2           # keep=2
        assert (store.load()["x"] == [3]).all()

    def test_incomplete_generation_ignored(self, store, tmp_path):
        store.save({"x": np.ones(3)}, step=1)
        # simulate a crash mid-write: gen dir without MANIFEST
        broken = os.path.join(store.root, "gen-000099")
        os.makedirs(broken)
        np.save(os.path.join(broken, "x.shard0.npy"), np.zeros(3))
        assert store.latest() == 1      # broken gen invisible
        assert (store.load()["x"] == 1).all()

    def test_async_commit(self, store):
        t = store.save_async({"x": np.full(5, 3.0)}, step=2)
        store.wait_async()
        assert (store.load()["x"] == 3.0).all()

    def test_concurrent_async_commits(self, store):
        # regression: the async-thread list is lock-guarded, and
        # wait_async joins OUTSIDE the lock (the background save takes
        # the commit lock itself, so a locked join would deadlock)
        import threading
        errs = []

        def spawn(i):
            try:
                store.save_async({"x": np.full(3, float(i))}, step=i)
            except Exception as exc:       # noqa: BLE001
                errs.append(exc)

        callers = [threading.Thread(target=spawn, args=(i,))
                   for i in range(6)]
        for c in callers:
            c.start()
        for c in callers:
            c.join()
        store.wait_async()
        assert not errs
        assert len(store.generations()) >= 1   # keep=2 bounds retention
        assert store.load()["x"].shape == (3,)
        store.wait_async()                     # idempotent on empty list

    def test_object_dtype_metadata_columns(self, store):
        state = {"meta": np.array(["a", None, 3], dtype=object)}
        store.save(state)
        out = store.load()
        assert out["meta"].tolist() == ["a", None, 3]


class TestWAL:
    def test_append_replay_clear(self, store):
        store.wal_append(np.ones((4, 8)), json.dumps([{"k": 1}] * 4))
        store.wal_append(np.zeros((2, 8)), None)
        rep = store.wal_replay()
        assert len(rep) == 2
        assert rep[0]["vectors"].shape == (4, 8)
        assert rep[0]["metadata"] == [{"k": 1}] * 4
        assert rep[1]["metadata"] is None
        store.save({"x": np.ones(1)})   # commit clears WAL
        assert store.wal_replay() == []

    def test_crash_recovery_flow(self, store):
        """Insert -> WAL; crash; restart replays WAL onto last commit."""
        store.save({"corpus": np.ones((10, 4))}, step=1)
        store.wal_append(np.full((3, 4), 2.0), None)
        # "restart"
        st2 = CheckpointStore(store.root, keep=2)
        base = st2.load()["corpus"]
        extra = [r["vectors"] for r in st2.wal_replay()]
        full = np.concatenate([base] + extra)
        assert full.shape == (13, 4)

    def test_wal_replay_lands_in_delta_segment(self, store):
        """Recovery = last generation + WAL replay, with no quantizer
        retraining and no sealed-graph rebuild (segmented write path)."""
        from repro.core import EngineConfig, QuantixarEngine, SealPolicy
        from repro.core.hnsw_build import HNSWConfig
        from repro.data.synthetic import gaussian_mixture

        corpus = gaussian_mixture(300, 16, n_clusters=4, scale=0.2, seed=0)
        fresh = gaussian_mixture(8, 16, n_clusters=4, scale=0.2, seed=1)
        eng = QuantixarEngine(EngineConfig(
            dim=16, builder="bulk", hnsw=HNSWConfig(M=8, ef_construction=40),
            seal=SealPolicy(auto=False)))
        eng.add(corpus)
        eng.build()
        store.save(eng.state_dict(), step=1)
        store.wal_append(fresh, json.dumps([None] * len(fresh)))

        # "restart": restore the sealed engine, replay the WAL tail
        eng2 = QuantixarEngine.from_state_dict(eng.config,
                                               store.load())
        assert replay_wal_into(store, eng2) == len(fresh)
        s = eng2.stats()
        assert s["delta_rows"] == len(fresh) and s["sealed_rows"] == 300
        _, ids = eng2.search(fresh[:2], 3)
        assert 300 in set(ids[0].tolist()) and 301 in set(ids[1].tolist())
        assert eng2.stats()["index_builds"] == 0
        assert eng2.stats()["quantizer_trains"] == 0


class TestElastic:
    def test_reshard_preserves_rows(self):
        shards = [np.arange(i * 10, (i + 1) * 10).reshape(10, 1)
                  for i in range(4)]
        out = reshard_rows(shards, 3)
        assert len(out) == 3
        merged = np.concatenate(out)
        np.testing.assert_array_equal(merged.ravel(), np.arange(40))

    def test_sharded_checkpoint_resharded_load(self, tmp_path):
        sh = ShardedCheckpoint(str(tmp_path / "s"), num_shards=4)
        gens = [sh.save_shard(i, {"vecs": np.full((8, 2), i)}, step=1)
                for i in range(4)]
        sh.commit(1, gens)
        parts = sh.load_resharded("vecs", 2)   # elastic: 4 -> 2 shards
        assert len(parts) == 2
        assert sum(len(p) for p in parts) == 32
        # order preserved: first new shard starts with old shard 0 rows
        assert (parts[0][0] == 0).all()

    def test_global_manifest(self, tmp_path):
        sh = ShardedCheckpoint(str(tmp_path / "g"), num_shards=2)
        gens = [sh.save_shard(i, {"v": np.zeros(2)}) for i in range(2)]
        sh.commit(5, gens)
        g = sh.load_global()
        assert g["step"] == 5 and g["num_shards"] == 2
