"""Product quantization: codebooks, encode/decode, ADC identity, recall."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # deterministic shim keeps properties runnable
    from _hypothesis_fallback import given, settings, st

from repro.core import PQConfig, ProductQuantizer, exact_knn
from repro.core.pq import adc_distances, build_adc_lut, decode, encode, \
    train_codebooks
from repro.data.synthetic import gaussian_mixture


@pytest.fixture(scope="module")
def clustered():
    return gaussian_mixture(1500, 32, n_clusters=16, scale=0.15, seed=0)


class TestCodebooks:
    def test_shapes_and_dtype(self, clustered):
        pq = ProductQuantizer(PQConfig(m=8, k=32, iters=8))
        pq.train(jnp.asarray(clustered))
        assert pq.codebooks.shape == (8, 32, 4)
        codes = pq.encode(jnp.asarray(clustered))
        assert codes.shape == (1500, 8) and codes.dtype == jnp.uint8

    def test_kmeans_reduces_distortion(self, clustered):
        x = jnp.asarray(clustered)
        few = train_codebooks(jax.random.PRNGKey(0), x, 4, 16, iters=1)
        many = train_codebooks(jax.random.PRNGKey(0), x, 4, 16, iters=20)

        def distortion(cb):
            return float(jnp.mean(jnp.sum(
                (x - decode(encode(x, cb), cb)) ** 2, axis=1)))

        assert distortion(many) <= distortion(few) + 1e-6

    def test_dim_divisibility_validated(self):
        with pytest.raises(ValueError):
            PQConfig(m=7).validate(32)


class TestADC:
    def test_adc_equals_l2_to_reconstruction(self, clustered):
        """The exact identity ADC(q, code) == ‖q − decode(code)‖²."""
        pq = ProductQuantizer(PQConfig(m=8, k=16, iters=5))
        pq.train(jnp.asarray(clustered))
        codes = pq.encode(jnp.asarray(clustered[:64]))
        recon = np.asarray(pq.decode(codes))
        q = clustered[100:103]
        lut = build_adc_lut(jnp.asarray(q), pq.codebooks)
        adc = np.asarray(adc_distances(lut, codes))
        want = ((q[:, None, :] - recon[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(adc, want, rtol=1e-3, atol=1e-3)

    def test_recall_on_clustered_data(self, clustered):
        pq = ProductQuantizer(PQConfig(m=16, k=64, iters=15))
        pq.train(jnp.asarray(clustered))
        codes = pq.encode(jnp.asarray(clustered))
        q = gaussian_mixture(32, 32, n_clusters=16, scale=0.15, seed=7)
        _, ids = pq.search(codes, jnp.asarray(q), 10)
        gt = exact_knn(q, clustered, 10, metric="l2")
        recall = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                          for a, b in zip(np.asarray(ids), gt)])
        assert recall > 0.55, recall

    def test_compression_ratio(self):
        pq = ProductQuantizer(PQConfig(m=16, k=256))
        assert pq.compression_ratio(128) == 32.0   # 512B -> 16B

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000))
    def test_encode_deterministic(self, seed):
        x = np.random.RandomState(seed).randn(50, 16).astype(np.float32)
        pq = ProductQuantizer(PQConfig(m=4, k=8, iters=3))
        pq.train(jnp.asarray(x), seed=0)
        c1 = np.asarray(pq.encode(jnp.asarray(x)))
        c2 = np.asarray(pq.encode(jnp.asarray(x)))
        assert (c1 == c2).all()

    def test_state_dict_roundtrip(self, clustered):
        pq = ProductQuantizer(PQConfig(m=8, k=16, iters=4))
        pq.train(jnp.asarray(clustered))
        pq2 = ProductQuantizer(PQConfig(m=8, k=16, iters=4))
        pq2.load_state_dict(pq.state_dict())
        codes1 = np.asarray(pq.encode(jnp.asarray(clustered[:32])))
        codes2 = np.asarray(pq2.encode(jnp.asarray(clustered[:32])))
        assert (codes1 == codes2).all()
