"""Binary quantization: packing, Hamming search, LSH recall behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # deterministic shim keeps properties runnable
    from _hypothesis_fallback import given, settings, st

from repro.core import BinaryQuantizer, BQConfig, exact_knn
from repro.core.bq import hamming_distances, pack_bits, unpack_bits
from repro.data.synthetic import gaussian_mixture


class TestPacking:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 20), st.integers(1, 4), st.integers(0, 10_000))
    def test_pack_unpack_roundtrip(self, n, words, seed):
        bits = words * 32
        raw = (np.random.RandomState(seed).rand(n, bits) > 0.5) \
            .astype(np.uint32)
        packed = pack_bits(jnp.asarray(raw))
        assert packed.shape == (n, words)
        back = np.asarray(unpack_bits(packed, bits))
        assert (back == raw).all()

    def test_hamming_equals_unpacked_xor(self):
        rng = np.random.RandomState(1)
        a = (rng.rand(5, 64) > 0.5).astype(np.uint32)
        b = (rng.rand(9, 64) > 0.5).astype(np.uint32)
        pa, pb = pack_bits(jnp.asarray(a)), pack_bits(jnp.asarray(b))
        got = np.asarray(hamming_distances(pa, pb))
        want = (a[:, None, :] != b[None, :, :]).sum(-1)
        assert (got == want).all()


class TestBQ:
    def test_bits_must_be_multiple_of_32(self):
        with pytest.raises(ValueError):
            BinaryQuantizer(BQConfig(bits=100))

    def test_recall_improves_with_bits(self):
        x = gaussian_mixture(1200, 48, n_clusters=12, scale=0.15, seed=0)
        q = gaussian_mixture(24, 48, n_clusters=12, scale=0.15, seed=5)
        gt = exact_knn(q, x, 10, metric="cosine")

        def recall(bits):
            bq = BinaryQuantizer(BQConfig(bits=bits))
            bq.train(jnp.asarray(x))
            codes = bq.encode(jnp.asarray(x))
            _, ids = bq.search(codes, jnp.asarray(q), 10)
            return np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                            for a, b in zip(np.asarray(ids), gt)])

        r64, r512 = recall(64), recall(512)
        assert r512 > r64, (r64, r512)
        assert r512 > 0.5, r512

    def test_hamming_correlates_with_cosine(self):
        """LSH property: E[hamming] is monotone in angle."""
        x = gaussian_mixture(400, 32, n_clusters=8, scale=0.2, seed=2)
        bq = BinaryQuantizer(BQConfig(bits=256))
        bq.train(jnp.asarray(x))
        codes = np.asarray(bq.encode(jnp.asarray(x)))
        ham = np.asarray(hamming_distances(
            jnp.asarray(codes[:50]), jnp.asarray(codes)))
        xn = x / np.linalg.norm(x, axis=1, keepdims=True)
        cos = 1.0 - xn[:50] @ xn.T
        corr = np.corrcoef(ham.ravel(), cos.ravel())[0, 1]
        assert corr > 0.8, corr

    def test_compression_ratio(self):
        bq = BinaryQuantizer(BQConfig(bits=256))
        assert bq.compression_ratio(128) == 16.0   # 512B -> 32B

    def test_state_dict_roundtrip(self):
        x = gaussian_mixture(200, 32, seed=3)
        bq = BinaryQuantizer(BQConfig(bits=64))
        bq.train(jnp.asarray(x))
        bq2 = BinaryQuantizer(BQConfig(bits=64))
        bq2.load_state_dict(bq.state_dict())
        c1 = np.asarray(bq.encode(jnp.asarray(x[:10])))
        c2 = np.asarray(bq2.encode(jnp.asarray(x[:10])))
        assert (c1 == c2).all()

    def test_pca_rotation_variant(self):
        x = gaussian_mixture(300, 24, seed=4)
        bq = BinaryQuantizer(BQConfig(bits=32, pca_rotate=True))
        bq.train(jnp.asarray(x))
        assert bq.hyperplanes.shape == (32, 24)
