"""Deterministic stand-in for `hypothesis` when it is not installed.

The property tests in this suite only use `@settings`, `@given`, and
`st.integers(lo, hi)`.  When the real package is available the test modules
import it directly; otherwise they fall back to this shim, which runs each
property with a bounded number of seeded pseudo-random draws so the
properties stay exercised (just with less adversarial example search).
"""

from __future__ import annotations

import functools

import numpy as np

# Cap fallback example counts: hypothesis shrinks + caches, we don't, so a
# straight 30-example sweep of interpret-mode kernels would dominate CI time.
MAX_FALLBACK_EXAMPLES = 10


class _Integers:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def draw(self, rng: np.random.RandomState) -> int:
        return int(rng.randint(self.min_value, self.max_value + 1))


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)


st = strategies


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats: _Integers):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above or below @given; check both.
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 20))
            n = min(n, MAX_FALLBACK_EXAMPLES)
            rng = np.random.RandomState(0)
            # always include the boundary example first, then seeded draws
            examples = [[s.min_value for s in strats]]
            examples += [[s.draw(rng) for s in strats] for _ in range(n - 1)]
            for vals in examples:
                fn(*args, *vals, **kwargs)
        # pytest must not follow __wrapped__: the drawn params would look
        # like missing fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
