"""Sparse full-text retrieval: tokenizer, BM25 index, hybrid plans.

The acceptance bar for the index is *exact* agreement with the brute-force
reference — identical floats, identical deterministic tie-breaks — across
every lifecycle event: initial build, post-build (delta) upserts, seal(),
deletes via row masks, compact(), and a save()/load round-trip.
"""

import tempfile

import numpy as np
import pytest

from repro.api import (CollectionSchema, Database, KeywordField, Predicate,
                       SchemaError, TextField, VectorField)
from repro.core.metadata import MetadataStore
from repro.core.sparse import (SparseIndex, TokenizerConfig, bm25_reference,
                               rank_scores)

# small but repetitive vocabulary so documents share terms (df > 1) and
# exact ties actually occur
_WORDS = ["quick", "fox", "lazy", "dog", "vector", "index", "search",
          "sparse", "dense", "query", "graph", "rank", "token", "fusion"]


def _corpus(rng, n, empty_every=7):
    texts = []
    for i in range(n):
        if empty_every and i % empty_every == 3:
            texts.append(None)            # rows without text stay aligned
            continue
        words = rng.choice(_WORDS, size=rng.integers(3, 12))
        texts.append(" ".join(words))
    return texts


def _assert_exact(index, texts, query, mask=None, k=10):
    """Index search must equal brute-force reference *exactly*."""
    ref = bm25_reference(texts, query, index.config)
    if mask is not None:
        ref = np.where(np.asarray(mask, bool)[:ref.shape[0]], ref, 0.0)
    want_d, want_rows = rank_scores(ref, k)
    got_d, got_rows = index.search(query, k, mask=mask)
    np.testing.assert_array_equal(got_rows, want_rows)
    np.testing.assert_array_equal(got_d, want_d)


class TestTokenizer:
    def test_deterministic_and_rules(self):
        cfg = TokenizerConfig()
        toks = cfg.tokenize("The Quick, quick brown FOX!")
        assert toks == ["quick", "quick", "brown", "fox"]  # "the" stopped
        assert cfg.tokenize("a I x") == []    # stopword / below min length
        assert cfg.tokenize(None) == []

    def test_query_tokens_dedupe_preserves_first_occurrence(self):
        cfg = TokenizerConfig()
        assert cfg.query_tokens("fox quick fox dog quick") == \
            ["fox", "quick", "dog"]

    def test_config_knobs(self):
        cfg = TokenizerConfig(lowercase=False, min_token_len=1,
                              stopwords=())
        assert cfg.tokenize("The Fox a") == ["The", "Fox", "a"]


class TestTextFieldSchema:
    def test_round_trip_with_params(self):
        schema = CollectionSchema(
            name="c", vector=VectorField(dim=4, index="flat"),
            fields=(TextField("body", min_token_len=3, lowercase=False,
                              stopwords=("foo", "bar")),))
        back = CollectionSchema.from_dict(schema.to_dict())
        fld = back.field("body")
        assert isinstance(fld, TextField)
        assert fld.min_token_len == 3 and not fld.lowercase
        assert fld.stopwords == ("foo", "bar")
        assert fld.tokenizer() == schema.field("body").tokenizer()

    def test_validation(self):
        with pytest.raises(SchemaError):
            TextField("body", min_token_len=0)
        with pytest.raises(SchemaError):
            TextField("body", stopwords=("ok", 3))
        with pytest.raises(SchemaError):
            TextField("body").validate(42)

    def test_text_fields_are_retrieval_only(self):
        schema = CollectionSchema(
            name="c", vector=VectorField(dim=4, index="flat"),
            fields=(TextField("body"),))
        from repro.api.plan import validate_filter
        with pytest.raises(SchemaError, match="not valid"):
            validate_filter(schema, Predicate("body", "eq", "x"))

    def test_resolve_text_field(self):
        one = CollectionSchema(
            name="c", vector=VectorField(dim=4, index="flat"),
            fields=(TextField("body"), KeywordField("lang")))
        assert one.resolve_text_field(None).name == "body"
        with pytest.raises(SchemaError, match="not a"):
            one.resolve_text_field("lang")
        none = CollectionSchema(name="c",
                                vector=VectorField(dim=4, index="flat"))
        with pytest.raises(SchemaError, match="no text fields"):
            none.resolve_text_field(None)
        two = CollectionSchema(
            name="c", vector=VectorField(dim=4, index="flat"),
            fields=(TextField("t1"), TextField("t2")))
        with pytest.raises(SchemaError, match="specify field="):
            two.resolve_text_field(None)


class TestSparseIndexExact:
    """Index top-k == brute-force reference, float-for-float."""

    def test_initial_build(self):
        rng = np.random.default_rng(0)
        texts = _corpus(rng, 60)
        index = SparseIndex()
        index.add(texts)
        for q in ("quick fox", "vector index search", "fusion rank token",
                  "quick quick dog", "missingword"):
            _assert_exact(index, texts, q)

    def test_after_delta_adds_and_seal(self):
        rng = np.random.default_rng(1)
        texts = _corpus(rng, 40)
        index = SparseIndex()
        index.add(texts[:25])
        index.seal()
        index.add(texts[25:])        # these live in the delta
        assert index.delta_postings > 0 and index.sealed_postings > 0
        _assert_exact(index, texts, "quick fox dense query")
        index.seal()
        assert index.delta_postings == 0
        _assert_exact(index, texts, "quick fox dense query")

    def test_mask_filters_candidates_not_statistics(self):
        rng = np.random.default_rng(2)
        texts = _corpus(rng, 50)
        index = SparseIndex()
        index.add(texts)
        mask = rng.random(50) > 0.4
        _assert_exact(index, texts, "quick fox vector", mask=mask)
        d, rows = index.search("quick fox vector", 50, mask=mask)
        assert all(mask[r] for r in rows if r >= 0)

    def test_auto_seal(self):
        index = SparseIndex()
        index.AUTO_SEAL_POSTINGS = 30
        rng = np.random.default_rng(3)
        texts = _corpus(rng, 40, empty_every=0)
        index.add(texts)
        assert index.seals >= 1
        _assert_exact(index, texts, "quick fox token")

    def test_state_dict_round_trip_preserves_delta_split(self):
        rng = np.random.default_rng(4)
        texts = _corpus(rng, 30)
        index = SparseIndex()
        index.add(texts[:20])
        index.seal()
        index.add(texts[20:])
        loaded = SparseIndex.from_state_dict(index.state_dict())
        assert loaded.sealed_postings == index.sealed_postings
        assert loaded.delta_postings == index.delta_postings
        _assert_exact(loaded, texts, "quick fox search")
        # the loaded index keeps absorbing upserts without a rebuild
        more = _corpus(rng, 10)
        index.add(more)
        loaded.add(more)
        _assert_exact(loaded, texts + more, "dense sparse rank")

    def test_jax_path_matches_numpy_approximately(self):
        rng = np.random.default_rng(5)
        texts = _corpus(rng, 80)
        index = SparseIndex()
        index.add(texts)
        toks = index.config.query_tokens("quick fox vector fusion")
        np.testing.assert_allclose(index.scores_jax(toks),
                                   index.scores(toks), rtol=1e-5, atol=1e-6)

    def test_tie_break_is_ascending_row_id(self):
        index = SparseIndex()
        index.add(["quick fox", "other words here", "quick fox"])
        d, rows = index.search("quick fox", 3)
        assert rows.tolist()[:2] == [0, 2]     # identical scores: row order
        assert d[0] == d[1]


class TestMetadataInOp:
    """Satellite: `in` with an empty value set / never-written columns."""

    def test_empty_in_matches_nothing(self):
        ms = MetadataStore()
        ms.append_batch([{"tag": "a"}, {"tag": "b"}, None])
        mask = ms.evaluate(Predicate("tag", "in", ()))
        assert mask.dtype == np.bool_ and mask.shape == (3,)
        assert not mask.any()

    def test_empty_in_on_empty_store(self):
        ms = MetadataStore()
        mask = ms.evaluate(Predicate("tag", "in", ()))
        assert mask.dtype == np.bool_ and mask.shape == (0,)

    def test_in_on_never_written_column(self):
        ms = MetadataStore()
        ms.append_batch([{"tag": "a"}, {"tag": "b"}])
        mask = ms.evaluate(Predicate("ghost", "in", ("a", "b")))
        assert mask.dtype == np.bool_ and not mask.any()
        mask = ms.evaluate(Predicate("ghost", "in", ()))
        assert mask.dtype == np.bool_ and not mask.any()

    def test_empty_in_through_collection(self):
        db = Database()
        col = db.create_collection(CollectionSchema(
            name="c", vector=VectorField(dim=4, index="flat"),
            fields=(KeywordField("tag"),)))
        col.upsert(["a", "b"], np.eye(4, dtype=np.float32)[:2],
                   [{"tag": "x"}, {"tag": "y"}])
        assert col.count(Predicate("tag", "in", ())) == 0
        hits = (col.query(np.ones(4, np.float32))
                .filter(Predicate("tag", "in", ())).run())
        assert hits == []


@pytest.fixture
def hybrid_col():
    rng = np.random.default_rng(7)
    db = Database()
    col = db.create_collection(CollectionSchema(
        name="docs", vector=VectorField(dim=8, metric="cosine", index="flat"),
        fields=(TextField("body"), KeywordField("lang"))))
    texts = _corpus(rng, 40)
    vecs = rng.normal(size=(40, 8)).astype(np.float32)
    payloads = []
    for i, t in enumerate(texts):
        p = {"lang": "en" if i % 2 == 0 else "de"}
        if t is not None:
            p["body"] = t
        payloads.append(p)
    col.upsert([f"d{i}" for i in range(40)], vecs, payloads)
    return col, texts, vecs, rng


class TestCollectionSparse:
    def _texts_live(self, texts, col):
        live = {col._ids[r] for r in col._row_of.values()}
        return [t if f"d{i}" in live else None
                for i, t in enumerate(texts)]

    def test_keyword_search_matches_reference(self, hybrid_col):
        col, texts, _, _ = hybrid_col
        hits = col.query().text("quick fox vector").top_k(5).run()
        ref = bm25_reference(texts, "quick fox vector")
        d, rows = rank_scores(ref, 5)
        want = [f"d{r}" for r in rows if r >= 0]
        assert [h.id for h in hits] == want
        np.testing.assert_array_equal(
            np.asarray([h.score for h in hits], dtype=np.float32),
            d[: len(hits)])

    def test_filtered_keyword_search(self, hybrid_col):
        col, texts, _, _ = hybrid_col
        hits = (col.query().text("quick fox vector")
                .filter(lang="en").top_k(10).run())
        assert hits and all(h.payload["lang"] == "en" for h in hits)

    def test_exact_after_upsert_delete_compact_save(self, hybrid_col):
        col, texts, vecs, rng = hybrid_col
        db = Database()
        db._collections[col.name] = col    # wrap for save()

        def check(q="quick fox dense rank"):
            ref = bm25_reference(self._texts_live(texts, col), q)
            want_d, want_rows = rank_scores(ref, 8)
            want = [(f"d{r}", float(np.float32(d)))
                    for d, r in zip(want_d, want_rows) if r >= 0]
            hits = col.query().text(q).top_k(8).run()
            assert [(h.id, h.score) for h in hits] == want

        check()
        # replace one doc and add a new one (delta path)
        texts[2] = "quick quick quick fox"
        texts.append("fresh dense vector rank")
        col.upsert(["d2", "d40"],
                   rng.normal(size=(2, 8)).astype(np.float32),
                   [{"body": texts[2], "lang": "en"},
                    {"body": texts[40], "lang": "de"}])
        # the replaced d2 row is a tombstone; reference corpus must model
        # the live view: old row text gone, new rows appended
        texts_now = [t for t in texts]

        def check_live(q="quick fox dense rank"):
            # tombstoned rows stay in the corpus statistics (N, df, avgdl)
            # and are filtered out as *candidates* via the liveness mask —
            # the same convention SparseIndex.search documents
            all_texts = [col._engine.metadata.record(row).get("body")
                         for row in range(len(col._ids))]
            ref = bm25_reference(all_texts, q)
            ref = np.where(np.asarray(col._live, bool), ref, 0.0)
            want_d, want_rows = rank_scores(ref, 8)
            want = [(col._ids[r], float(np.float32(d)))
                    for d, r in zip(want_d, want_rows) if r >= 0]
            hits = col.query().text(q).top_k(8).run()
            assert [(h.id, h.score) for h in hits] == want

        check_live()
        col.delete(["d0", "d5", "d11"])
        check_live()
        col.compact()
        check_live()
        col.query()  # still builds
        with tempfile.TemporaryDirectory() as tmp:
            db.save(tmp)
            col2 = Database.load(tmp).collection("docs")
            h1 = col.query().text("quick fox dense rank").top_k(8).run()
            h2 = col2.query().text("quick fox dense rank").top_k(8).run()
            assert [(h.id, h.score) for h in h1] == \
                [(h.id, h.score) for h in h2]
        assert texts_now  # silence unused warning

    def test_hybrid_fuses_dense_and_sparse(self, hybrid_col):
        col, texts, vecs, _ = hybrid_col
        ex = col.query(vecs[0]).text("quick fox").top_k(5).explain()
        ops = [s["stage"] for s in ex.stages]
        assert ops == ["prefetch", "fusion"]
        children = ex.stages[0]["children"]
        assert [c[0]["stage"] for c in children] == ["ann", "sparse"]
        assert ex.stages[0]["candidates_out"] > 0
        assert len(ex.hits) == 5
        # plan echo carries the sparse leg with the resolved field
        sub_ops = [p["stages"][0]["op"]
                   for p in ex.plan["stages"][0]["plans"]]
        assert sub_ops == ["ann", "sparse"]
        assert ex.plan["stages"][0]["plans"][1]["stages"][0]["field"] \
            == "body"

    def test_hybrid_with_explicit_prefetch_and_linear_fusion(
            self, hybrid_col):
        col, _, vecs, _ = hybrid_col
        hits = (col.query(vecs[1])
                .prefetch(k=12, filter=Predicate("lang", "eq", "en"))
                .prefetch(text="quick fox", k=12)
                .fuse("linear", weights=(0.5, 0.5))
                .top_k(5).run())
        assert len(hits) == 5

    def test_vectorless_errors(self, hybrid_col):
        col, _, _, _ = hybrid_col
        with pytest.raises(SchemaError, match="vector or text"):
            col.query().top_k(3).run()
        with pytest.raises(SchemaError, match="needs a query vector"):
            col.query().text("quick").stages(coarse_k=10).run()
        with pytest.raises(SchemaError, match="fuse"):
            col.query().text("quick").fuse("rrf").run()
        with pytest.raises(SchemaError):
            col.query().text("")
        with pytest.raises(SchemaError, match="dense or sparse"):
            col.query(np.ones(8, np.float32)).prefetch(
                vector=np.ones(8, np.float32), text="quick")

    def test_stats_counters(self, hybrid_col):
        col, texts, _, _ = hybrid_col
        stats = col.stats()
        n_text = sum(1 for t in texts if t)
        assert stats["sparse_fields"] == 1
        assert stats["sparse_docs_indexed"] == n_text
        assert stats["sparse_vocab"] > 0
        assert stats["sparse_postings"] == \
            stats["sparse_sealed_postings"] + stats["sparse_delta_postings"]
        col.compact()   # no tombstones: seals the sparse delta too
        stats = col.stats()
        assert stats["sparse_delta_postings"] == 0
        assert stats["sparse_seals"] >= 1
