"""Public API layer: schemas, collections, string ids, queries, persistence."""

import dataclasses

import numpy as np
import pytest

from repro.api import (And, BoolField, CollectionSchema, Database,
                       KeywordField, NumericField, Predicate, SchemaError,
                       VectorField)
from repro.core import PQConfig, QuantixarEngine
from repro.data.synthetic import gaussian_mixture

N, DIM = 600, 32


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=8, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(8, DIM, n_clusters=8, scale=0.2, seed=3)


def _ids(n=N):
    return [f"item-{i}" for i in range(n)]


def _payloads(n=N):
    return [{"category": f"cat-{i % 4}", "price": float(i % 50),
             "in_stock": i % 3 == 0} for i in range(n)]


def _schema(name="items", **vector_kw):
    vector_kw.setdefault("dim", DIM)
    vector_kw.setdefault("index", "flat")
    return CollectionSchema(
        name=name, vector=VectorField(**vector_kw),
        fields=(KeywordField("category"), NumericField("price"),
                BoolField("in_stock")))


def _collection(corpus, **vector_kw):
    col = Database().create_collection(_schema(**vector_kw))
    col.upsert(_ids(), corpus, _payloads())
    return col


class TestSchemaValidation:
    def test_bad_vector_field(self):
        with pytest.raises(SchemaError):
            VectorField(dim=0)
        with pytest.raises(SchemaError):
            VectorField(dim=8, metric="manhattan")
        with pytest.raises(SchemaError):
            VectorField(dim=8, index="lsh-forest")
        with pytest.raises(SchemaError):
            VectorField(dim=10, quantization="pq", pq=PQConfig(m=16))

    def test_bad_schema(self):
        v = VectorField(dim=8)
        with pytest.raises(SchemaError):
            CollectionSchema(name="", vector=v)
        with pytest.raises(SchemaError):
            CollectionSchema(name="a/b", vector=v)
        with pytest.raises(SchemaError):
            CollectionSchema(name="ok", vector=v,
                             fields=(KeywordField("x"), NumericField("x")))
        with pytest.raises(SchemaError):
            KeywordField("id")          # reserved

    def test_payload_type_errors(self):
        s = _schema()
        with pytest.raises(SchemaError):
            s.validate_payload({"category": 7})
        with pytest.raises(SchemaError):
            s.validate_payload({"price": "cheap"})
        with pytest.raises(SchemaError):
            s.validate_payload({"in_stock": 1})      # bool field, int given
        with pytest.raises(SchemaError):
            s.validate_payload({"unknown_key": 1})
        assert s.validate_payload({"price": 3})["price"] == 3.0

    def test_required_field_enforced(self):
        s = CollectionSchema(name="r", vector=VectorField(dim=8),
                             fields=(KeywordField("lang", required=True),))
        with pytest.raises(SchemaError):
            s.validate_payload({})
        assert s.validate_payload({"lang": "en"}) == {"lang": "en"}

    def test_schema_dict_roundtrip(self):
        s = _schema(index="hnsw", quantization="pq",
                    pq=PQConfig(m=8, k=32, iters=5))
        s2 = CollectionSchema.from_dict(s.to_dict())
        assert s2 == s
        assert s2.vector.pq.m == 8

    def test_batcher_config(self, corpus):
        from repro.api import BatcherConfig
        with pytest.raises(SchemaError):
            BatcherConfig(max_batch=0)
        with pytest.raises(SchemaError):
            BatcherConfig(max_wait_ms=-1.0)
        s = dataclasses.replace(
            _schema(), batcher=BatcherConfig(max_batch=4, max_wait_ms=7.0))
        assert CollectionSchema.from_dict(s.to_dict()).batcher == s.batcher
        # create_collection(batcher=...) threads through to the live batcher
        col = Database().create_collection(
            _schema(), batcher=BatcherConfig(max_batch=4, max_wait_ms=7.0))
        col.upsert(_ids(10), corpus[:10], _payloads(10))
        assert col.batcher.max_batch == 4
        assert col.batcher.max_wait == pytest.approx(0.007)
        col.close()

    def test_upsert_shape_and_id_errors(self, corpus):
        col = Database().create_collection(_schema())
        with pytest.raises(SchemaError):
            col.upsert([""], corpus[:1])
        with pytest.raises(SchemaError):
            col.upsert(["a", "a"], corpus[:2])
        with pytest.raises(SchemaError):
            col.upsert(["a"], corpus[:1, :8])        # wrong dim
        with pytest.raises(SchemaError):
            col.upsert(["a", "b"], corpus[:1])       # count mismatch


class TestCrud:
    def test_upsert_get_delete_roundtrip(self, corpus):
        col = _collection(corpus)
        e = col.get("item-7")
        assert e.id == "item-7" and e.payload["category"] == "cat-3"
        np.testing.assert_allclose(e.vector, corpus[7])
        assert col.get("missing") is None
        assert len(col) == N and "item-7" in col

        # replace: same id, new vector + payload
        col.upsert("item-7", corpus[0],
                   [{"category": "cat-0", "price": 1.0}])
        e2 = col.get("item-7")
        np.testing.assert_allclose(e2.vector, corpus[0])
        assert e2.payload["category"] == "cat-0"
        assert len(col) == N and col.tombstones == 1

        assert col.delete("item-7") == 1
        assert col.delete("item-7") == 0          # already gone
        assert col.get("item-7") is None and len(col) == N - 1

    def test_replaced_id_appears_once_in_results(self, corpus, queries):
        col = _collection(corpus)
        col.upsert("item-3", queries[0], [{"category": "cat-1"}])
        hits = col.query(queries[0]).top_k(N).run()
        ids = [h.id for h in hits]
        assert ids.count("item-3") == 1
        assert hits[0].id == "item-3"             # exact match ranks first

    def test_query_validation(self, corpus, queries):
        col = _collection(corpus)
        with pytest.raises(SchemaError):
            col.query(queries[0][:8])             # wrong dim
        with pytest.raises(SchemaError):
            col.query(queries[0]).top_k(0)
        with pytest.raises(SchemaError):
            col.query(queries[0]).filter(unknown=1)
        with pytest.raises(SchemaError):          # lt on keyword field
            col.query(queries[0]).where("category", "lt", "x")
        with pytest.raises(SchemaError):
            col.query(queries[0]).include("nope")

    def test_empty_collection_returns_empty(self, queries):
        """Empty collection = empty result (the old SchemaError turned into
        a 500 through any transport)."""
        col = Database().create_collection(_schema())
        assert col.query(queries[0]).run() == []
        assert col.query(queries[:3]).run() == [[], [], []]
        d, rows = col.search(queries, k=4)
        assert d.shape == rows.shape == (len(queries), 4)
        assert np.isinf(d).all() and (rows == -1).all()
        d, ids = col.search_ids(queries[:2], k=3)
        assert all(i is None for i in ids.ravel())


class TestQueryParity:
    """The API layer must return exactly what the engine returns."""

    def test_filtered_pq_hnsw_query_matches_engine(self, corpus, queries):
        """Acceptance: filtered Query over a PQ-quantized HNSW collection ==
        engine-level search, hit for hit (string ids resolved)."""
        col = _collection(corpus, index="hnsw", quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        flt = And((Predicate("category", "eq", "cat-1"),
                   Predicate("price", "lt", 30)))

        eng = QuantixarEngine(dataclasses.replace(
            col.schema.vector.to_engine_config()))
        eng.add(corpus, _payloads())
        eng.build()
        d_eng, rows_eng = eng.search(queries, 5, flt=flt)

        hits = col.query(queries).filter(flt).top_k(5).run()
        assert len(hits) == len(queries)
        for qi in range(len(queries)):
            got = [(h.id, pytest.approx(h.score, rel=1e-5))
                   for h in hits[qi]]
            want = [(f"item-{row}", pytest.approx(float(d), rel=1e-5))
                    for d, row in zip(d_eng[qi], rows_eng[qi]) if row >= 0]
            assert got == want
            for h in hits[qi]:
                assert h.payload["category"] == "cat-1"
                assert h.payload["price"] < 30

    def test_single_query_batcher_path_matches_direct(self, corpus, queries):
        col = _collection(corpus)
        direct = col.query(queries).top_k(5).run()      # 2-D: direct path
        for qi in (0, 3):
            single = col.query(queries[qi]).top_k(5).run()   # batcher path
            assert [h.id for h in single] == [h.id for h in direct[qi]]
        assert col.batcher.requests_served >= 2
        col.close()

    def test_include_vector_and_ef(self, corpus, queries):
        col = _collection(corpus, index="hnsw")
        hits = (col.query(queries[0]).top_k(3).ef(128)
                .include("vector").run())
        assert all(h.vector is not None and h.vector.shape == (DIM,)
                   for h in hits)
        row = int(hits[0].id.split("-")[1])
        np.testing.assert_allclose(hits[0].vector, corpus[row])


class TestTombstones:
    def test_deleted_never_returned(self, corpus, queries):
        col = _collection(corpus)
        victims = [f"item-{i}" for i in range(0, 100)]
        assert col.delete(victims) == 100
        hits = col.query(queries[1]).top_k(N).run()
        ids = {h.id for h in hits}
        assert not ids & set(victims)
        assert len(col) == N - 100

    def test_compact_reclaims_and_preserves_results(self, corpus, queries):
        col = _collection(corpus)
        col.delete([f"item-{i}" for i in range(50)])
        before = [h.id for h in col.query(queries[2]).top_k(10).run()]
        reclaimed = col.compact()
        assert reclaimed == 50 and col.tombstones == 0
        assert len(col) == N - 50
        after = [h.id for h in col.query(queries[2]).top_k(10).run()]
        assert after == before
        assert col.compact() == 0                 # idempotent

    def test_quantized_tombstones_respected_with_rescore(self, corpus,
                                                         queries):
        """Rescore must not resurrect masked rows (regression: the exact
        second pass used to drop the row mask)."""
        col = _collection(corpus, index="flat", quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        col.delete([f"item-{i}" for i in range(300)])
        hits = col.query(queries[0]).top_k(N).run()
        assert {h.id for h in hits} <= {f"item-{i}" for i in range(300, N)}


class TestDatabase:
    def test_collection_management(self):
        db = Database()
        db.create_collection(_schema("a"))
        db.create_collection(_schema("b"))
        assert db.list_collections() == ["a", "b"]
        assert db["a"].name == "a" and "a" in db
        with pytest.raises(SchemaError):
            db.create_collection(_schema("a"))
        db.drop_collection("a")
        assert db.list_collections() == ["b"]
        with pytest.raises(KeyError):
            db.collection("a")

    def test_save_load_roundtrip(self, corpus, queries, tmp_path):
        db = Database()
        col = db.create_collection(_schema("items", index="hnsw"))
        col.upsert(_ids(), corpus, _payloads())
        col.delete(["item-0", "item-1"])
        other = db.create_collection(_schema("other"))
        other.upsert(_ids(50), corpus[:50], _payloads(50))
        before = [h.id for h in
                  col.query(queries[0]).filter(category="cat-2")
                  .top_k(5).run()]
        gen = db.save(str(tmp_path), step=3)
        assert gen == 1

        db2 = Database.load(str(tmp_path))
        assert db2.list_collections() == ["items", "other"]
        col2 = db2["items"]
        assert col2.schema == col.schema
        assert len(col2) == N - 2 and col2.tombstones == 2
        assert col2.get("item-0") is None
        assert col2.get("item-5").payload == col.get("item-5").payload
        after = [h.id for h in
                 col2.query(queries[0]).filter(category="cat-2")
                 .top_k(5).run()]
        assert after == before
        db2.close()
        db.close()

    def test_load_rejects_foreign_checkpoint(self, tmp_path):
        from repro.checkpoint import CheckpointStore
        CheckpointStore(str(tmp_path)).save({"x": np.zeros(3)})
        with pytest.raises(SchemaError):
            Database.load(str(tmp_path))


# ---------------------------------------------------------------------------
# Query builder copy-on-write (PR 5 satellite regression)
# ---------------------------------------------------------------------------

class TestQueryCopyOnWrite:
    def test_base_query_forks_cleanly(self, corpus, queries):
        """Setters must return copies: reusing a base query between
        variants used to silently accumulate filters in the base."""
        col = _collection(corpus)
        base = col.query(queries[0]).top_k(5)
        v1 = base.filter(category="cat-1")
        v2 = base.filter(category="cat-2")
        assert v1 is not base and v2 is not base and v1 is not v2
        assert all(h.payload["category"] == "cat-1" for h in v1.run())
        assert all(h.payload["category"] == "cat-2" for h in v2.run())
        # the base stayed unfiltered (this is the regression: it used to
        # carry cat-1 AND cat-2 and match nothing)
        hits = base.run()
        assert len(hits) == 5
        assert {h.payload["category"] for h in hits} != {"cat-1"}

    def test_every_setter_is_copy_on_write(self, corpus, queries):
        col = _collection(corpus)
        base = col.query(queries[0])
        for forked in (base.top_k(3), base.ef(32), base.expansion_width(2),
                       base.rescore(False), base.include("vector"),
                       base.where("price", "lt", 10),
                       base.stages(coarse_k=12),
                       base.prefetch(category="cat-1")):
            assert forked is not base
        # base state untouched by all of the above
        assert base._k == 10 and base._flt is None and base._ef is None
        assert base._prefetch == () and base._coarse_k is None
        assert not base._include_vector


# ---------------------------------------------------------------------------
# Declarative plans, embedded: stages / fusion / recommend / count
# ---------------------------------------------------------------------------

class TestPlansEmbedded:
    def test_stages_matches_engine_rescore_hit_for_hit(self, corpus,
                                                       queries):
        """Acceptance: the explicit coarse-to-fine plan (raw code-domain
        first pass at oversample*k, exact rescore to k) must reproduce the
        legacy engine-internal rescore=True path exactly at equal k."""
        col = _collection(corpus, quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        k = 10
        for q in queries[:4]:
            legacy = col.query(q).top_k(k).rescore(True).run()
            staged = col.query(q).top_k(k).stages(coarse_k=4 * k).run()
            assert [h.id for h in staged] == [h.id for h in legacy]
            assert [h.score for h in staged] == pytest.approx(
                [h.score for h in legacy])

    def test_explain_stages_and_counts(self, corpus, queries):
        col = _collection(corpus, quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        ex = col.query(queries[0]).top_k(5).stages(coarse_k=20).explain()
        assert [s["stage"] for s in ex.stages] == ["ann", "rescore"]
        ann, rescore = ex.stages
        assert ann["k"] == 20 and ann["candidates_out"] == 20
        assert rescore["k"] == 5 and rescore["candidates_out"] == 5
        assert rescore["candidates_in"] == 20
        assert all(s["seconds"] >= 0 for s in ex.stages)
        assert ex.plan["k"] == 5
        assert [s["op"] for s in ex.plan["stages"]] == ["ann", "rescore"]
        assert [h.id for h in ex.hits] == [
            h.id for h in
            col.query(queries[0]).top_k(5).stages(coarse_k=20).run()]

    def test_fusion_validation_errors(self, corpus, queries):
        col = _collection(corpus)
        with pytest.raises(SchemaError):          # fuse without prefetch
            col.query(queries[0]).fuse("rrf").run()
        with pytest.raises(SchemaError):          # batch root + prefetch
            col.query(queries[:2]).prefetch(category="cat-1").run()
        with pytest.raises(SchemaError):          # unknown fusion method
            col.query(queries[0]).prefetch(category="cat-1").fuse("max")
        with pytest.raises(SchemaError):          # weights/plans mismatch
            (col.query(queries[0]).prefetch(category="cat-1")
             .fuse("linear", weights=[0.5, 0.5]).run())

    def test_rrf_fusion_unions_filtered_lists(self, corpus, queries):
        col = _collection(corpus)
        fused = (col.query(queries[0]).top_k(8)
                 .prefetch(category="cat-1")
                 .prefetch(category="cat-2")
                 .fuse("rrf")
                 .run())
        assert 0 < len(fused) <= 8
        cats = {h.payload["category"] for h in fused}
        assert cats <= {"cat-1", "cat-2"}
        # top hit of each filtered sub-query must survive RRF
        top1 = col.query(queries[0]).filter(category="cat-1").top_k(1).run()
        top2 = col.query(queries[0]).filter(category="cat-2").top_k(1).run()
        fused_ids = {h.id for h in fused}
        assert top1[0].id in fused_ids and top2[0].id in fused_ids

    def test_linear_fusion_respects_weights(self, corpus, queries):
        col = _collection(corpus)
        heavy1 = (col.query(queries[0]).top_k(1)
                  .prefetch(category="cat-1").prefetch(category="cat-2")
                  .fuse("linear", weights=[1.0, 0.0]).run())
        top1 = col.query(queries[0]).filter(category="cat-1").top_k(1).run()
        assert heavy1[0].id == top1[0].id

    def test_recommend_synthesizes_mean_difference(self, corpus, queries):
        col = _collection(corpus)
        pos, neg = [corpus[3], corpus[4]], [corpus[100]]
        expect = corpus[3:5].mean(axis=0) - corpus[100]
        by_vec = col.recommend(pos, neg).top_k(5).run()
        direct = col.query(expect).top_k(5).run()
        assert [h.id for h in by_vec] == [h.id for h in direct]
        # ids resolve to stored vectors
        by_id = col.recommend(["item-3", "item-4"], ["item-100"]) \
            .top_k(5).run()
        assert [h.id for h in by_id] == [h.id for h in direct]
        with pytest.raises(SchemaError):
            col.recommend([])
        with pytest.raises(SchemaError):
            col.recommend(["never-stored"])

    def test_count(self, corpus):
        col = _collection(corpus)
        assert col.count() == N
        assert col.count(Predicate("category", "eq", "cat-1")) == N // 4
        assert col.count(And((Predicate("category", "eq", "cat-1"),
                              Predicate("price", "lt", 0)))) == 0
        col.delete(["item-1", "item-5"])          # both cat-1
        assert col.count() == N - 2
        assert col.count(Predicate("category", "eq", "cat-1")) == N // 4 - 2
        with pytest.raises(SchemaError):
            col.count(Predicate("no_such_field", "eq", 1))

    def test_empty_collection_plans(self, queries):
        col = Database().create_collection(_schema())
        assert col.query(queries[0]).stages(coarse_k=20).run() == []
        ex = col.query(queries[0]).stages(coarse_k=20).explain()
        assert ex.hits == [] and ex.stages == []
        assert col.count() == 0
        # filtered count on an empty collection is 0, not a KeyError from
        # the metadata store's never-seen column
        assert col.count(Predicate("category", "eq", "cat-1")) == 0

    def test_direct_path_honors_timeout(self, corpus, queries):
        """Multi-stage plans enforce run(timeout=...) at stage boundaries
        instead of silently ignoring it on the direct execution path."""
        col = _collection(corpus)
        with pytest.raises(TimeoutError):
            col.query(queries[0]).top_k(5).stages(coarse_k=20) \
                .run(timeout=0.0)
        # a sane deadline still completes
        hits = col.query(queries[0]).top_k(5).stages(coarse_k=20) \
            .run(timeout=30.0)
        assert len(hits) == 5

    def test_search_array_api_unchanged(self, corpus, queries):
        """Legacy array-level search now compiles to a trivial plan but
        must keep its (distances, rows) contract, ef=0 honoring included."""
        col = _collection(corpus)
        d, rows = col.search(queries, k=4)
        assert d.shape == rows.shape == (len(queries), 4)
        assert (rows >= 0).all()
        with pytest.raises(ValueError):
            col.search(queries, k=0)

    def test_root_filter_is_an_invariant_over_prefetch(self, corpus,
                                                       queries):
        """A root .filter() must be ANDed into every prefetch sub-query,
        not silently replaced by the sub-query's own filter."""
        col = _collection(corpus)
        fused = (col.query(queries[0]).top_k(8)
                 .filter(in_stock=True)
                 .prefetch(category="cat-1")
                 .prefetch(category="cat-2")
                 .fuse("rrf")
                 .run())
        assert fused, "expected in-stock hits"
        for h in fused:
            assert h.payload["in_stock"] is True
            assert h.payload["category"] in ("cat-1", "cat-2")

    def test_rescore_override_reaches_prefetch_subplans(self, corpus,
                                                        queries):
        """.rescore(False) (a latency knob) must not be silently ignored
        when prefetch sub-queries are present."""
        col = _collection(corpus, quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        plan = (col.query(queries[0]).top_k(5).rescore(False)
                .prefetch(category="cat-1")
                .fuse("rrf")._compile())
        sub = plan.stages[0].plans[0]
        assert sub.stages[0].rescore is False

    def test_fused_stages_oversample_widens_subquery_pools(self, corpus,
                                                           queries):
        """.stages(oversample=N) on a fused query must widen each prefetch
        sub-query to the coarse pool (raw candidates, no engine-internal
        rescore) and leave the one exact pass to the trailing rescore
        stage — not fuse N*k out of k-sized lists."""
        col = _collection(corpus, quantization="pq",
                          pq=PQConfig(m=8, k=32, iters=6))
        plan = (col.query(queries[0]).top_k(10).stages(oversample=8)
                .prefetch(category="cat-1").prefetch(category="cat-2")
                .fuse("rrf")._compile())
        prefetch, fusion, rescore = plan.stages
        assert fusion.k == 80 and rescore.k == 10
        for sub in prefetch.plans:
            assert sub.k == 80
            assert sub.stages[0].k == 80
            assert sub.stages[0].rescore is False
        hits = (col.query(queries[0]).top_k(10).stages(oversample=8)
                .prefetch(category="cat-1").prefetch(category="cat-2")
                .fuse("rrf").run())
        assert 0 < len(hits) <= 10

    def test_filter_on_never_written_column_matches_nothing(self, corpus,
                                                            queries):
        """A schema-declared field no payload ever populated is all-missing
        ('missing values never match'), not a KeyError/500."""
        col = Database().create_collection(_schema())
        col.upsert(_ids(20), corpus[:20])            # no payloads at all
        assert col.count(Predicate("category", "eq", "x")) == 0
        assert col.query(queries[0]) \
            .filter(category="x").top_k(3).run() == []

    def test_closed_collection_refuses_direct_path_queries(self, corpus,
                                                           queries):
        """close()/drop must refuse multi-stage, batched, count, and array
        searches too — not just the batcher path."""
        from repro.api import CollectionClosed
        col = _collection(corpus)
        col.close()
        with pytest.raises(CollectionClosed):
            col.query(queries[0]).stages(coarse_k=20).run()
        with pytest.raises(CollectionClosed):
            col.query(queries[:2]).top_k(3).run()     # batched
        with pytest.raises(CollectionClosed):
            col.count()
        with pytest.raises(CollectionClosed):
            col.search(queries, k=3)
