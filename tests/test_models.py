"""Per-arch smoke tests (assignment deliverable f): every assigned
architecture instantiates a REDUCED same-family config and runs one forward +
one train step + decode steps on CPU, asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_config, get_smoke_config
from repro.models import (init_decode_state, init_train_state, forward,
                          make_serve_step, make_train_step)
from repro.optim import AdamWConfig

B, S = 2, 32


def _batch(cfg):
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "targets": jnp.asarray(toks[:, 1:]),
             "segment_ids": jnp.ones((B, S), jnp.int32)}
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.d_model).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", arch_ids())
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        logits, aux = forward(state.params, _batch(cfg), cfg)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"

    def test_train_step_reduces_loss(self, arch):
        cfg = get_smoke_config(arch)
        state = init_train_state(jax.random.PRNGKey(1), cfg)
        step = jax.jit(make_train_step(cfg, AdamWConfig(
            lr=5e-3, total_steps=20, warmup_steps=1)))
        batch = _batch(cfg)
        losses = []
        for _ in range(5):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses)), f"{arch}: {losses}"
        assert losses[-1] < losses[0], f"{arch}: no learning {losses}"

    def test_decode_matches_position_count(self, arch):
        cfg = get_smoke_config(arch)
        state = init_train_state(jax.random.PRNGKey(2), cfg)
        dstate = init_decode_state(cfg, B, 16)
        if cfg.is_enc_dec:
            from repro.models.model import encode, precompute_cross_kv
            enc_out = encode(state.params, _batch(cfg)["frames"], cfg)
            dstate = dstate._replace(cross_kv=precompute_cross_kv(
                state.params, enc_out, cfg))
        serve = jax.jit(make_serve_step(cfg))
        tok = jnp.ones((B, 1), jnp.int32)
        for i in range(4):
            tok, dstate = serve(state.params, dstate, tok)
        assert tok.shape == (B, 1)
        assert int(dstate.pos[0]) == 4
        assert (np.asarray(tok) >= 0).all()
        assert (np.asarray(tok) < cfg.vocab_size).all()


@pytest.mark.parametrize("arch,nominal_b", [
    ("qwen2-1.5b", 1.5), ("qwen3-4b", 4.0), ("starcoder2-15b", 15.0),
    ("stablelm-3b", 3.0), ("recurrentgemma-9b", 9.0), ("mixtral-8x7b", 46.7),
    ("granite-moe-3b-a800m", 3.3), ("xlstm-1.3b", 1.3),
    ("chameleon-34b", 34.0), ("seamless-m4t-medium", 1.2),
])
def test_param_counts_in_family_range(arch, nominal_b):
    """Full configs land within 2x of the published size class."""
    pc = get_config(arch).param_count() / 1e9
    assert nominal_b / 2 <= pc <= nominal_b * 2, (arch, pc)


def test_moe_active_params_below_total():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
    # published: 46.7B total / 12.9B active
    assert abs(cfg.param_count() / 1e9 - 46.7) < 1.0
    assert abs(cfg.active_param_count() / 1e9 - 12.9) < 1.0


def test_decode_consistency_with_prefill():
    """Greedy decode over a teacher-forced prefix must equal forward logits
    argmax at every position (cache correctness)."""
    cfg = get_smoke_config("qwen2-1.5b")
    state = init_train_state(jax.random.PRNGKey(3), cfg)
    rng = np.random.RandomState(5)
    toks = rng.randint(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    logits, _ = forward(state.params, {"tokens": jnp.asarray(toks)}, cfg)
    want = np.argmax(np.asarray(logits)[0], axis=-1)

    from repro.models.model import decode_step
    dstate = init_decode_state(cfg, 1, 16)
    got = []
    for t in range(8):
        lg, dstate = decode_step(state.params,
                                 dstate, jnp.asarray(toks[:, t: t + 1]), cfg)
        got.append(int(np.argmax(np.asarray(lg)[0, 0])))
    assert got == want.tolist()


def test_swa_ring_cache_matches_full_cache():
    """Mixtral SWA: ring buffer (bounded) decode == full cache decode."""
    cfg = get_smoke_config("mixtral-8x7b")   # window=32
    state = init_train_state(jax.random.PRNGKey(4), cfg)
    from repro.models.model import decode_step
    rng = np.random.RandomState(6)
    toks = rng.randint(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    # ring: cache_len == window -> ring=True path
    ring = init_decode_state(cfg, 1, cfg.window)
    full = init_decode_state(cfg, 1, 64)     # > window -> linear path
    for t in range(12):
        lr, ring = decode_step(state.params, ring,
                               jnp.asarray(toks[:, t: t + 1]), cfg)
        lf, full = decode_step(state.params, full,
                               jnp.asarray(toks[:, t: t + 1]), cfg)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                               rtol=2e-2, atol=2e-2)


def test_rglru_long_context_state_is_bounded():
    cfg = get_smoke_config("recurrentgemma-9b")
    dstate = init_decode_state(cfg, 1, cfg.local_window)
    nbytes = sum(np.prod(l.shape) * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(dstate.block_states))
    # recurrent state + window cache only — no 500k-token buffer
    assert nbytes < 4 << 20, nbytes
