"""Segmented write path: delta segment, seal policy, serving/persistence fixes.

The contract under test (ISSUE 2): after `build()`, an `add()` must be
searchable with no quantizer retraining and no sealed-graph rebuild
(observed via the `index_builds` / `quantizer_trains` / `seals` counters in
`stats()`), masks and rescore must apply across the sealed+delta union, and
`seal()` folds the delta encode-only.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (DeltaSegment, EngineConfig, Predicate,
                        QuantixarEngine, SealPolicy, exact_knn,
                        merge_candidates)
from repro.core.hnsw_build import HNSWConfig
from repro.core.ivf import IVFIndex, IVFConfig
from repro.core.pq import PQConfig
from repro.data.synthetic import gaussian_mixture
from repro.serving.batcher import RequestBatcher

N, N_EXTRA, DIM = 600, 60, 24
NO_AUTOSEAL = SealPolicy(auto=False)


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=8, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def extra():
    return gaussian_mixture(N_EXTRA, DIM, n_clusters=8, scale=0.2, seed=1)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(8, DIM, n_clusters=8, scale=0.2, seed=2)


def _engine(corpus, meta=None, **kw):
    kw.setdefault("hnsw", HNSWConfig(M=8, ef_construction=40))
    kw.setdefault("pq", PQConfig(m=4, k=16, iters=6))
    kw.setdefault("builder", "bulk")
    kw.setdefault("seal", NO_AUTOSEAL)
    eng = QuantixarEngine(EngineConfig(dim=DIM, **kw))
    eng.add(corpus, meta)
    eng.build()
    return eng


def _recall(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / gt.shape[1]
                    for a, b in zip(ids, gt)])


# ---------------------------------------------------------------------------
# Unit: segment primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_seal_policy_row_trigger(self):
        p = SealPolicy(max_delta_rows=100, max_delta_ratio=10.0)
        assert not p.should_seal(sealed_rows=1000, delta_rows=99)
        assert p.should_seal(sealed_rows=1000, delta_rows=100)

    def test_seal_policy_ratio_trigger(self):
        p = SealPolicy(max_delta_rows=10**9, max_delta_ratio=0.5)
        assert not p.should_seal(sealed_rows=1000, delta_rows=499)
        assert p.should_seal(sealed_rows=1000, delta_rows=500)
        # no sealed rows -> ratio trigger is meaningless
        assert not p.should_seal(sealed_rows=0, delta_rows=499)

    def test_delta_segment_global_ids_and_codes(self):
        seg = DeltaSegment(start=100, dim=4)
        seg.append(np.ones((3, 4), np.float32), np.zeros((3, 2), np.uint8))
        seg.append(np.full((2, 4), 2.0, np.float32), np.ones((2, 2), np.uint8))
        assert len(seg) == 5 and seg.start == 100 and seg.stop == 105
        assert seg.raw.shape == (5, 4)
        assert seg.codes.shape == (5, 2)
        with pytest.raises(ValueError):
            seg.append(np.ones((1, 4), np.float32))   # codes went missing

    def test_merge_candidates_orders_and_pads(self):
        d_a = np.array([[0.1, 0.5, np.inf]])
        i_a = np.array([[3, 7, -1]])
        d_b = np.array([[0.2, np.inf]])
        i_b = np.array([[100, -1]])
        d, i = merge_candidates(d_a, i_a, d_b, i_b, 4)
        assert i[0].tolist() == [3, 100, 7, -1]
        assert d[0, :3].tolist() == [pytest.approx(0.1), pytest.approx(0.2),
                                     pytest.approx(0.5)]
        assert np.isinf(d[0, 3])


# ---------------------------------------------------------------------------
# Engine: add-after-build rides the delta, no rebuild / no retraining
# ---------------------------------------------------------------------------

class TestSegmentedWritePath:
    @pytest.mark.parametrize("index,quant", [
        ("hnsw", "none"), ("hnsw", "pq"), ("hnsw", "bq"),
        ("ivf", "none"), ("flat", "pq")])
    def test_add_after_build_searchable_without_rebuild(
            self, corpus, extra, queries, index, quant):
        eng = _engine(corpus, index=index, quantization=quant)
        s = eng.stats()
        assert s["index_builds"] == 1 and s["sealed_rows"] == N
        trains = s["quantizer_trains"]
        assert trains == (0 if quant == "none" else 1)

        eng.add(extra)
        # querying a delta row by itself must surface its global id
        _, ids = eng.search(extra[:4], 5)
        for j in range(4):
            assert N + j in set(ids[j].tolist()), (index, quant)
        s = eng.stats()
        assert s["index_builds"] == 1, "add() triggered a sealed rebuild"
        assert s["quantizer_trains"] == trains, "add() retrained quantizers"
        assert s["delta_rows"] == N_EXTRA and s["sealed_rows"] == N

    def test_recall_across_union_matches_full_rebuild(
            self, corpus, extra, queries):
        full = np.concatenate([corpus, extra])
        gt = exact_knn(queries, full, 10, metric="cosine")
        eng = _engine(corpus)
        eng.add(extra)
        _, ids = eng.search(queries, 10)
        rebuilt = _engine(full)
        _, ids_rb = rebuilt.search(queries, 10)
        assert _recall(ids, gt) >= _recall(ids_rb, gt) - 0.05

    def test_seal_folds_encode_only(self, corpus, extra, queries):
        eng = _engine(corpus, quantization="pq")
        eng.add(extra)
        assert eng.seal()
        s = eng.stats()
        assert s["seals"] == 1 and s["delta_rows"] == 0
        assert s["sealed_rows"] == N + N_EXTRA
        assert s["index_builds"] == 2          # graph rebuilt once by seal()
        assert s["quantizer_trains"] == 1      # codebooks were NOT retrained
        _, ids = eng.search(extra[:4], 5)
        for j in range(4):
            assert N + j in set(ids[j].tolist())
        assert not eng.seal()                  # empty delta: no-op

    def test_auto_seal_policy_triggers_on_add(self, corpus, extra):
        eng = _engine(corpus, seal=SealPolicy(max_delta_rows=32,
                                              max_delta_ratio=10.0))
        eng.add(extra[:16])
        assert eng.stats()["delta_rows"] == 16
        eng.add(extra[16:])                    # 60 >= 32: policy fires
        s = eng.stats()
        assert s["seals"] == 1 and s["delta_rows"] == 0
        assert s["sealed_rows"] == N + N_EXTRA

    def test_filtered_rescored_union_agrees_with_oracle(
            self, corpus, extra, queries):
        meta = [{"cat": i % 4, "cat16": i % 16} for i in range(N)]
        meta_x = [{"cat": i % 4, "cat16": i % 16} for i in range(N_EXTRA)]
        eng = _engine(corpus, meta, quantization="pq",
                      pq=PQConfig(m=8, k=32, iters=8),
                      rescore=True, rescore_multiplier=8)
        eng.add(extra, meta_x)
        full = np.concatenate([corpus, extra])
        cats = np.array([m["cat"] for m in meta + meta_x])
        cats16 = np.array([m["cat16"] for m in meta + meta_x])

        # 25% selectivity: masked beam over sealed graph + delta scan merge
        d, ids = eng.search(queries, 5, flt=Predicate("cat", "eq", 2),
                            ef=256, rescore=True)
        valid = ids[ids >= 0]
        assert len(valid) and (cats[valid] == 2).all()
        allowed = np.where(cats == 2)[0]
        gt = allowed[exact_knn(queries, full[allowed], 5, metric="cosine")]
        assert _recall(ids, gt) >= 0.9

        # 6.25% selectivity: routed to the exact masked scan over the union
        d, ids = eng.search(queries, 5, flt=Predicate("cat16", "eq", 2),
                            rescore=True)
        valid = ids[ids >= 0]
        assert len(valid) and (cats16[valid] == 2).all()
        allowed = np.where(cats16 == 2)[0]
        gt = allowed[exact_knn(queries, full[allowed], 5, metric="cosine")]
        assert _recall(ids, gt) >= 0.99

    def test_mask_never_resurfaces_across_union(self, corpus, extra, queries):
        eng = _engine(corpus, quantization="pq", rescore=True)
        eng.add(extra)
        mask = np.ones(N + N_EXTRA, dtype=bool)
        dead = list(range(0, N, 3)) + list(range(N, N + N_EXTRA, 2))
        mask[dead] = False
        _, ids = eng.search(queries, 10, mask=mask, rescore=True)
        hit = set(ids[ids >= 0].tolist())
        assert not hit & set(dead)

    def test_persistence_roundtrip_keeps_delta(self, corpus, extra, queries):
        eng = _engine(corpus, quantization="pq")
        eng.add(extra)
        d1, i1 = eng.search(queries, 10)
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        s = eng2.stats()
        assert s["delta_rows"] == N_EXTRA and s["sealed_rows"] == N
        d2, i2 = eng2.search(queries, 10)
        assert eng2.stats()["index_builds"] == 0, "restored engine rebuilt"
        assert (i1 == i2).all()
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# API layer: collections ride the segmented write path
# ---------------------------------------------------------------------------

class TestCollectionSegments:
    def _collection(self, corpus):
        from repro.api import CollectionSchema, Database, VectorField
        col = Database().create_collection(CollectionSchema(
            name="seg", vector=VectorField(
                dim=DIM, index="hnsw", builder="bulk",
                hnsw=HNSWConfig(M=8, ef_construction=40))))
        col.upsert([f"doc-{i}" for i in range(N)], corpus)
        return col

    def test_upsert_after_search_no_rebuild(self, corpus, extra):
        col = self._collection(corpus)
        col.search(corpus[:2], 3)              # forces the first build
        builds = col.stats()["index_builds"]
        col.upsert([f"new-{i}" for i in range(8)], extra[:8])
        hits = col.query(extra[0]).top_k(3).run()
        assert hits[0].id == "new-0"
        s = col.stats()
        assert s["index_builds"] == builds, "upsert rebuilt the sealed index"
        assert s["delta_rows"] == 8
        col.close()

    def test_compact_without_tombstones_seals_delta(self, corpus, extra):
        col = self._collection(corpus)
        col.search(corpus[:2], 3)
        col.upsert([f"new-{i}" for i in range(8)], extra[:8])
        assert col.stats()["delta_rows"] == 8
        assert col.compact() == 0              # nothing dead to reclaim...
        s = col.stats()
        assert s["delta_rows"] == 0 and s["seals"] == 1   # ...but delta folded
        hits = col.query(extra[0]).top_k(3).run()
        assert hits[0].id == "new-0"
        col.close()


# ---------------------------------------------------------------------------
# Satellite: search() argument validation (ef falsy bug, k >= 1)
# ---------------------------------------------------------------------------

class TestSearchValidation:
    def test_k_must_be_positive(self, corpus, queries):
        eng = _engine(corpus)
        with pytest.raises(ValueError, match="k must be >= 1"):
            eng.search(queries, 0)
        with pytest.raises(ValueError, match="k must be >= 1"):
            eng.search(queries, -3)

    def test_explicit_ef_zero_is_honored(self, corpus, queries, monkeypatch):
        """`ef or default` silently replaced ef=0 with the config default."""
        eng = _engine(corpus)
        seen = {}
        orig = eng._hnsw_pass

        def spy(q, k, ef, mask, expansion_width=None):
            seen["ef"] = ef
            return orig(q, k, ef, mask, expansion_width)

        monkeypatch.setattr(eng, "_hnsw_pass", spy)
        eng.search(queries, 5, ef=0)
        assert seen["ef"] == 0                 # not cfg.ef_search (64)
        eng.search(queries, 5)
        assert seen["ef"] == eng.config.ef_search


# ---------------------------------------------------------------------------
# Satellite: IVF persistence keeps list_sizes
# ---------------------------------------------------------------------------

class TestIVFRestore:
    def test_list_sizes_survive_roundtrip(self, corpus):
        import jax.numpy as jnp
        ivf = IVFIndex(IVFConfig(nlist=16))
        ivf.train(jnp.asarray(corpus))
        ivf.build_lists(jnp.asarray(corpus))
        ivf2 = IVFIndex(IVFConfig(nlist=16))
        ivf2.load_state_dict(ivf.state_dict())
        assert ivf2.list_sizes is not None
        np.testing.assert_array_equal(np.asarray(ivf2.list_sizes),
                                      np.asarray(ivf.list_sizes))

    def test_restored_engine_stats_do_not_crash(self, corpus, queries):
        eng = _engine(corpus, index="ivf")
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        s = eng2.stats()                       # used to die on list_sizes=None
        assert s["ivf_lists"] == eng.config.ivf.nlist
        assert s["ivf_mean_list"] > 0

    @pytest.mark.parametrize("quant", ["none", "pq", "bq"])
    def test_quantized_ivf_roundtrip_identical(self, corpus, queries, quant):
        """Restore must mirror _build_index: PQ probes reconstructions under
        L2, BQ/none probe raw vectors — a metric or effective-vector mismatch
        silently changes (or crashes) restored searches."""
        eng = _engine(corpus, index="ivf", quantization=quant)
        d1, i1 = eng.search(queries, 10)
        eng2 = QuantixarEngine.from_state_dict(eng.config, eng.state_dict())
        d2, i2 = eng2.search(queries, 10)
        assert (i1 == i2).all()
        np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: batcher shutdown semantics
# ---------------------------------------------------------------------------

def _echo_search(q, k):
    return np.zeros((len(q), k), np.float32), np.zeros((len(q), k), np.int32)


class TestBatcherClose:
    def test_submit_after_close_raises(self):
        b = RequestBatcher(_echo_search)
        b.submit(np.zeros(4, np.float32), 2).result(timeout=5)
        b.close()
        with pytest.raises(RuntimeError, match="batcher closed"):
            b.submit(np.zeros(4, np.float32), 2)

    def test_close_is_idempotent(self):
        b = RequestBatcher(_echo_search)
        b.close()
        b.close()

    def test_queued_futures_fail_instead_of_hanging(self):
        gate = threading.Event()

        def slow(q, k):
            gate.wait(5)
            return _echo_search(q, k)

        b = RequestBatcher(slow, max_batch=1, max_wait_ms=1.0)
        f_inflight = b.submit(np.zeros(4, np.float32), 2)
        time.sleep(0.05)                       # worker picks it up, blocks
        f_queued = b.submit(np.zeros(4, np.float32), 2)
        b.close(timeout=0.2)
        with pytest.raises(RuntimeError, match="batcher closed"):
            f_queued.result(timeout=1)
        gate.set()                             # in-flight request completes
        assert f_inflight.result(timeout=5)[0].shape == (2,)
