"""Flat (exact) index: correctness, chunked streaming, masks, merge."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # deterministic shim keeps properties runnable
    from _hypothesis_fallback import given, settings, st

from repro.core import exact_knn, flat_search, merge_topk


def _rand(n, d, seed=0):
    return np.random.RandomState(seed).randn(n, d).astype(np.float32)


class TestFlatSearch:
    def test_matches_ground_truth(self):
        q, x = _rand(16, 32, 1), _rand(300, 32, 2)
        _, ids = flat_search(jnp.asarray(q), jnp.asarray(x), 10,
                             metric="cosine")
        gt = exact_knn(q, x, 10, metric="cosine")
        assert (np.asarray(ids) == gt).mean() > 0.99

    def test_chunked_equals_unchunked(self):
        q, x = _rand(8, 16, 3), _rand(257, 16, 4)   # non-multiple of chunk
        d1, i1 = flat_search(jnp.asarray(q), jnp.asarray(x), 7, metric="l2")
        d2, i2 = flat_search(jnp.asarray(q), jnp.asarray(x), 7, metric="l2",
                             chunk=64)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-4, atol=1e-4)
        assert (np.asarray(i1) == np.asarray(i2)).all()

    def test_mask_excludes_rows(self):
        q, x = _rand(4, 8, 5), _rand(100, 8, 6)
        mask = np.zeros(100, dtype=bool)
        mask[::3] = True
        _, ids = flat_search(jnp.asarray(q), jnp.asarray(x), 5,
                             metric="l2", mask=jnp.asarray(mask))
        assert (np.asarray(ids) % 3 == 0).all()

    def test_masked_chunked_agrees(self):
        q, x = _rand(4, 8, 7), _rand(120, 8, 8)
        mask = np.random.RandomState(9).rand(120) > 0.5
        d1, i1 = flat_search(jnp.asarray(q), jnp.asarray(x), 5, metric="l2",
                             mask=jnp.asarray(mask))
        d2, i2 = flat_search(jnp.asarray(q), jnp.asarray(x), 5, metric="l2",
                             mask=jnp.asarray(mask), chunk=32)
        assert (np.asarray(i1) == np.asarray(i2)).all()

    def test_base_index_offsets(self):
        q, x = _rand(2, 8, 10), _rand(50, 8, 11)
        _, i0 = flat_search(jnp.asarray(q), jnp.asarray(x), 3, metric="l2")
        _, i7 = flat_search(jnp.asarray(q), jnp.asarray(x), 3, metric="l2",
                            base_index=700)
        assert (np.asarray(i7) - np.asarray(i0) == 700).all()

    def test_k_larger_than_corpus(self):
        q, x = _rand(2, 8, 12), _rand(5, 8, 13)
        d, ids = flat_search(jnp.asarray(q), jnp.asarray(x), 10, metric="l2")
        assert ids.shape == (2, 5)


class TestMergeTopK:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 12), st.integers(1, 12), st.integers(1, 8),
           st.integers(0, 10_000))
    def test_merge_equals_global_topk(self, ka, kb, k, seed):
        """top-k(merge(A, B)) == top-k(A ∪ B) — the cross-shard invariant."""
        rng = np.random.RandomState(seed)
        q = 3
        d_a = rng.rand(q, ka).astype(np.float32)
        d_b = rng.rand(q, kb).astype(np.float32)
        i_a = rng.randint(0, 1000, (q, ka)).astype(np.int32)
        i_b = rng.randint(1000, 2000, (q, kb)).astype(np.int32)
        k = min(k, ka + kb)
        md, mi = merge_topk(jnp.asarray(d_a), jnp.asarray(i_a),
                            jnp.asarray(d_b), jnp.asarray(i_b), k)
        alld = np.concatenate([d_a, d_b], axis=1)
        want = np.sort(alld, axis=1)[:, :k]
        np.testing.assert_allclose(np.asarray(md), want, rtol=1e-6, atol=1e-6)

    def test_merge_associative(self):
        rng = np.random.RandomState(0)
        parts = [(jnp.asarray(rng.rand(2, 4).astype(np.float32)),
                  jnp.asarray(rng.randint(0, 100, (2, 4)).astype(np.int32)))
                 for _ in range(3)]
        k = 4
        (a, b), (c, d2), (e, f) = parts
        left = merge_topk(*merge_topk(a, b, c, d2, k), e, f, k)
        right = merge_topk(a, b, *merge_topk(c, d2, e, f, k), k)
        np.testing.assert_allclose(np.asarray(left[0]), np.asarray(right[0]),
                                   rtol=1e-6, atol=1e-6)
