"""HNSW: graph invariants, recall vs brute force, device/host parity."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HNSWConfig, build, bulk_build, exact_knn, recall_at_k
from repro.core.hnsw_build import PAD, preprocess_vectors
from repro.core.hnsw_search import search, search_numpy_reference, to_device
from repro.data.synthetic import gaussian_mixture

N, DIM = 1200, 24


@pytest.fixture(scope="module")
def corpus():
    return gaussian_mixture(N, DIM, n_clusters=20, scale=0.2, seed=0)


@pytest.fixture(scope="module")
def queries():
    return gaussian_mixture(40, DIM, n_clusters=20, scale=0.2, seed=9)


@pytest.fixture(scope="module")
def packed(corpus):
    return build(corpus, HNSWConfig(M=12, ef_construction=80,
                                    metric="cosine", seed=0))


@pytest.fixture(scope="module")
def packed_bulk(corpus):
    return bulk_build(corpus, HNSWConfig(M=12, metric="cosine", seed=0))


class TestGraphInvariants:
    def test_degrees_bounded(self, packed):
        deg0 = (packed.adj0 != PAD).sum(1)
        assert deg0.max() <= packed.config.m0
        assert (packed.upper_adj != PAD).sum(-1).max() <= packed.config.M

    def test_no_duplicate_neighbours(self, packed):
        """Required by the device search's scatter-add visited trick."""
        for row in packed.adj0:
            real = row[row != PAD]
            assert len(set(real.tolist())) == len(real)

    def test_no_self_loops(self, packed):
        for i, row in enumerate(packed.adj0):
            assert i not in row[row != PAD]

    def test_entry_point_valid(self, packed):
        assert 0 <= packed.entry_global < packed.n
        assert packed.levels[packed.entry_global] == packed.max_level

    def test_mostly_connected_at_base(self, packed):
        """BFS from entry reaches nearly every node (navigability)."""
        seen = {packed.entry_global}
        frontier = [packed.entry_global]
        while frontier:
            nxt = []
            for node in frontier:
                for nb in packed.adj0[node]:
                    if nb != PAD and nb not in seen:
                        seen.add(int(nb))
                        nxt.append(int(nb))
            frontier = nxt
        assert len(seen) > 0.98 * packed.n

    def test_level_distribution_geometric(self, packed):
        share_upper = (packed.levels >= 1).mean()
        assert 0.02 < share_upper < 0.25   # ~1/M ± slack


class TestSearch:
    def test_recall_faithful_builder(self, packed, corpus, queries):
        g, max_level, metric = to_device(packed)
        qn = preprocess_vectors(queries, "cosine")
        _, ids = search(g, jnp.asarray(qn), k=10, ef=64,
                        max_level=max_level, metric=metric)
        gt = exact_knn(queries, corpus, 10, metric="cosine")
        assert recall_at_k(np.asarray(ids), gt) > 0.9

    def test_recall_bulk_builder(self, packed_bulk, corpus, queries):
        g, max_level, metric = to_device(packed_bulk)
        qn = preprocess_vectors(queries, "cosine")
        _, ids = search(g, jnp.asarray(qn), k=10, ef=64,
                        max_level=max_level, metric=metric)
        gt = exact_knn(queries, corpus, 10, metric="cosine")
        assert recall_at_k(np.asarray(ids), gt) > 0.9

    def test_ef_improves_recall(self, packed, corpus, queries):
        g, max_level, metric = to_device(packed)
        qn = preprocess_vectors(queries, "cosine")
        gt = exact_knn(queries, corpus, 10, metric="cosine")

        def r(ef):
            _, ids = search(g, jnp.asarray(qn), k=10, ef=ef,
                            max_level=max_level, metric=metric)
            return recall_at_k(np.asarray(ids), gt)

        assert r(96) >= r(12) - 0.02

    def test_jax_matches_numpy_reference(self, packed, queries):
        g, max_level, metric = to_device(packed)
        qn = preprocess_vectors(queries[:10], "cosine")
        _, ids_jax = search(g, jnp.asarray(qn), k=10, ef=48,
                            max_level=max_level, metric=metric)
        _, ids_np = search_numpy_reference(packed, queries[:10], 10, 48)
        overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                           for a, b in zip(np.asarray(ids_jax), ids_np)])
        assert overlap > 0.95, overlap

    def test_l2_metric_build_and_search(self, corpus, queries):
        packed = build(corpus[:600],
                       HNSWConfig(M=8, ef_construction=60, metric="l2"))
        g, max_level, metric = to_device(packed)
        _, ids = search(g, jnp.asarray(queries), k=5, ef=48,
                        max_level=max_level, metric=metric)
        gt = exact_knn(queries, corpus[:600], 5, metric="l2")
        assert recall_at_k(np.asarray(ids), gt) > 0.85

    def test_k_greater_than_ef_rejected(self, packed, queries):
        g, max_level, metric = to_device(packed)
        with pytest.raises(ValueError):
            search(g, jnp.asarray(queries), k=20, ef=10,
                   max_level=max_level, metric=metric)

    def test_state_dict_roundtrip(self, packed, queries):
        from repro.core.hnsw_build import PackedHNSW
        state = packed.state_dict()
        packed2 = PackedHNSW.from_state_dict(state, packed.config)
        g1, ml1, m1 = to_device(packed)
        g2, ml2, m2 = to_device(packed2)
        qn = preprocess_vectors(queries[:5], "cosine")
        _, i1 = search(g1, jnp.asarray(qn), k=5, ef=32, max_level=ml1,
                       metric=m1)
        _, i2 = search(g2, jnp.asarray(qn), k=5, ef=32, max_level=ml2,
                       metric=m2)
        assert (np.asarray(i1) == np.asarray(i2)).all()
