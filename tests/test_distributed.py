"""Distributed search + sharding policy.

The multi-device tests run in a SUBPROCESS with
--xla_force_host_platform_device_count=8 so the rest of the suite keeps
seeing the real (single) device, per the dry-run isolation rule.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_local_mesh
    from repro.distributed.search import (make_flat_search, make_pq_search,
                                          make_hamming_search)
    from repro.core import exact_knn
    from repro.core.pq import ProductQuantizer, PQConfig, build_adc_lut
    from repro.core.bq import BinaryQuantizer, BQConfig
    from repro.core.distances import normalize
    from repro.data.synthetic import gaussian_mixture

    mesh = make_local_mesh(data=4, model=2)
    N, D, Q, K = 1600, 32, 8, 10
    x = gaussian_mixture(N, D, seed=0)
    q = gaussian_mixture(Q, D, seed=1)

    # ---- flat: sharded == exact ----
    xn = np.asarray(normalize(jnp.asarray(x)))
    qn = np.asarray(normalize(jnp.asarray(q)))
    fn = make_flat_search(mesh, k=K, metric="cosine", dim=D)
    d, ids = fn(jnp.asarray(xn), jnp.asarray(qn))
    gt = exact_knn(q, x, K, metric="cosine")
    rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / K
                   for a, b in zip(np.asarray(ids), gt)])
    assert rec > 0.99, f"flat sharded recall {rec}"

    # ---- pq: sharded ADC == single-host ADC ----
    pq = ProductQuantizer(PQConfig(m=8, k=32, iters=6))
    pq.train(jnp.asarray(x))
    codes = pq.encode(jnp.asarray(x))
    lut = build_adc_lut(jnp.asarray(q), pq.codebooks)
    fn_pq = make_pq_search(mesh, k=K, m_subspaces=8)
    d_sh, ids_sh = fn_pq(codes, lut)
    d_local, ids_local = pq.search(codes, jnp.asarray(q), K)
    np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_local),
                               rtol=1e-4, atol=1e-4)

    # ---- bq: sharded hamming == single-host ----
    bq = BinaryQuantizer(BQConfig(bits=64))   # 2 words: divisible by model=2
    bq.train(jnp.asarray(x))
    codes_b = bq.encode(jnp.asarray(x))
    q_codes = bq.encode(jnp.asarray(q))
    fn_bq = make_hamming_search(mesh, k=K, words=2)
    d_sh, ids_sh = fn_bq(codes_b, q_codes)
    d_loc, ids_loc = bq.search(codes_b, jnp.asarray(q), K)
    np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_loc),
                               rtol=1e-5, atol=1e-5)

    # ---- model train_step lowers + runs on 4x2 mesh ----
    from repro.configs import get_smoke_config
    from repro.models import init_train_state, make_train_step
    from repro.distributed.sharding import ShardingPolicy
    from repro.optim import AdamWConfig
    cfg = get_smoke_config("qwen2-1.5b").with_overrides(
        batch_axes=("data",))
    policy = ShardingPolicy(mesh)
    with mesh:
        state = jax.jit(lambda k: init_train_state(k, cfg))(
            jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=5)))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "targets": jnp.ones((8, 16), jnp.int32),
                 "segment_ids": jnp.ones((8, 16), jnp.int32)}
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    print("SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_sharded_search_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + _ROOT
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr


class TestShardingPolicy:
    def _policy(self):
        import jax
        from repro.distributed.sharding import ShardingPolicy
        from repro.launch.mesh import make_local_mesh
        return ShardingPolicy(make_local_mesh(1, 1))

    def test_divisibility_guard(self):
        from jax.sharding import PartitionSpec as P
        pol = self._policy()
        # model axis size 1 -> everything trivially divisible; spec exists
        spec = pol.param_spec("units/0/mlp/wg", (4, 64, 128))
        assert isinstance(spec, P)

    def test_row_parallel_names(self):
        pol = self._policy()
        spec = pol.param_spec("units/0/mlp/wd", (4, 128, 64))
        # contraction dim (ndim-2) gets the model axis (size 1 here -> ok)
        assert len(spec) == 3

    def test_batch_spec_skips_indivisible(self):
        pol = self._policy()
        assert pol.batch_spec((1, 5)) is not None


def test_production_mesh_shapes():
    """Mesh helper math (device-count-independent checks)."""
    from repro.launch.mesh import batch_axes

    class FakeMesh:
        axis_names = ("pod", "data", "model")

    assert batch_axes(FakeMesh()) == ("pod", "data")

    class FakeMesh2:
        axis_names = ("data", "model")

    assert batch_axes(FakeMesh2()) == ("data",)
