"""Embedded REST front-end: `ThreadingHTTPServer` over `QuantixarService`.

Stdlib-only (no new dependencies).  Each route parses into a wire-protocol
request dataclass and goes through `QuantixarService.dispatch`; responses are
always JSON envelopes —

    200  {"ok": true,  "result": {...}}
    4xx/5xx {"ok": false, "error": {"code": ..., "message": ...}}

— never an HTML error page or a traceback body.  Status codes follow the
error taxonomy: SCHEMA_ERROR / INVALID_ARGUMENT -> 400, NOT_FOUND -> 404,
UNAVAILABLE -> 503, INTERNAL -> 500.

Routes (all under /v1):

    GET    /v1/healthz
    GET    /v1/collections
    POST   /v1/collections                      {"schema": {...}}
    GET    /v1/collections/{name}
    DELETE /v1/collections/{name}
    POST   /v1/collections/{name}/points        {"ids", "vectors", "payloads"}
    POST   /v1/collections/{name}/points/delete {"ids": [...]}
    GET    /v1/collections/{name}/points/{id}
    POST   /v1/collections/{name}/search        {"vector", "k", "filter", ...}
                                                or {"text", "text_field", ...}
                                                or {"plan": {...}, "explain"}
    POST   /v1/collections/{name}/count         {"filter": {...}}
    GET    /v1/collections/{name}/count
    POST   /v1/collections/{name}/compact       {"shard": N} (optional)
    POST   /v1/collections/{name}/rebalance     {"shards", "replicas"}
    GET    /v1/collections/{name}/shards
    GET    /v1/collections/{name}/stats
    GET    /v1/stats
    POST   /v1/snapshot                         {"path", "step"}
    POST   /v1/restore                          {"path", "generation"}
    POST   /v1/rpc                              raw protocol envelope

Because `ThreadingHTTPServer` handles each connection on its own thread,
concurrent single-vector searches naturally coalesce in the collection's
`RequestBatcher` behind the service.
"""

from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qs, unquote

import numpy as np

from ..api import requests as rq
from .service import QuantixarService, ServiceConfig

ERROR_STATUS = {
    rq.SCHEMA_ERROR: 400,
    rq.INVALID_ARGUMENT: 400,
    rq.NOT_FOUND: 404,
    rq.UNAVAILABLE: 503,
    rq.INTERNAL: 500,
}


def _json_default(obj: Any):
    """numpy scalars/arrays inside stats payloads -> plain JSON."""
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")


def _invalid(message: str) -> rq.ApiError:
    return rq.error_to_exception(rq.ErrorInfo(rq.INVALID_ARGUMENT, message))


def _query_params(qs: str) -> Dict[str, Any]:
    """`?include_vector=false&k=5` -> typed scalars (GET routes have no
    body; the JSON body still wins on key collisions)."""
    out: Dict[str, Any] = {}
    for key, values in parse_qs(qs).items():
        value = values[-1]
        low = value.lower()
        if low in ("true", "1"):
            out[key] = True
        elif low in ("false", "0"):
            out[key] = False
        else:
            try:
                out[key] = int(value)
            except ValueError:
                try:
                    out[key] = float(value)
                except ValueError:
                    out[key] = value
    return out


def _build(cls, **kw) -> rq.Request:
    """Request constructor that turns bad/missing body keys into
    INVALID_ARGUMENT instead of a TypeError 500."""
    try:
        return cls(**kw)
    except TypeError as exc:
        raise _invalid(f"bad request body for {cls.op!r}: {exc}")


# (method, compiled path regex, builder(body, *path groups) -> Request)
_ROUTES: List[Tuple[str, "re.Pattern[str]", Callable[..., rq.Request]]] = []


def _route(method: str, pattern: str):
    def register(fn):
        _ROUTES.append((method, re.compile(pattern), fn))
        return fn
    return register


@_route("GET", r"^/v1/healthz$")
def _r_health(body):
    return rq.Health()


@_route("GET", r"^/v1/collections$")
def _r_list(body):
    return rq.ListCollections()


@_route("POST", r"^/v1/collections$")
def _r_create(body):
    schema = body.get("schema", body)
    return _build(rq.CreateCollection, schema=schema)


@_route("GET", r"^/v1/collections/([^/]+)$")
def _r_describe(body, name):
    return rq.DescribeCollection(collection=name)


@_route("DELETE", r"^/v1/collections/([^/]+)$")
def _r_drop(body, name):
    return rq.DropCollection(collection=name)


@_route("POST", r"^/v1/collections/([^/]+)/points$")
def _r_upsert(body, name):
    return _build(rq.Upsert, collection=name, **body)


@_route("POST", r"^/v1/collections/([^/]+)/points/delete$")
def _r_delete(body, name):
    return _build(rq.Delete, collection=name, **body)


@_route("GET", r"^/v1/collections/([^/]+)/points/([^/]+)$")
def _r_get(body, name, id_):
    # ?include_vector=false skips serializing the (possibly large) vector
    return rq.Get(collection=name, id=id_,
                  include_vector=bool(body.get("include_vector", True)))


@_route("POST", r"^/v1/collections/([^/]+)/search$")
def _r_search(body, name):
    return _build(rq.Search, collection=name, **body)


# POST carries an optional filter tree in the body; GET counts everything
@_route("POST", r"^/v1/collections/([^/]+)/count$")
@_route("GET", r"^/v1/collections/([^/]+)/count$")
def _r_count(body, name):
    return _build(rq.Count, collection=name, **body)


@_route("POST", r"^/v1/collections/([^/]+)/compact$")
def _r_compact(body, name):
    # ?shard=N (or body {"shard": N}) compacts one shard of a sharded
    # collection instead of the whole thing
    return _build(rq.Compact, collection=name, **body)


@_route("POST", r"^/v1/collections/([^/]+)/rebalance$")
def _r_rebalance(body, name):
    return _build(rq.Rebalance, collection=name, **body)


@_route("GET", r"^/v1/collections/([^/]+)/shards$")
def _r_shard_stats(body, name):
    return rq.ShardStats(collection=name)


@_route("GET", r"^/v1/collections/([^/]+)/stats$")
def _r_col_stats(body, name):
    return rq.Stats(collection=name)


@_route("GET", r"^/v1/stats$")
def _r_stats(body):
    return rq.Stats()


@_route("POST", r"^/v1/snapshot$")
def _r_snapshot(body):
    return _build(rq.Snapshot, **body)


@_route("POST", r"^/v1/restore$")
def _r_restore(body):
    return _build(rq.Restore, **body)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "quantixar"

    # silence per-request stderr logging (opt back in via server attribute)
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def do_GET(self):
        self._handle("GET")

    def do_POST(self):
        self._handle("POST")

    def do_DELETE(self):
        self._handle("DELETE")

    def send_error(self, code, message=None, explain=None):
        """Stdlib-level failures (unsupported method, malformed request
        line, ...) must keep the JSON error contract — never an HTML page."""
        taxonomy = {404: rq.NOT_FOUND, 501: rq.INVALID_ARGUMENT}
        short = message or self.responses.get(code, ("unknown error",))[0]
        info = rq.ErrorInfo(
            taxonomy.get(code,
                         rq.INVALID_ARGUMENT if code < 500 else rq.INTERNAL),
            f"HTTP {code}: {short}")
        self._reply(code, False, info.to_dict())
        self.close_connection = True

    # ------------------------------------------------------------- internals
    @property
    def _service(self) -> QuantixarService:
        return self.server.quantixar_service

    def _read_body(self) -> Dict[str, Any]:
        try:
            length = int(self.headers.get("Content-Length") or 0)
        except ValueError:
            raise _invalid("Content-Length header is not an integer")
        if length == 0:
            return {}
        raw = self.rfile.read(length)
        try:
            body = json.loads(raw.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as exc:
            raise _invalid(f"request body is not valid JSON: {exc}")
        if not isinstance(body, dict):
            raise _invalid(
                f"request body must be a JSON object, "
                f"got {type(body).__name__}")
        return body

    def _handle(self, method: str) -> None:
        try:
            path, _, qs = self.path.partition("?")
            body = {**_query_params(qs), **self._read_body()}
            if path == "/v1/rpc" and method == "POST":
                ok, payload = self._service.dispatch_dict(body)
                code = 200 if ok else ERROR_STATUS.get(
                    payload.get("code", rq.INTERNAL), 500)
                return self._reply(code, ok, payload)
            for route_method, pattern, builder in _ROUTES:
                if route_method != method:
                    continue
                m = pattern.match(path)
                if m is None:
                    continue
                groups = [unquote(g) for g in m.groups()]
                request = builder(body, *groups)
                out = self._service.dispatch(request)
                if isinstance(out, rq.ErrorInfo):
                    return self._reply(ERROR_STATUS.get(out.code, 500),
                                       False, out.to_dict())
                return self._reply(200, True, out.to_dict())
            info = rq.ErrorInfo(rq.NOT_FOUND,
                                f"no route {method} {path}")
            return self._reply(404, False, info.to_dict())
        except rq.ApiError as exc:
            return self._reply(ERROR_STATUS.get(exc.code, 500), False,
                               exc.info.to_dict())
        except Exception as exc:             # noqa: BLE001 — no tracebacks
            info = rq.ErrorInfo(rq.INTERNAL,
                                f"{type(exc).__name__}: {exc}")
            return self._reply(500, False, info.to_dict())

    def _reply(self, status: int, ok: bool, payload: Dict[str, Any]) -> None:
        envelope = {"ok": ok, ("result" if ok else "error"): payload}
        try:
            data = json.dumps(envelope, default=_json_default).encode("utf-8")
        except TypeError as exc:
            status, data = 500, json.dumps({
                "ok": False,
                "error": rq.ErrorInfo(
                    rq.INTERNAL, f"unserializable response: {exc}").to_dict(),
            }).encode("utf-8")
        try:
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)
        except (BrokenPipeError, ConnectionResetError):
            pass                             # client went away mid-reply


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    # socketserver's default listen backlog is 5; a concurrent client wave
    # (the smoke test fires 100+ simultaneous connects) overflows it while
    # the first requests hold the accept loop, and overflowed connects
    # surface as connection-reset on loaded 1-core boxes
    request_queue_size = 256


class QuantixarHTTPServer:
    """Embedded server: `start()` for a background thread (tests, drivers),
    `serve_forever()` for a foreground process (`repro.launch.serve`)."""

    def __init__(self, service: Optional[QuantixarService] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 config: Optional[ServiceConfig] = None,
                 verbose: bool = False):
        self.service = service or QuantixarService(config=config)
        self._httpd = _Server((host, port), _Handler)
        self._httpd.quantixar_service = self.service
        self._httpd.verbose = verbose
        self._thread: Optional[threading.Thread] = None
        self._serving = False

    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "QuantixarHTTPServer":
        self._serving = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="quantixar-http", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self._serving = True
        self._httpd.serve_forever()

    def shutdown(self, close_service: bool = True) -> None:
        # BaseServer.shutdown() waits on serve_forever's exit event, which
        # only ever fires if serve_forever ran — guard so shutting down a
        # constructed-but-never-started server cannot hang forever
        if self._serving:
            self._httpd.shutdown()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self._httpd.server_close()
        if close_service:
            self.service.close()
