"""`QuantixarService`: the transport-agnostic request plane over a Database.

One service instance owns one `Database` and turns wire-protocol requests
(`repro.api.requests`) into typed responses:

  * dispatch is a pure function of the request dataclass — the HTTP server,
    an in-process test harness, or any future transport all call
    `dispatch()` and get back a `Response` or an `ErrorInfo`, never a raw
    exception;
  * single-vector searches flow through each collection's `RequestBatcher`
    (via the fluent `Query` path), so concurrent wire requests coalesce into
    padded engine batches without any caller touching `.batcher`;
  * every internal failure is mapped onto the structured error taxonomy
    (SCHEMA_ERROR / NOT_FOUND / INVALID_ARGUMENT / UNAVAILABLE / INTERNAL).

Snapshot/Restore round-trip the whole database through the checkpoint
store: `Restore` atomically swaps the served `Database` for the one loaded
from disk.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple, Type, Union

import numpy as np

from ..api import requests as rq
from ..api.collection import CollectionClosed, QueryRetriesExhausted
from ..api.database import Database
from ..api.plan import plan_from_dict
from ..api.query import Hit
from ..api.schema import BatcherConfig, CollectionSchema, SchemaError
from ..cluster.sharded import ShardedCollection, ShardUnavailable
from .batcher import BatcherClosed


@dataclasses.dataclass
class ServiceConfig:
    """Service-plane knobs, applied on top of per-collection schemas."""

    # batcher defaults for collections whose schema doesn't specify one
    default_max_batch: int = 32
    default_max_wait_ms: float = 2.0
    # bound on how long one search request may sit in the serving queue
    query_timeout_s: float = 60.0

    def default_batcher(self) -> BatcherConfig:
        return BatcherConfig(max_batch=self.default_max_batch,
                             max_wait_ms=self.default_max_wait_ms)


def to_error_info(exc: BaseException) -> rq.ErrorInfo:
    """Internal exception -> structured taxonomy entry.  The order matters:
    `ApiError` carries its own info; `SchemaError` is a ValueError subclass
    so it must be tested before the generic INVALID_ARGUMENT bucket."""
    if isinstance(exc, rq.ApiError):
        return exc.info
    if isinstance(exc, SchemaError):
        return rq.ErrorInfo(rq.SCHEMA_ERROR, str(exc))
    if isinstance(exc, KeyError):
        # NOT a not-found: genuine lookups are wrapped at their call sites
        # (`_col`, drop).  A bare KeyError here is a malformed body — e.g. a
        # schema dict without "name" or a filter node missing "column".
        missing = exc.args[0] if exc.args else exc
        return rq.ErrorInfo(rq.INVALID_ARGUMENT,
                            f"missing required key {missing!r}")
    if isinstance(exc, FileNotFoundError):
        return rq.ErrorInfo(rq.NOT_FOUND, str(exc))
    if isinstance(exc, TimeoutError):
        return rq.ErrorInfo(rq.UNAVAILABLE, str(exc) or "request timed out")
    # shutdown / compaction churn / a shard with no healthy replicas:
    # transient, the caller should retry
    if isinstance(exc, (BatcherClosed, CollectionClosed,
                        QueryRetriesExhausted, ShardUnavailable)):
        return rq.ErrorInfo(rq.UNAVAILABLE, str(exc))
    if isinstance(exc, RuntimeError):
        return rq.ErrorInfo(rq.INTERNAL, str(exc))
    if isinstance(exc, (ValueError, TypeError)):
        return rq.ErrorInfo(rq.INVALID_ARGUMENT, str(exc))
    return rq.ErrorInfo(rq.INTERNAL,
                        f"{type(exc).__name__}: {exc}")


def _hit_to_dict(hit: Hit) -> Dict[str, Any]:
    out: Dict[str, Any] = {"id": hit.id, "score": float(hit.score),
                           "payload": hit.payload}
    if hit.vector is not None:
        out["vector"] = np.asarray(hit.vector, dtype=np.float32).tolist()
    return out


class QuantixarService:
    def __init__(self, db: Optional[Database] = None,
                 config: Optional[ServiceConfig] = None):
        self.db = db if db is not None else Database()  # guarded-by: _lock
        self.config = config or ServiceConfig()
        # serializes DDL and the restore swap; data-plane ops rely on each
        # collection's own lock
        self._lock = threading.RLock()

    # -------------------------------------------------------------- dispatch
    def dispatch(self, request: rq.Request
                 ) -> Union[rq.Response, rq.ErrorInfo]:
        """Handle one typed request; failures come back as `ErrorInfo`."""
        handler = self._HANDLERS.get(type(request))
        if handler is None:
            return rq.ErrorInfo(
                rq.INVALID_ARGUMENT,
                f"unhandled request type {type(request).__name__}")
        try:
            return handler(self, request)
        except Exception as exc:             # noqa: BLE001 — errors are data
            return to_error_info(exc)

    def dispatch_dict(self, envelope: Dict[str, Any]
                      ) -> Tuple[bool, Dict[str, Any]]:
        """Raw envelope dict -> (ok, result-or-error dict): the full wire
        round-trip for transports that only speak JSON."""
        try:
            request = rq.decode_request(envelope)
        except rq.ApiError as exc:
            return False, exc.info.to_dict()
        out = self.dispatch(request)
        if isinstance(out, rq.ErrorInfo):
            return False, out.to_dict()
        return True, out.to_dict()

    def close(self) -> None:
        with self._lock:
            self.db.close()

    # ------------------------------------------------------------- internals
    def _col(self, name: str):
        try:
            # a restore swaps self.db atomically; data-plane handlers may
            # read the old or new reference, and either is a consistent
            # database whose collections guard themselves
            return self.db.collection(name)  # unguarded-ok: atomic ref snapshot; restore swap is safe to race
        except KeyError as exc:
            raise rq.error_to_exception(
                rq.ErrorInfo(rq.NOT_FOUND, str(exc.args[0])))

    # -------------------------------------------------------------- handlers
    def _create_collection(self, req: rq.CreateCollection) -> rq.CollectionInfo:
        if not isinstance(req.schema, dict):
            raise rq.error_to_exception(rq.ErrorInfo(
                rq.INVALID_ARGUMENT,
                f"schema must be an object, got {type(req.schema).__name__}"))
        schema = CollectionSchema.from_dict(req.schema)
        if schema.batcher is None:           # service-level default knobs
            schema = dataclasses.replace(
                schema, batcher=self.config.default_batcher())
        with self._lock:
            col = self.db.create_collection(schema)
        return rq.CollectionInfo(name=col.name, schema=col.schema.to_dict())

    def _drop_collection(self, req: rq.DropCollection) -> rq.Ack:
        with self._lock:
            try:
                self.db.drop_collection(req.collection)
            except KeyError as exc:
                raise rq.error_to_exception(
                    rq.ErrorInfo(rq.NOT_FOUND, str(exc.args[0])))
        return rq.Ack()

    def _list_collections(self, req: rq.ListCollections) -> rq.CollectionList:
        with self._lock:      # create/drop mutate the dict we iterate
            return rq.CollectionList(collections=self.db.list_collections())

    def _describe_collection(self, req: rq.DescribeCollection
                             ) -> rq.CollectionInfo:
        col = self._col(req.collection)
        return rq.CollectionInfo(name=col.name, schema=col.schema.to_dict())

    def _upsert(self, req: rq.Upsert) -> rq.UpsertResult:
        col = self._col(req.collection)
        vectors = np.asarray(req.vectors, dtype=np.float32)
        n = col.upsert(req.ids, vectors, req.payloads)
        return rq.UpsertResult(upserted=n)

    def _delete(self, req: rq.Delete) -> rq.DeleteResult:
        col = self._col(req.collection)
        return rq.DeleteResult(deleted=col.delete(req.ids))

    def _get(self, req: rq.Get) -> rq.GetResult:
        col = self._col(req.collection)
        e = col.get(req.id)
        if e is None:
            return rq.GetResult(entity=None)
        entity: Dict[str, Any] = {"id": e.id, "payload": e.payload}
        if req.include_vector:
            entity["vector"] = np.asarray(e.vector,
                                          dtype=np.float32).tolist()
        return rq.GetResult(entity=entity)

    def _search(self, req: rq.Search) -> rq.SearchResult:
        col = self._col(req.collection)
        timeout = self.config.query_timeout_s
        if req.plan is not None:
            # full declarative plan: validate/execute through the one plan
            # path (trivial plans still coalesce in the RequestBatcher)
            plan = plan_from_dict(req.plan)
            out = col.execute_plan(plan, include_vector=req.include_vector,
                                   timeout=timeout, explain=req.explain)
            batched = plan.batched
        else:
            if req.vector is None and req.text is None:
                raise rq.error_to_exception(rq.ErrorInfo(
                    rq.INVALID_ARGUMENT,
                    "search needs either 'vector', 'text', or 'plan'"))
            vector = None
            if req.vector is not None:
                vector = np.asarray(req.vector, dtype=np.float32)
            flt = rq.filter_from_dict(req.filter)
            query = col.query(vector).top_k(req.k)
            if req.text is not None:
                # keyword leg: alone -> pure sparse plan; with a vector ->
                # hybrid RRF plan, same compile as the fluent Query.text()
                query = query.text(req.text, field=req.text_field)
            if flt is not None:
                query = query.filter(flt)
            if req.ef is not None:
                query = query.ef(req.ef)
            if req.rescore is not None:
                query = query.rescore(req.rescore)
            if req.expansion_width is not None:
                query = query.expansion_width(req.expansion_width)
            if req.include_vector:
                query = query.include("vector")
            # the fluent builder compiles to a trivial plan: 1-D requests
            # coalesce through the RequestBatcher, 2-D run as one batch
            out = (query.explain(timeout=timeout) if req.explain
                   else query.run(timeout=timeout))
            batched = vector is not None and vector.ndim == 2
        explain = None
        hits = out
        if req.explain:
            hits, explain = out.hits, out.to_dict()
        if not batched:
            return rq.SearchResult(hits=[_hit_to_dict(h) for h in hits],
                                   explain=explain)
        return rq.SearchResult(
            hits=[[_hit_to_dict(h) for h in row] for row in hits],
            batched=True, explain=explain)

    def _count(self, req: rq.Count) -> rq.CountResult:
        col = self._col(req.collection)
        return rq.CountResult(
            count=col.count(rq.filter_from_dict(req.filter)))

    def _compact(self, req: rq.Compact) -> rq.CompactResult:
        col = self._col(req.collection)
        if req.shard is not None:
            if not isinstance(col, ShardedCollection):
                raise ValueError(     # -> INVALID_ARGUMENT
                    f"collection {req.collection!r} is not sharded; "
                    f"omit 'shard'")
            return rq.CompactResult(reclaimed=col.compact(shard=req.shard))
        return rq.CompactResult(reclaimed=col.compact())

    def _rebalance(self, req: rq.Rebalance) -> rq.RebalanceResult:
        col = self._col(req.collection)
        if not isinstance(col, ShardedCollection):
            raise ValueError(         # -> INVALID_ARGUMENT
                f"collection {req.collection!r} is not sharded; create it "
                f"with shards > 1 or replicas > 1 to rebalance")
        info = col.rebalance(shards=req.shards, replicas=req.replicas)
        return rq.RebalanceResult(shards=info["shards"],
                                  replicas=info["replicas"],
                                  rows=info["rows"],
                                  seconds=info["seconds"])

    def _shard_stats(self, req: rq.ShardStats) -> rq.ShardStatsResult:
        # uniform: a plain collection answers as one shard of one replica
        return rq.ShardStatsResult(
            shards=self._col(req.collection).shard_stats())

    def _stats(self, req: rq.Stats) -> rq.StatsResult:
        if req.collection is not None:
            return rq.StatsResult(stats=self._col(req.collection).stats())
        with self._lock:      # whole-db stats iterate the collections dict
            return rq.StatsResult(stats=self.db.stats())

    def _snapshot(self, req: rq.Snapshot) -> rq.SnapshotResult:
        with self._lock:
            gen = self.db.save(req.path, step=req.step)
        return rq.SnapshotResult(generation=gen)

    def _restore(self, req: rq.Restore) -> rq.RestoreResult:
        loaded = Database.load(req.path, generation=req.generation)
        with self._lock:
            old, self.db = self.db, loaded
        old.close()
        return rq.RestoreResult(collections=loaded.list_collections())

    def _health(self, req: rq.Health) -> rq.HealthResult:
        return rq.HealthResult()

    _HANDLERS: Dict[Type[rq.Request], Callable] = {
        rq.CreateCollection: _create_collection,
        rq.DropCollection: _drop_collection,
        rq.ListCollections: _list_collections,
        rq.DescribeCollection: _describe_collection,
        rq.Upsert: _upsert,
        rq.Delete: _delete,
        rq.Get: _get,
        rq.Search: _search,
        rq.Count: _count,
        rq.Compact: _compact,
        rq.Rebalance: _rebalance,
        rq.ShardStats: _shard_stats,
        rq.Stats: _stats,
        rq.Snapshot: _snapshot,
        rq.Restore: _restore,
        rq.Health: _health,
    }
