"""Quantixar serving layer: request batching, shard fan-out, and the
service-oriented request plane (`QuantixarService` + embedded HTTP server).

`service`/`http` are re-exported lazily: they import the `repro.api` package,
which itself imports `repro.serving.batcher`, so eager imports here would
cycle during `repro.api` initialization.
"""

from .batcher import QuorumFanout, RequestBatcher

__all__ = ["QuorumFanout", "RequestBatcher",
           "QuantixarService", "ServiceConfig", "QuantixarHTTPServer"]


def __getattr__(name):
    if name in ("QuantixarService", "ServiceConfig"):
        from . import service
        return getattr(service, name)
    if name == "QuantixarHTTPServer":
        from .http import QuantixarHTTPServer
        return QuantixarHTTPServer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
