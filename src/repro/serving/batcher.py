"""Request batching + straggler-tolerant fan-out for serving.

The paper's Query Processing module, production-shaped:
  * `RequestBatcher` — collects single queries into fixed-size padded batches
    (deadline-bounded, so tail latency is capped even at low QPS)
  * `QuorumFanout` — sends a search to every corpus shard and merges what
    returns within the deadline; slow shards degrade recall instead of
    blocking the query (degraded-read straggler mitigation, DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    query: np.ndarray
    k: int
    future: "Future"
    enqueued_at: float
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # requests are only co-batched when their extras (filter/ef/...) agree;
    # repr-compare since extras values (Filter trees) aren't hashable
    extras_key: str = ""

    def __post_init__(self):
        # drop None-valued extras so `submit(q, k)` and
        # `submit(q, k, flt=None)` land in the same batch
        self.extras = {k: v for k, v in self.extras.items() if v is not None}
        self.extras_key = repr(sorted(self.extras.items()))


class Future:
    def __init__(self):
        self._ev = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def set(self, value):
        if self._ev.is_set():       # first resolution wins (close() may race
            return                  # the worker on a straggling batch)
        self._value = value
        self._ev.set()

    def set_exception(self, exc: BaseException):
        if self._ev.is_set():
            return
        self._exc = exc
        self._ev.set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request timed out")
        if self._exc is not None:
            raise self._exc
        return self._value


class BatcherClosed(RuntimeError):
    """Submit after close(), or a request stranded by shutdown.  Typed so
    the service plane can map it to UNAVAILABLE without string matching."""

    def __init__(self, message: str = "batcher closed"):
        super().__init__(message)


class RequestBatcher:
    """Pads/batches requests; flushes on max_batch or max_wait_ms."""

    def __init__(self, search_fn: Callable[[np.ndarray, int], Tuple],
                 max_batch: int = 32, max_wait_ms: float = 5.0):
        self._search = search_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self._q: "queue.Queue[Optional[Request]]" = queue.Queue()
        self._carry: Optional[Request] = None   # guarded-by: _state_lock
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._running = True                    # guarded-by: _state_lock
        self._state_lock = threading.Lock()   # serializes submit/close/worker
        self.batches_served = 0      # guarded-by: _state_lock
        self.requests_served = 0     # guarded-by: _state_lock
        self.carried_requests = 0    # guarded-by: _state_lock
        self._thread.start()

    def submit(self, query: np.ndarray, k: int, **extras: Any) -> Future:
        """Enqueue one query.  `extras` (e.g. flt=..., params=AnnParams(...))
        are forwarded to search_fn; requests are only co-batched when their
        extras match (dataclass reprs make equal knob structs coalesce).

        Raises RuntimeError once `close()` has been called — the worker loop
        is gone, so enqueueing would leave the future to dangle until the
        caller's timeout."""
        with self._state_lock:
            if not self._running:
                raise BatcherClosed()
            fut = Future()
            self._q.put(Request(np.asarray(query, np.float32), k, fut,
                                time.perf_counter(), dict(extras)))
            return fut

    @staticmethod
    def zero_stats() -> Dict[str, int]:
        """Counter shape for collections whose batcher never started."""
        return {"batches_served": 0, "requests_served": 0,
                "carried_requests": 0, "queue_depth": 0}

    def stats(self) -> Dict[str, int]:
        """Serving observability counters (`/stats` endpoint feed)."""
        with self._state_lock:
            return {"batches_served": self.batches_served,
                    "requests_served": self.requests_served,
                    "carried_requests": self.carried_requests,
                    "queue_depth": self._q.qsize()}

    def close(self, timeout: float = 2.0):
        """Stop the worker.  Requests it never got to — queued behind the
        shutdown sentinel or carried between batches — have their futures
        failed with RuntimeError rather than silently dropped."""
        with self._state_lock:
            if not self._running:
                return                        # idempotent
            self._running = False
            self._q.put(None)
        self._thread.join(timeout=timeout)
        # If the worker is still alive (stuck in a slow search_fn), it owns
        # _carry and may be mid-pop on the queue; it sweeps both in its own
        # exit path.  Sweeping here too covers the already-dead case and is
        # idempotent (futures resolve first-wins).
        self._fail_pending(BatcherClosed())

    def _fail_pending(self, exc: BaseException) -> None:
        with self._state_lock:
            carry, self._carry = self._carry, None
        if carry is not None:
            carry.future.set_exception(exc)
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                return
            if req is not None:
                req.future.set_exception(exc)

    def _loop(self):
        try:
            self._serve_batches()
        finally:
            # a request popped between close()'s sweep and our exit would
            # otherwise dangle (neither batched nor failed)
            self._fail_pending(BatcherClosed())

    def _serve_batches(self):
        while True:
            with self._state_lock:
                if not self._running:
                    return
                first, self._carry = self._carry, None
            if first is None:
                first = self._q.get()
                if first is None:
                    return
            batch = [first]
            deadline = time.perf_counter() + self.max_wait
            while len(batch) < self.max_batch:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=left)
                except queue.Empty:
                    break
                if nxt is None:
                    with self._state_lock:
                        self._running = False
                    break
                if nxt.extras_key != first.extras_key:
                    with self._state_lock:  # incompatible: heads next batch
                        self._carry = nxt
                        self.carried_requests += 1
                    break
                batch.append(nxt)
            try:
                k = max(r.k for r in batch)
                queries = np.stack([r.query for r in batch])
                # pad the batch up to the next power of two (capped at
                # max_batch): the jitted search kernels specialize on the
                # query-count dimension, so free-running batch sizes would
                # trigger a fresh ~100ms XLA compile per novel size — per
                # corpus shape, so per shard.  Bucketing bounds that to
                # log2(max_batch) shapes at <= 2x padded compute.
                bucket = min(self.max_batch,
                             1 << (len(batch) - 1).bit_length())
                if bucket > len(batch):
                    fill = np.broadcast_to(
                        queries[:1], (bucket - len(batch),) +
                        queries.shape[1:])
                    queries = np.concatenate([queries, fill])
                d, ids = self._search(queries, k, **first.extras)
                d, ids = np.asarray(d)[: len(batch)], \
                    np.asarray(ids)[: len(batch)]
            except Exception as exc:          # surface, don't kill the loop
                for r in batch:
                    r.future.set_exception(exc)
                continue
            # count before resolving: a caller reading stats() right after
            # its result arrives must see this batch reflected
            with self._state_lock:
                self.batches_served += 1
                self.requests_served += len(batch)
            for i, r in enumerate(batch):
                r.future.set((d[i, : r.k], ids[i, : r.k]))


class QuorumFanout:
    """Fan a query out to per-shard searchers; merge whatever answers within
    the deadline (min_quorum shards required, else TimeoutError)."""

    def __init__(self, shard_search_fns: Sequence[Callable],
                 deadline_ms: float = 50.0, min_quorum: int = 1):
        self.fns = list(shard_search_fns)
        self.deadline = deadline_ms / 1e3
        self.min_quorum = min_quorum
        self.last_responders = 0

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        results: List[Optional[Tuple]] = [None] * len(self.fns)

        def run(i):
            try:
                results[i] = self.fns[i](queries, k)
            except Exception:
                results[i] = None

        threads = [threading.Thread(target=run, args=(i,), daemon=True)
                   for i in range(len(self.fns))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            left = self.deadline - (time.perf_counter() - t0)
            t.join(max(left, 0))
        got = [r for r in results if r is not None]
        self.last_responders = len(got)
        if len(got) < self.min_quorum:
            raise TimeoutError(
                f"only {len(got)}/{len(self.fns)} shards answered")
        all_d = np.concatenate([np.asarray(d) for d, _ in got], axis=1)
        all_i = np.concatenate([np.asarray(i) for _, i in got], axis=1)
        order = np.argsort(all_d, axis=1, kind="stable")[:, :k]
        return (np.take_along_axis(all_d, order, axis=1),
                np.take_along_axis(all_i, order, axis=1))
