"""Distributed Quantixar search — the paper's engine on the production mesh.

Corpus rows are sharded over the batch axes (`pod`, `data`); vector *dims*
(float scan) or PQ *sub-spaces* / BQ *words* are sharded over `model`, so
both mesh axes contribute:

    local partial distances  (MXU GEMM / ADC gather / popcount per shard)
      → psum over `model`    (partial-dim contributions)
      → local top-k          (k per row shard)
      → all_gather over row shards (k·shards candidates — tiny)
      → global top-k merge   (exact: top-k of a union ⊇ top-k of whole set)

Exactness of the merge is property-tested (tests/test_distributed.py).  This
is the shard_map program the multi-pod dry-run lowers for the quantixar-db
cells, and the serving path for real deployments.
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import axis_size as _axis_size
from ..compat import shard_map_compat as _shard_map
from ..launch.mesh import batch_axes, mesh_axis_sizes

Array = jax.Array


def _model_in_mesh(mesh: Mesh, feature_dim: int = 0) -> bool:
    """Use the model axis for the feature dim only when it divides evenly
    (e.g. BQ's 8 packed words cannot split 16 ways — replicated instead)."""
    if "model" not in mesh.axis_names:
        return False
    size = mesh_axis_sizes(mesh)["model"]
    return size > 1 and (feature_dim == 0 or feature_dim % size == 0)


def _merge_shard_topk(d: Array, k: int, rows) -> Tuple[Array, Array]:
    """Local (Q, N_local) distances -> exact global (Q, k) top-k.

    Works over any tuple of row axes (e.g. ('pod','data','model') in the
    rows-mode layout): the flattened shard index recovers global row ids.
    """
    n_local = d.shape[1]
    kk = min(k, n_local)
    neg, idx = jax.lax.top_k(-d, kk)
    shard = jax.lax.axis_index(rows[0])
    for ax in rows[1:]:
        shard = shard * _axis_size(ax) + jax.lax.axis_index(ax)
    gids = (idx + shard * n_local).astype(jnp.int32)
    cand_d = jax.lax.all_gather(-neg, rows, axis=1, tiled=True)
    cand_i = jax.lax.all_gather(gids, rows, axis=1, tiled=True)
    neg2, sel = jax.lax.top_k(-cand_d, k)
    return -neg2, jnp.take_along_axis(cand_i, sel, axis=1)


def _build(mesh: Mesh, local_distances: Callable, k: int,
           corpus_spec: P, query_spec: P, rows=None):
    rows = rows or batch_axes(mesh)

    def local(corpus, queries):
        d = local_distances(corpus, queries)
        return _merge_shard_topk(d, k, rows)

    # replication checking off: after the cross-shard all_gather + top_k the
    # outputs are value-identical on every shard (exactness property-tested),
    # but the static varying-axes checker cannot infer that through gather.
    fn = _shard_map(local, mesh,
                    (corpus_spec, query_spec),
                    (P(None, None), P(None, None)))
    return jax.jit(fn,
                   in_shardings=(NamedSharding(mesh, corpus_spec),
                                 NamedSharding(mesh, query_spec)),
                   out_shardings=NamedSharding(mesh, P(None, None)))


def make_flat_search(mesh: Mesh, *, k: int, metric: str = "cosine",
                     dim: int = 0, mode: str = "rows"):
    """Sharded exact scan.

    mode="rows" (optimized, §Perf iteration 1): rows over ALL mesh axes
    (pod × data × model), feature dim replicated — no psum at all; the only
    collective is the tiny k-candidate all_gather.
    mode="dims" (paper-faithful 2D baseline): rows over (pod,data), feature
    dim over model with a psum of the (Q, N_local) partial-distance buffer —
    measured 50x more collective bytes; kept for the §Perf A/B record.
    cosine/dot assume pre-normalized inputs. Returns (dists, global ids)."""
    rows = batch_axes(mesh)
    use_model = mode == "dims" and _model_in_mesh(mesh, dim)
    if mode == "rows" and "model" in mesh.axis_names:
        rows = rows + ("model",)
    dim_ax = "model" if use_model else None

    def local_distances(corpus, queries):
        q = queries.astype(jnp.float32)
        x = corpus.astype(jnp.float32)
        if metric == "l2":
            part = (jnp.sum(q * q, 1)[:, None] + jnp.sum(x * x, 1)[None, :]
                    - 2.0 * q @ x.T)
        else:  # cosine/dot on pre-normalized vectors
            part = -(q @ x.T)
        if use_model:
            part = jax.lax.psum(part, "model")
        return jnp.maximum(part, 0.0) if metric == "l2" else part

    return _build(mesh, local_distances, k,
                  P(rows, dim_ax), P(None, dim_ax), rows=rows)


def make_pq_search(mesh: Mesh, *, k: int, m_subspaces: int = 0,
                   mode: str = "rows"):
    """Sharded PQ-ADC scan. codes (N, m), lut (Q, m, k_cb).

    mode="rows": rows over all axes, LUT replicated (Q·m·k_cb·4 ≈ 16 MB) —
    no psum. mode="dims": rows over (pod,data) + sub-spaces over model with
    a (Q, N_local) psum (baseline for the §Perf A/B)."""
    rows = batch_axes(mesh)
    use_model = mode == "dims" and _model_in_mesh(mesh, m_subspaces)
    if mode == "rows" and "model" in mesh.axis_names:
        rows = rows + ("model",)
    sub_ax = "model" if use_model else None

    def local_distances(codes, lut):
        c = codes.astype(jnp.int32)

        def per_sub(lut_i, c_i):
            return lut_i[:, c_i]

        part = jnp.sum(jax.vmap(per_sub, in_axes=(1, 1))(lut, c), axis=0)
        if use_model:
            part = jax.lax.psum(part, "model")
        return part

    return _build(mesh, local_distances, k,
                  P(rows, sub_ax), P(None, sub_ax, None), rows=rows)


def make_hamming_search(mesh: Mesh, *, k: int, words: int = 0,
                        mode: str = "rows"):
    """Sharded BQ scan (packed uint32 XOR+popcount). Same mode semantics as
    make_flat_search."""
    rows = batch_axes(mesh)
    use_model = mode == "dims" and _model_in_mesh(mesh, words)
    if mode == "rows" and "model" in mesh.axis_names:
        rows = rows + ("model",)
    word_ax = "model" if use_model else None

    def local_distances(codes, q_codes):
        x = jnp.bitwise_xor(q_codes[:, None, :], codes[None, :, :])
        part = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)
        if use_model:
            part = jax.lax.psum(part, "model")
        return part.astype(jnp.float32)

    return _build(mesh, local_distances, k,
                  P(rows, word_ax), P(None, word_ax), rows=rows)
