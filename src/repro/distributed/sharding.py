"""Param/activation sharding policy: TP over `model`, FSDP over `data`,
DP over `pod` (DESIGN.md §6).

Rule-based and divisibility-guarded: a dim is sharded only if the mesh axis
divides it — otherwise it stays replicated and is recorded in the decision
log (surface small-head GQA cases instead of letting GSPMD pad silently).
Optimizer state inherits each param's spec; the policy is pure shape/path
logic so it works on abstract (ShapeDtypeStruct) trees — the dry-run path.
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.mesh import batch_axes, mesh_axis_sizes

PyTree = Any

# params whose *first* dim is the contraction output of an up-projection —
# shard it on `model` to match, avoiding an inter-matmul reshard.
_ROW_PARALLEL_SUFFIXES = ("wd", "w_out", "w_down", "wo")
# embedding tables: vocab × d_model — vocab over `model` (masked-gather +
# all-reduce pattern), d over `data` (FSDP).
_EMBED_NAMES = ("embed",)
# block-diagonal per-head projections (see __init__ head_proj_model_only)
_HEAD_PROJ_NAMES = ("w_q", "w_k", "w_v", "r", "gate_a", "gate_i")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(getattr(p, "idx", p)))
    return "/".join(parts)


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


class ShardingPolicy:
    """Assigns PartitionSpecs to a train/serve state tree for a mesh."""

    def __init__(self, mesh: Mesh, *, shard_cache_seq: bool = False,
                 head_proj_model_only: bool = False, dp_only: bool = False):
        self.mesh = mesh
        sizes = mesh_axis_sizes(mesh)
        # dp_only: fold the model axis into data parallelism — the right
        # layout for small-state-coupled archs (xlstm's 4-head blocked mLSTM
        # resists 16-way TP; params fit replicated) — §Perf iteration 3
        self.dp_only = dp_only
        # model_size=0 => _divides() is always False => the model axis is
        # never assigned to any param dim in dp_only mode
        self.model_size = 0 if dp_only else sizes.get("model", 1)
        self.data_size = sizes.get("data", 1)
        self.batch_axes = batch_axes(mesh) + ("model",) if dp_only \
            else batch_axes(mesh)
        # flash-decode layout (§Perf): KV-cache seq dim over `model` —
        # attention over the sharded cache becomes partial-softmax + psum
        # (GSPMD inserts the small stat reductions), and a 32k cache that
        # exceeds per-chip HBM under batch-only sharding fits again.
        self.shard_cache_seq = shard_cache_seq
        # block-diagonal per-head projections (mlstm w_q/k/v, slstm r,
        # rglru gates) are small; FSDP-sharding their contraction dim forces
        # GSPMD "involuntary full rematerialization" activation gathers
        # (observed on xlstm train — §Perf) — column-parallel-only instead
        self.head_proj_model_only = head_proj_model_only
        self.decisions: List[Tuple[str, Tuple[int, ...], P]] = []

    # ------------------------------------------------------------- params
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        name = path.rsplit("/", 1)[-1]
        nd = len(shape)
        spec: List[Optional[Any]] = [None] * nd

        if self.head_proj_model_only and name in _HEAD_PROJ_NAMES:
            if _divides(shape[nd - 1], self.model_size):
                spec[nd - 1] = "model"
            return P(*spec)

        if nd >= 2:
            if name in _EMBED_NAMES:
                if _divides(shape[0], self.model_size):
                    spec[0] = "model"
                if _divides(shape[1], self.data_size):
                    spec[1] = "data"
            elif name.rstrip("0123456789_") in _ROW_PARALLEL_SUFFIXES \
                    or name in _ROW_PARALLEL_SUFFIXES:
                # row-parallel: contraction dim over model, output over data
                cdim = nd - 2
                if _divides(shape[cdim], self.model_size):
                    spec[cdim] = "model"
                if _divides(shape[nd - 1], self.data_size):
                    spec[nd - 1] = "data"
            else:
                # column-parallel default: last dim over model,
                # biggest other dim over data (FSDP)
                if _divides(shape[nd - 1], self.model_size):
                    spec[nd - 1] = "model"
                rest = [(shape[i], i) for i in range(nd - 1)]
                rest.sort(reverse=True)
                for sz, i in rest:
                    if _divides(sz, self.data_size) and sz >= 64:
                        spec[i] = "data"
                        break
        # stacked-unit leading dim (scan over layers) stays unsharded: it is
        # sliced per scan step.
        return P(*spec)

    def spec_tree(self, abstract_tree: PyTree) -> PyTree:
        def rule(path, leaf):
            spec = self.param_spec(_path_str(path), leaf.shape)
            self.decisions.append((_path_str(path), tuple(leaf.shape), spec))
            return spec

        return jax.tree_util.tree_map_with_path(rule, abstract_tree)

    def sharding_tree(self, abstract_tree: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s),
            self.spec_tree(abstract_tree),
            is_leaf=lambda x: isinstance(x, P))

    # -------------------------------------------------------------- batch
    @property
    def n_batch_shards(self) -> int:
        sizes = mesh_axis_sizes(self.mesh)
        n = 1
        for ax in self.batch_axes:
            n *= sizes.get(ax, 1)
        return n

    def batch_spec(self, shape: Tuple[int, ...]) -> P:
        """Shard dim 0 (global batch) over (pod, data) iff divisible
        (long_500k has global_batch=1 — replicated)."""
        ndim = len(shape)
        if ndim == 0 or not _divides(shape[0], self.n_batch_shards):
            return P(*([None] * ndim))
        return P(self.batch_axes, *([None] * (ndim - 1)))

    def batch_spec_tree(self, abstract_batch: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda l: self.batch_spec(l.shape), abstract_batch)

    def batch_sharding_tree(self, abstract_batch: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda l: NamedSharding(self.mesh, self.batch_spec(l.shape)),
            abstract_batch)

    # -------------------------------------------------- decode/serve state
    def serve_state_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Decode state: batch dim over (pod, data); stacked-unit leaves have
        the batch at dim 1 (dim 0 is the scanned unit axis)."""
        nd = len(shape)
        if nd == 0:
            return P()
        # stacked-unit leaves (block_states/<i>/..., cross_kv) carry the
        # scanned unit axis at dim 0 and batch at dim 1; tail-block states
        # and pos have batch at dim 0
        stacked = ("block_states" in path or "cross_kv" in path) \
            and "tail" not in path and nd >= 2
        batch_dim = 1 if stacked else 0
        spec: List[Optional[Any]] = [None] * nd
        if _divides(shape[batch_dim], self.n_batch_shards):
            spec[batch_dim] = self.batch_axes
        # KV caches (units, B, S, nkv, dh): optionally shard S over `model`
        leaf = path.rsplit("/", 1)[-1]
        if (self.shard_cache_seq and leaf in ("k", "v") and nd == 5
                and _divides(shape[2], self.model_size)):
            spec[2] = "model"
        return P(*spec)

    def serve_sharding_tree(self, abstract_state: PyTree) -> PyTree:
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(
                self.mesh, self.serve_state_spec(_path_str(p), l.shape)),
            abstract_state)

    # ------------------------------------------------------------- report
    def replicated_report(self) -> List[str]:
        """Large params left fully replicated (divisibility misses)."""
        out = []
        for path, shape, spec in self.decisions:
            n = 1
            for s in shape:
                n *= s
            if n >= 1 << 20 and all(a is None for a in spec):
                out.append(f"{path} {shape} replicated")
        return out


def make_train_shardings(policy: ShardingPolicy, abstract_state,
                         abstract_batch):
    """(state_shardings, batch_shardings) NamedSharding trees."""
    return (policy.sharding_tree(abstract_state),
            policy.batch_sharding_tree(abstract_batch))
