"""Distribution: sharding policy + shard_map search."""

from .sharding import ShardingPolicy, make_train_shardings
from .search import make_flat_search, make_hamming_search, make_pq_search
