"""stablelm-3b [dense] — 32L d_model=2560 32H (kv=32, i.e. MHA) d_ff=6912
vocab=50304; partial RoPE (25% of head dim), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b family; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_head=80, d_ff=6912, vocab_size=50304,
    block_pattern=("attn",), mlp_type="swiglu", norm_type="layernorm",
    rope_pct=0.25)

SMOKE = CONFIG.with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab_size=256)
