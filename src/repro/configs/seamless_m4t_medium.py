"""seamless-m4t-medium [audio] — enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206; the speech frontend
(conformer feature extractor) is a STUB per the assignment: input_specs
provides precomputed frame embeddings (B, S_enc, d_model) to the encoder;
the text decoder decodes with self- + cross-attention. LayerNorm, gelu.
[arXiv:2308.11596; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, d_head=64, d_ff=4096, vocab_size=256206,
    block_pattern=("attn",), mlp_type="gelu", norm_type="layernorm",
    encoder_layers=12, frontend="audio_frames")

SMOKE = CONFIG.with_overrides(
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab_size=256)
