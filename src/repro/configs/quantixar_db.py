"""quantixar-db — the paper's own workload as a dry-runnable config:
a sharded vector corpus searched with flat / PQ-ADC / BQ-hamming scans +
cross-shard top-k merge.  Corpus rows are sharded over (pod, data); the
search step is the shard_map program in repro.distributed.search."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class DBConfig:
    name: str = "quantixar-db"
    n_vectors: int = 100_000_000     # 100M corpus (production cell)
    dim: int = 128                   # SIFT-like
    query_batch: int = 1024
    k: int = 100
    metric: str = "cosine"
    pq_m: int = 16
    pq_k: int = 256
    bq_bits: int = 256


CONFIG = DBConfig()
SMOKE = DBConfig(n_vectors=4096, query_batch=16, k=10)
