"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion: image patches arrive as VQ-VAE token ids inside
the same 65536 vocabulary (the VQ tokenizer IS the modality frontend and is
stubbed per the assignment — input_specs provides token ids directly; the
VQ codebook-lookup machinery is the same construction as core/pq.py decode).
qk_norm as in the paper. [arXiv:2405.09818; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_head=128, d_ff=22016, vocab_size=65536,
    block_pattern=("attn",), mlp_type="swiglu", qk_norm=True,
    frontend="vq_tokens")

SMOKE = CONFIG.with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab_size=256)
