"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; Griffin pattern: 2 RG-LRU blocks : 1 local-attention block
(window 2048), GeGLU MLP.  Bounded recurrent state + window cache =>
runs the long_500k cell. [arXiv:2402.19427; unverified]

38 layers = 12 scanned (rglru, rglru, local_attn) units + a (rglru,
rglru) tail — exact layer count via ModelConfig.tail_pattern."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_head=256, d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"), mlp_type="geglu",
    local_window=2048, rnn_width=4096, supports_long_context=True)

SMOKE = CONFIG.with_overrides(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_head=16, d_ff=128,
    vocab_size=256, rnn_width=64, local_window=32)
