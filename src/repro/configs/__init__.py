"""Architecture config registry: ``get_config("qwen2-1.5b")`` etc.

One module per assigned architecture (+ the paper's own quantixar_db).  Each
module exposes CONFIG (full published size) and SMOKE (reduced same-family
config for CPU tests) plus input_specs helpers via repro.launch.specs.
"""

from __future__ import annotations

import importlib
from typing import List

_ARCHS = {
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-15b": "starcoder2_15b",
    "stablelm-3b": "stablelm_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mixtral-8x7b": "mixtral_8x7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "xlstm-1.3b": "xlstm_1_3b",
    "chameleon-34b": "chameleon_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def arch_ids() -> List[str]:
    return list(_ARCHS)


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE
