"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; xLSTM[7:1]
(the published 1.3B config): 7 mLSTM blocks per 1 sLSTM block, 6 units of 8.
Blocks are self-contained (internal up/down projections; d_ff=0 per spec).
Pure recurrent state => runs the long_500k cell.
[arXiv:2405.04517; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    supports_long_context=True)

SMOKE = CONFIG.with_overrides(
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=256,
    block_pattern=("mlstm", "slstm"))
