"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
(per expert) vocab=49155; MoE 40 experts top-8 (structured spec; the prose
comment says 32 — we follow the structured spec, noted in DESIGN.md).
Tied embeddings. [hf:ibm-granite/granite-3.0-*; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_head=64, d_ff=512, vocab_size=49155,
    block_pattern=("attn_moe",), mlp_type="swiglu",
    moe_experts=40, moe_top_k=8, tie_embeddings=True)

SMOKE = CONFIG.with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=32,
    vocab_size=256, moe_experts=8, moe_top_k=2, moe_group_size=64)
