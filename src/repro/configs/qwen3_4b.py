"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936; qk_norm, GQA, tied embeddings, head_dim 128 (q/k/v project to n_heads*128
independent of d_model). [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=9728, vocab_size=151936,
    block_pattern=("attn",), mlp_type="swiglu", qk_norm=True,
    rope_theta=1_000_000.0, tie_embeddings=True)

SMOKE = CONFIG.with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab_size=256)
