"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2, sliding-window attention (4096).
SWA ring cache is bounded => runs the long_500k cell.
[arXiv:2401.04088; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab_size=32000,
    block_pattern=("swa_moe",), mlp_type="swiglu", window=4096,
    moe_experts=8, moe_top_k=2, supports_long_context=True)

SMOKE = CONFIG.with_overrides(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab_size=256, window=32, moe_experts=4, moe_top_k=2,
    moe_group_size=64)
