"""Binary Quantization (paper §II-B-2).

Faithful to the paper's formulation:

  1) Learn m hyperplanes with normals u_1 … u_m ∈ R^d.
  2) Encode b_i = 1 if u_iᵀx ≥ 0 else 0.
  3) The m-bit code b is the compact representation; search uses Hamming
     distance (locality-sensitive for cosine/angular similarity).

Hyperplane learning: the default is data-centred random Gaussian hyperplanes
(the classic SimHash/LSH construction the paper's formulation describes); an
optional PCA rotation decorrelates dimensions first (beyond-paper toggle, off
by default to stay faithful).

TPU adaptation: codes are packed 32 bits/word into uint32; Hamming distance is
XOR + ``lax.population_count`` on the VPU (kernels/hamming.py tiles it through
VMEM).  x86 POPCNT/AVX2 of the paper maps 1:1 onto this.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

WORD_BITS = 32


@dataclass(frozen=True)
class BQConfig:
    bits: int = 256            # m hyperplanes; multiple of 32 for packing
    center: bool = True        # subtract data mean before projecting
    pca_rotate: bool = False   # beyond-paper: PCA-decorrelate first

    def validate(self) -> None:
        if self.bits % WORD_BITS != 0:
            raise ValueError(f"bits={self.bits} must be a multiple of {WORD_BITS}")

    @property
    def words(self) -> int:
        return self.bits // WORD_BITS


def sample_hyperplanes(key: Array, d: int, bits: int) -> Array:
    """Blockwise-orthogonal Gaussian hyperplane normals (bits, d).

    Super-bit LSH: each block of ≤ d normals is the Q factor of a Gaussian
    matrix.  Orthogonal directions within a block decorrelate the sign bits,
    which improves Hamming↔cosine recall over i.i.d. Gaussian normals
    whenever bits approaches or exceeds d (Ji et al., NeurIPS 2012).
    """
    blocks = []
    left = bits
    while left > 0:
        m = min(left, d)
        key, sub = jax.random.split(key)
        # reduced QR of a (d, m) Gaussian: m orthonormal directions at
        # O(d*m^2) instead of factoring a full d x d matrix
        g = jax.random.normal(sub, (d, m), dtype=jnp.float32)
        q, _ = jnp.linalg.qr(g)
        blocks.append(q.T)
        left -= m
    return jnp.concatenate(blocks, axis=0)


@jax.jit
def project_bits(vectors: Array, hyperplanes: Array, mean: Array) -> Array:
    """Sign bits (n, bits) uint32 ∈ {0,1}: b_i = [u_iᵀ(x - mean) >= 0]."""
    x = vectors.astype(jnp.float32) - mean[None, :]
    proj = x @ hyperplanes.T  # (n, bits) — MXU GEMM
    return (proj >= 0.0).astype(jnp.uint32)


@jax.jit
def pack_bits(bits: Array) -> Array:
    """Pack (n, m) {0,1} -> (n, m/32) uint32, bit i at position i%32 (LSB-first)."""
    n, m = bits.shape
    w = m // WORD_BITS
    b = bits.reshape(n, w, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(b << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits",))
def unpack_bits(packed: Array, bits: int) -> Array:
    n, w = packed.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    b = (packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return b.reshape(n, w * WORD_BITS)[:, :bits]


@jax.jit
def hamming_distances(q_codes: Array, x_codes: Array) -> Array:
    """(Q, W) × (N, W) packed -> (Q, N) int32 Hamming distances (oracle path)."""
    x = jnp.bitwise_xor(q_codes[:, None, :], x_codes[None, :, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def hamming_topk(q_codes: Array, x_codes: Array, k: int) -> Tuple[Array, Array]:
    d = hamming_distances(q_codes, x_codes)
    neg_d, idx = jax.lax.top_k(-d, k)
    return -neg_d, idx.astype(jnp.int32)


def _pca_rotation(x: np.ndarray, bits: int) -> np.ndarray:
    """Top-`bits` principal directions as hyperplane normals (host-side)."""
    xc = x - x.mean(0, keepdims=True)
    cov = xc.T @ xc / max(len(x) - 1, 1)
    w, v = np.linalg.eigh(cov)
    order = np.argsort(w)[::-1]
    v = v[:, order]  # (d, d) descending variance
    d = x.shape[1]
    reps = -(-bits // d)
    normals = np.tile(v.T, (reps, 1))[:bits]
    return normals.astype(np.float32)


class BinaryQuantizer:
    """Stateful wrapper: learn hyperplanes, encode, Hamming search."""

    def __init__(self, config: BQConfig):
        config.validate()
        self.config = config
        self.hyperplanes: Optional[Array] = None
        self.mean: Optional[Array] = None

    @property
    def is_trained(self) -> bool:
        return self.hyperplanes is not None

    def train(self, vectors: Array, seed: int = 0) -> None:
        d = vectors.shape[1]
        self.mean = (jnp.mean(vectors.astype(jnp.float32), axis=0)
                     if self.config.center else jnp.zeros((d,), jnp.float32))
        if self.config.pca_rotate:
            self.hyperplanes = jnp.asarray(
                _pca_rotation(np.asarray(vectors, dtype=np.float32), self.config.bits))
        else:
            self.hyperplanes = sample_hyperplanes(
                jax.random.PRNGKey(seed), d, self.config.bits)

    def encode(self, vectors: Array) -> Array:
        assert self.is_trained, "train() before encode()"
        return pack_bits(project_bits(vectors, self.hyperplanes, self.mean))

    def search(self, codes: Array, queries: Array, k: int) -> Tuple[Array, Array]:
        q = self.encode(queries)
        return hamming_topk(q, codes, k)

    def compression_ratio(self, d: int, dtype_bytes: int = 4) -> float:
        return (d * dtype_bytes) / (self.config.words * 4)

    def state_dict(self):
        return {"hyperplanes": np.asarray(self.hyperplanes),
                "mean": np.asarray(self.mean)}

    def load_state_dict(self, state):
        self.hyperplanes = jnp.asarray(state["hyperplanes"])
        self.mean = jnp.asarray(state["mean"])
