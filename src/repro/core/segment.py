"""Segmented storage: mutable delta segment + sealed-segment merge policy.

Production VDBMSs decouple ingest from index maintenance with segmented
storage (Pan et al. 2023: "Survey of Vector Database Management Systems";
Qdrant/Milvus ship the same shape): writes land in a small **delta segment**
served by an exact flat scan, while the **sealed segment** keeps its trained
quantizers and HNSW/IVF structure.  Queries fan out over both and merge
top-k; an explicit `seal()` folds the delta into a new sealed segment on an
amortized schedule instead of billing an O(N) rebuild to one unlucky query.

This module owns the delta-side bookkeeping:

  * `DeltaSegment` — the append-only mutable tail: raw vector chunks plus
    (when quantizer codebooks exist) their encode-only codes.  Rows keep
    *global* ids — `start + local offset` — so masks, metadata and rescore
    indexing stay corpus-wide.
  * `SealPolicy` — when to fold: absolute delta size or delta/sealed ratio.
  * `merge_candidates` — top-k merge of per-segment candidate lists that are
    already in one distance space (the engine guarantees the delta scan uses
    the sealed pass's traversal space; id ranges are disjoint by
    construction, so no dedup is needed).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SealPolicy:
    """When the mutable delta should be folded into a sealed segment.

    Either trigger suffices: an absolute row count (bounds the exact-scan
    cost at large corpora) or a delta/sealed ratio (bounds relative scan
    overhead at small ones).  `auto=False` restricts sealing to explicit
    `seal()` / `Collection.compact()` calls.
    """

    max_delta_rows: int = 10000
    max_delta_ratio: float = 0.5
    auto: bool = True

    def should_seal(self, sealed_rows: int, delta_rows: int) -> bool:
        if delta_rows <= 0:
            return False
        if delta_rows >= self.max_delta_rows:
            return True
        return sealed_rows > 0 and delta_rows >= self.max_delta_ratio * sealed_rows


class ChunkedArray:
    """Append-only row store: chunks in, one array out, concatenated lazily.

    Every write-path buffer in the engine has this access pattern (raw
    vectors, code matrices, the delta's copies of both): O(batch) appends,
    occasional whole-array reads.  `view()` collapses the chunk list once
    and caches the result until the next append.
    """

    def __init__(self, chunks: Optional[List[np.ndarray]] = None):
        self._chunks: List[np.ndarray] = \
            [np.asarray(c) for c in (chunks or [])]

    def __bool__(self) -> bool:
        return bool(self._chunks)

    def append(self, arr: np.ndarray) -> None:
        self._chunks.append(np.asarray(arr))

    def view(self) -> Optional[np.ndarray]:
        """The concatenated array, or None when nothing was appended."""
        if not self._chunks:
            return None
        if len(self._chunks) > 1:
            self._chunks = [np.concatenate(self._chunks, axis=0)]
        return self._chunks[0]


class DeltaSegment:
    """Mutable write segment: post-build inserts, exact-scanned at query time.

    Stores references to the raw chunks the engine already holds (no copy)
    plus the encode-only codes for quantized engines.
    """

    def __init__(self, start: int, dim: int):
        self.start = int(start)          # first global row id in the delta
        self.dim = int(dim)
        self._raw = ChunkedArray()
        self._codes = ChunkedArray()
        self._n = 0
        self.version = 0                 # bumped per append: cache fencing

    def __len__(self) -> int:
        return self._n

    @property
    def stop(self) -> int:
        """One past the last global row id (== engine row count)."""
        return self.start + self._n

    def append(self, vectors: np.ndarray,
               codes: Optional[np.ndarray] = None) -> None:
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim != 2 or vectors.shape[1] != self.dim:
            raise ValueError(
                f"expected (n, {self.dim}) vectors, got {vectors.shape}")
        if codes is not None and len(codes) != len(vectors):
            raise ValueError("codes/vectors length mismatch")
        if self._codes and codes is None:
            raise ValueError("segment has codes; batch arrived without")
        if len(vectors) == 0:
            return
        self._raw.append(vectors)
        if codes is not None:
            self._codes.append(codes)
        self._n += len(vectors)
        self.version += 1

    @property
    def raw(self) -> np.ndarray:
        v = self._raw.view()
        return v if v is not None \
            else np.zeros((0, self.dim), dtype=np.float32)

    @property
    def codes(self) -> Optional[np.ndarray]:
        return self._codes.view()


def merge_candidates(d_a: np.ndarray, i_a: np.ndarray,
                     d_b: np.ndarray, i_b: np.ndarray,
                     k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Merge two (Q, ka)/(Q, kb) candidate lists into the best-k (ascending).

    Both lists must be in the same distance space and carry disjoint global
    id ranges (sealed rows < delta rows).  +inf slots sink to the tail and
    surface as id -1, matching the engine's padding contract.
    """
    d = np.concatenate([np.asarray(d_a, dtype=np.float32),
                        np.asarray(d_b, dtype=np.float32)], axis=1)
    i = np.concatenate([np.asarray(i_a), np.asarray(i_b)], axis=1)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    d = np.take_along_axis(d, order, axis=1)
    i = np.take_along_axis(i, order, axis=1)
    return d, np.where(np.isfinite(d), i, -1).astype(np.int32)
