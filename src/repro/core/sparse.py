"""Sparse full-text retrieval: tokenizer, inverted index, BM25 scoring.

Both VDBMS surveys the roadmap cites (Pan et al. 2023, Taipalus 2024) call
combined text+vector querying a defining VDBMS capability; this module is
the sparse half of that hybrid.  It mirrors the dense engine's segmented
shape (`core/segment.py`):

  * `TokenizerConfig` — deterministic, schema-serialized tokenization
    (lowercase + min-length + stopword rules).  The same config tokenizes
    documents at upsert time and queries at search time, so scores are a
    pure function of (corpus, query, config).
  * `SparseIndex` — an incremental inverted index: token -> postings
    (global row id, term frequency) split into a **sealed** packed store
    (CSR-style: one rows array + one tfs array + per-token offsets) and a
    mutable **delta** dict that absorbs post-build upserts without any
    rebuild.  `seal()` folds the delta into new packed arrays; deletes are
    handled by the caller's row mask exactly like the dense engine's
    tombstones, so the index itself never mutates postings in place.
  * BM25 scoring — a vectorized numpy path (`scores()`) that the index's
    `search()` uses, a standalone brute-force reference
    (`bm25_reference`) computing the same formula from raw texts with the
    same accumulation order (so index top-k == reference top-k *exactly*,
    float-for-float), and a batched JAX path (`scores_jax`) over the same
    packed postings for large candidate sets.

Score contract: BM25 is higher-is-better; `search()` returns **negated**
scores so the engine-wide "lower is closer" ordering holds for sparse
candidates too (RRF ranks are unaffected; linear fusion min-max
normalizes either way).  Ties break deterministically on ascending row id.

Corpus statistics (N, df, avgdl) are computed over every *indexed* doc
regardless of the row mask — matching production engines, where deletes
filter candidates but do not retrain the scorer — and the reference uses
the same convention, so masked searches still match it exactly.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# small English closed-class words; enough to keep toy corpora honest
# without dragging in a stemming dependency
DEFAULT_STOPWORDS: Tuple[str, ...] = (
    "a", "an", "and", "are", "as", "at", "be", "but", "by", "for", "if",
    "in", "into", "is", "it", "no", "not", "of", "on", "or", "such",
    "that", "the", "their", "then", "there", "these", "they", "this",
    "to", "was", "will", "with")

_TOKEN_RE = re.compile(r"\w+", re.UNICODE)

BM25_K1 = 1.2
BM25_B = 0.75


@dataclasses.dataclass(frozen=True)
class TokenizerConfig:
    """Deterministic tokenization rules, serialized inside `TextField`.

    `stopwords=None` means the default English list; an explicit empty
    tuple disables stopword removal entirely.
    """

    lowercase: bool = True
    min_token_len: int = 2
    stopwords: Optional[Tuple[str, ...]] = None

    def stopword_set(self) -> frozenset:
        words = DEFAULT_STOPWORDS if self.stopwords is None else self.stopwords
        return frozenset(words)

    def tokenize(self, text: Optional[str]) -> List[str]:
        if not text:
            return []
        if self.lowercase:
            text = text.lower()
        stop = self.stopword_set()
        return [t for t in _TOKEN_RE.findall(text)
                if len(t) >= self.min_token_len and t not in stop]

    def query_tokens(self, text: str) -> List[str]:
        """Tokenize a query and dedupe preserving first occurrence — the
        iteration order every scoring path (index, reference, JAX) shares,
        which is what makes their floating-point sums bit-identical."""
        seen: Dict[str, None] = {}
        for tok in self.tokenize(text):
            seen.setdefault(tok)
        return list(seen)


def _idf(n_docs: int, df: np.ndarray) -> np.ndarray:
    """Lucene-style smoothed idf: ln(1 + (N - df + .5)/(df + .5)), always
    positive so a very common term can demote but never negate a match."""
    df = np.asarray(df, dtype=np.float64)
    return np.log1p((n_docs - df + 0.5) / (df + 0.5))


@dataclasses.dataclass(frozen=True)
class CorpusStats:
    """Externally-supplied BM25 corpus statistics (N, avgdl, per-token df).

    A sharded corpus scores each shard with *global* statistics — local
    df/avgdl would make scores incomparable across shards — so the caller
    gathers every shard's `SparseIndex.term_stats()`, sums them, and passes
    the aggregate back into each shard's `search(stats=...)`.  Summing the
    integer counters before the float divisions reproduces the exact
    float64 values a single unsharded index computes, so the distributed
    merge stays hit-for-hit identical to the single-engine ranking.
    """

    docs_with_text: int
    avgdl: float
    df: Dict[str, int]

    @classmethod
    def aggregate(cls, parts: Sequence[Tuple[int, int, Dict[str, int]]]
                  ) -> "CorpusStats":
        """Sum per-shard `term_stats()` tuples into global statistics."""
        docs = sum(p[0] for p in parts)
        total = sum(p[1] for p in parts)
        df: Dict[str, int] = {}
        for _, _, part_df in parts:
            for tok, n in part_df.items():
                df[tok] = df.get(tok, 0) + n
        return cls(docs_with_text=docs,
                   avgdl=(total / docs if docs else 1.0), df=df)


class SparseIndex:
    """Incremental inverted index with BM25 scoring (sealed + delta).

    Documents are appended in global row order — `add()` MUST be called
    with one entry per corpus row (None/empty for rows without text) so
    sparse row ids stay aligned with the dense engine's.
    """

    # delta postings beyond this fold into the sealed store automatically
    AUTO_SEAL_POSTINGS = 65536

    def __init__(self, config: Optional[TokenizerConfig] = None,
                 k1: float = BM25_K1, b: float = BM25_B):
        self.config = config or TokenizerConfig()
        self.k1 = float(k1)
        self.b = float(b)
        # sealed packed store: vocab token -> slot; postings CSR arrays
        self._vocab: Dict[str, int] = {}
        self._offsets = np.zeros(1, dtype=np.int64)      # (V + 1,)
        self._rows = np.zeros(0, dtype=np.int64)
        self._tfs = np.zeros(0, dtype=np.int64)
        # mutable delta: token -> parallel [rows], [tfs] lists
        self._delta: Dict[str, Tuple[List[int], List[int]]] = {}
        self._delta_postings = 0
        self._doc_lens: List[int] = []     # one per corpus row (0 = no text)
        self._total_tokens = 0
        self._docs_with_text = 0
        self._sealed_docs = 0              # rows covered when last sealed
        self.seals = 0

    # ------------------------------------------------------------ properties
    def __len__(self) -> int:
        return len(self._doc_lens)

    @property
    def docs_indexed(self) -> int:
        """Rows that contributed at least one token."""
        return self._docs_with_text

    @property
    def vocab_size(self) -> int:
        tokens = set(self._vocab)
        tokens.update(self._delta)
        return len(tokens)

    @property
    def sealed_postings(self) -> int:
        return int(self._rows.shape[0])

    @property
    def delta_postings(self) -> int:
        return self._delta_postings

    @property
    def postings(self) -> int:
        return self.sealed_postings + self.delta_postings

    # ---------------------------------------------------------------- writes
    def add(self, texts: Sequence[Optional[str]]) -> None:
        """Append one document per entry (None = row without text)."""
        for text in texts:
            row = len(self._doc_lens)
            tokens = self.config.tokenize(text)
            self._doc_lens.append(len(tokens))
            if tokens:
                self._docs_with_text += 1
                self._total_tokens += len(tokens)
                for tok, tf in Counter(tokens).items():
                    rows, tfs = self._delta.setdefault(tok, ([], []))
                    rows.append(row)
                    tfs.append(tf)
                    self._delta_postings += 1
        if self._delta_postings >= self.AUTO_SEAL_POSTINGS:
            self.seal()

    def seal(self) -> bool:
        """Fold the delta postings into a fresh packed sealed store.
        Returns True if anything was folded."""
        if not self._delta:
            self._sealed_docs = len(self._doc_lens)
            return False
        tokens = sorted(set(self._vocab) | set(self._delta))
        offsets = np.zeros(len(tokens) + 1, dtype=np.int64)
        chunks_r: List[np.ndarray] = []
        chunks_t: List[np.ndarray] = []
        for slot, tok in enumerate(tokens):
            parts_r, parts_t = [], []
            old = self._vocab.get(tok)
            if old is not None:
                lo, hi = self._offsets[old], self._offsets[old + 1]
                parts_r.append(self._rows[lo:hi])
                parts_t.append(self._tfs[lo:hi])
            if tok in self._delta:
                d_rows, d_tfs = self._delta[tok]
                parts_r.append(np.asarray(d_rows, dtype=np.int64))
                parts_t.append(np.asarray(d_tfs, dtype=np.int64))
            # sealed rows predate delta rows, so concat stays ascending
            rows = np.concatenate(parts_r) if len(parts_r) > 1 else parts_r[0]
            tfs = np.concatenate(parts_t) if len(parts_t) > 1 else parts_t[0]
            chunks_r.append(rows)
            chunks_t.append(tfs)
            offsets[slot + 1] = offsets[slot] + rows.shape[0]
        self._vocab = {tok: slot for slot, tok in enumerate(tokens)}
        self._offsets = offsets
        self._rows = (np.concatenate(chunks_r) if chunks_r
                      else np.zeros(0, dtype=np.int64))
        self._tfs = (np.concatenate(chunks_t) if chunks_t
                     else np.zeros(0, dtype=np.int64))
        self._delta = {}
        self._delta_postings = 0
        self._sealed_docs = len(self._doc_lens)
        self.seals += 1
        return True

    # -------------------------------------------------------------- postings
    def _postings(self, token: str) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, tfs) for one token across sealed + delta (row-ascending)."""
        parts_r, parts_t = [], []
        slot = self._vocab.get(token)
        if slot is not None:
            lo, hi = self._offsets[slot], self._offsets[slot + 1]
            if hi > lo:
                parts_r.append(self._rows[lo:hi])
                parts_t.append(self._tfs[lo:hi])
        if token in self._delta:
            d_rows, d_tfs = self._delta[token]
            parts_r.append(np.asarray(d_rows, dtype=np.int64))
            parts_t.append(np.asarray(d_tfs, dtype=np.int64))
        if not parts_r:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        if len(parts_r) == 1:
            return parts_r[0], parts_t[0]
        return np.concatenate(parts_r), np.concatenate(parts_t)

    def _norm(self, avgdl: Optional[float] = None
              ) -> Tuple[np.ndarray, float]:
        """(per-doc length-normalization denominator term, avgdl); pass
        ``avgdl`` to normalize against global (cross-shard) statistics."""
        lens = np.asarray(self._doc_lens, dtype=np.float64)
        if avgdl is None:
            avgdl = (self._total_tokens / self._docs_with_text
                     if self._docs_with_text else 1.0)
        return self.k1 * (1.0 - self.b + self.b * lens / avgdl), avgdl

    def term_stats(self, tokens: Sequence[str]
                   ) -> Tuple[int, int, Dict[str, int]]:
        """This index's contribution to global BM25 statistics:
        (docs with text, total tokens, per-query-token document
        frequency).  `CorpusStats.aggregate` sums these across shards."""
        return (self._docs_with_text, self._total_tokens,
                {tok: int(self._postings(tok)[0].shape[0])
                 for tok in tokens})

    # --------------------------------------------------------------- scoring
    def scores(self, tokens: Sequence[str],
               stats: Optional[CorpusStats] = None) -> np.ndarray:
        """Dense (n_rows,) float64 BM25 scores for already-deduped query
        tokens — the vectorized numpy path `search()` ranks with.  `stats`
        substitutes global (cross-shard) corpus statistics for this
        index's local ones."""
        n = len(self._doc_lens)
        out = np.zeros(n, dtype=np.float64)
        n_docs = stats.docs_with_text if stats else self._docs_with_text
        if n == 0 or not n_docs:
            return out
        norm, _ = self._norm(stats.avgdl if stats else None)
        for tok in tokens:
            rows, tfs = self._postings(tok)
            if rows.shape[0] == 0:
                continue
            df = stats.df.get(tok, int(rows.shape[0])) if stats \
                else int(rows.shape[0])
            idf = float(_idf(n_docs, df))
            tf = tfs.astype(np.float64)
            contrib = idf * tf * (self.k1 + 1.0) / (tf + norm[rows])
            np.add.at(out, rows, contrib)
        return out

    def scores_jax(self, tokens: Sequence[str],
                   stats: Optional[CorpusStats] = None) -> np.ndarray:
        """Batched JAX scoring over the packed postings of the query's
        tokens: one gather of (rows, tfs, per-posting idf), one fused
        contribution computation, one scatter-add into the dense score
        vector.  Numerically equivalent to `scores()` up to float32
        accumulation — use for large candidate sets on device; the numpy
        path remains the exact reference."""
        import jax.numpy as jnp

        n = len(self._doc_lens)
        n_docs = stats.docs_with_text if stats else self._docs_with_text
        if n == 0 or not n_docs:
            return np.zeros(n, dtype=np.float64)
        gathered = [(tok, *self._postings(tok)) for tok in tokens]
        gathered = [(tok, r, t) for tok, r, t in gathered if r.shape[0]]
        if not gathered:
            return np.zeros(n, dtype=np.float64)
        rows = np.concatenate([r for _, r, _ in gathered])
        tfs = np.concatenate([t for _, _, t in gathered]).astype(np.float32)
        idf = np.concatenate([
            np.full(r.shape[0],
                    float(_idf(n_docs,
                               stats.df.get(tok, int(r.shape[0])) if stats
                               else int(r.shape[0]))),
                    dtype=np.float32)
            for tok, r, _ in gathered])
        norm, _ = self._norm(stats.avgdl if stats else None)
        norm_g = norm.astype(np.float32)[rows]
        contrib = jnp.asarray(idf) * jnp.asarray(tfs) * (self.k1 + 1.0) \
            / (jnp.asarray(tfs) + jnp.asarray(norm_g))
        dense = jnp.zeros(n, dtype=jnp.float32).at[jnp.asarray(rows)].add(
            contrib)
        return np.asarray(dense, dtype=np.float64)

    def search(self, text: str, k: int,
               mask: Optional[np.ndarray] = None,
               backend: str = "numpy",
               stats: Optional[CorpusStats] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k BM25 search.  Returns padded (k,) arrays in the engine's
        candidate convention: distances = **negated** scores ascending
        (best first), +inf / row -1 for empty slots; `mask` (row liveness
        and/or a metadata filter) removes candidates but does not change
        the corpus statistics.  Ties break on ascending row id.  `stats`
        scores against global (cross-shard) corpus statistics."""
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        tokens = self.config.query_tokens(text)
        scorer = self.scores_jax if backend == "jax" else self.scores
        scores = scorer(tokens, stats=stats)
        if mask is not None:
            m = np.asarray(mask, dtype=bool)
            scores = np.where(m[:scores.shape[0]], scores, 0.0)
        return rank_scores(scores, k)

    # ------------------------------------------------------------------ misc
    def stats(self) -> Dict[str, Any]:
        return {"docs": len(self._doc_lens),
                "docs_indexed": self.docs_indexed,
                "vocab": self.vocab_size,
                "postings": self.postings,
                "sealed_postings": self.sealed_postings,
                "delta_postings": self.delta_postings,
                "seals": self.seals}

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Packed arrays only — the sealed/delta split survives the
        round-trip, so a loaded index keeps absorbing upserts without a
        rebuild."""
        sealed_vocab = [None] * len(self._vocab)
        for tok, slot in self._vocab.items():
            sealed_vocab[slot] = tok
        d_vocab, d_offsets, d_rows, d_tfs = [], [0], [], []
        for tok in sorted(self._delta):
            rows, tfs = self._delta[tok]
            d_vocab.append(tok)
            d_rows.extend(rows)
            d_tfs.extend(tfs)
            d_offsets.append(len(d_rows))
        return {
            "vocab": np.asarray(sealed_vocab, dtype=object),
            "offsets": self._offsets,
            "rows": self._rows,
            "tfs": self._tfs,
            "delta_vocab": np.asarray(d_vocab, dtype=object),
            "delta_offsets": np.asarray(d_offsets, dtype=np.int64),
            "delta_rows": np.asarray(d_rows, dtype=np.int64),
            "delta_tfs": np.asarray(d_tfs, dtype=np.int64),
            "doc_lens": np.asarray(self._doc_lens, dtype=np.int64),
            "counters": np.asarray([self._total_tokens,
                                    self._docs_with_text,
                                    self._sealed_docs, self.seals],
                                   dtype=np.int64),
        }

    @classmethod
    def from_state_dict(cls, state: Dict[str, np.ndarray],
                        config: Optional[TokenizerConfig] = None,
                        k1: float = BM25_K1, b: float = BM25_B
                        ) -> "SparseIndex":
        idx = cls(config, k1=k1, b=b)
        idx._vocab = {str(tok): slot
                      for slot, tok in enumerate(state["vocab"])}
        idx._offsets = np.asarray(state["offsets"], dtype=np.int64)
        idx._rows = np.asarray(state["rows"], dtype=np.int64)
        idx._tfs = np.asarray(state["tfs"], dtype=np.int64)
        d_off = np.asarray(state["delta_offsets"], dtype=np.int64)
        d_rows = np.asarray(state["delta_rows"], dtype=np.int64)
        d_tfs = np.asarray(state["delta_tfs"], dtype=np.int64)
        for i, tok in enumerate(state["delta_vocab"]):
            lo, hi = int(d_off[i]), int(d_off[i + 1])
            idx._delta[str(tok)] = (list(d_rows[lo:hi].tolist()),
                                    list(d_tfs[lo:hi].tolist()))
        idx._delta_postings = int(d_rows.shape[0])
        idx._doc_lens = [int(x) for x in state["doc_lens"]]
        total, with_text, sealed_docs, seals = \
            (int(x) for x in state["counters"])
        idx._total_tokens = total
        idx._docs_with_text = with_text
        idx._sealed_docs = sealed_docs
        idx.seals = seals
        return idx


# ------------------------------------------------------------------ ranking
def rank_scores(scores: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Dense score vector -> padded (k,) (distances, rows): rows with
    score > 0 ranked by (-score, row id), distances negated float32."""
    scores = np.asarray(scores, dtype=np.float64)
    cand = np.flatnonzero(scores > 0.0)
    if cand.shape[0]:
        order = np.lexsort((cand, -scores[cand]))[:k]
        top = cand[order]
    else:
        top = cand
    d = np.full(k, np.inf, dtype=np.float32)
    rows = np.full(k, -1, dtype=np.int64)
    d[:top.shape[0]] = (-scores[top]).astype(np.float32)
    rows[:top.shape[0]] = top
    return d, rows


# ---------------------------------------------------------------- reference
def bm25_reference(texts: Sequence[Optional[str]], query: str,
                   config: Optional[TokenizerConfig] = None,
                   k1: float = BM25_K1, b: float = BM25_B) -> np.ndarray:
    """Brute-force dense BM25 scores straight from raw texts — no index
    structure at all.  Deliberately mirrors `SparseIndex.scores()`'s
    accumulation order (per deduped query token, ascending row), so the
    incremental index must match it float-for-float, not just rank-wise."""
    config = config or TokenizerConfig()
    doc_tokens = [config.tokenize(t) for t in texts]
    doc_lens = np.asarray([len(t) for t in doc_tokens], dtype=np.float64)
    with_text = int((doc_lens > 0).sum())
    out = np.zeros(len(doc_tokens), dtype=np.float64)
    if with_text == 0:
        return out
    avgdl = float(doc_lens.sum()) / with_text
    norm = k1 * (1.0 - b + b * doc_lens / avgdl)
    counts = [Counter(t) for t in doc_tokens]
    for tok in (config.query_tokens(query)):
        rows = np.asarray([i for i, c in enumerate(counts) if tok in c],
                          dtype=np.int64)
        if rows.shape[0] == 0:
            continue
        tf = np.asarray([counts[i][tok] for i in rows], dtype=np.float64)
        idf = float(_idf(with_text, rows.shape[0]))
        contrib = idf * tf * (k1 + 1.0) / (tf + norm[rows])
        np.add.at(out, rows, contrib)
    return out
