"""HNSW graph construction (paper §II-B-1), faithful to Malkov & Yashunin.

Construction is inherently sequential (each insert searches the graph built so
far), so — exactly as real deployments do (indexes are built offline on host
CPUs, then served from accelerators) — the builder runs host-side in numpy,
and the *search* runs on-device (see hnsw_search.py).  The builder vectorises
each beam expansion (one gather + one GEMM per expansion) instead of scalar
distance calls.

Algorithms implemented (numbering from the paper's reference [1]):
  * Alg 1 INSERT         — level sampling l = ⌊−ln(U)·mL⌋, mL = 1/ln(M);
                           greedy descent above l, ef_construction beam at ≤ l.
  * Alg 2 SEARCH-LAYER   — beam search with visited set, ef-bounded result heap.
  * Alg 4 SELECT-NEIGHBORS-HEURISTIC — keep candidate e iff it is closer to q
                           than to every already-selected neighbour (with
                           keepPruned fill-up), which preserves long-range
                           "small-world" links.
  * M_max enforcement    — overflowing nodes are re-pruned with the heuristic.

A second, beyond-paper builder (`bulk_build`) constructs the same packed
structure from an exact kNN graph computed as one big GEMM (device-friendly,
CAGRA-style bulk build) — orders of magnitude faster for large corpora; its
recall is compared against the faithful builder in tests/benchmarks.

Output is a `PackedHNSW`: fixed-shape, padded dense arrays that the jitted
TPU search consumes (see DESIGN.md §2 for the adaptation rationale).
"""

from __future__ import annotations

import dataclasses
import heapq
import logging
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

PAD = -1  # padding sentinel in adjacency rows

logger = logging.getLogger(__name__)

# optional build-progress callback: (phase, done, total) -> None
ProgressFn = Callable[[str, int, int], None]


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    M: int = 16                    # max out-degree at layers >= 1
    M0: Optional[int] = None       # max out-degree at layer 0 (default 2M)
    ef_construction: int = 100
    metric: str = "cosine"         # "cosine" | "l2" | "dot"
    seed: int = 0
    extend_candidates: bool = False
    keep_pruned: bool = True
    # search-time default: candidates popped per wide-beam iteration
    # (1 == classic single-pop traversal); per-query override rides the
    # engine/API search path
    expansion_width: int = 4
    # --- device bulk-builder knobs (core/hnsw_bulk.py) ---
    bulk_mode: str = "auto"        # "auto" | "level" | "coarse"
    build_batch: int = 1024        # nodes per level-wise insert step
    ef_build: Optional[int] = None  # construction beam (None -> ef_construction)
    coarse_threshold: int = 1024   # auto: two-phase coarse path at n >= this
    coarse_cluster: int = 8192     # target rows per coarse k-means cluster
    #   (single global-kNN cluster up to ~12k rows — chunked GEMM keeps the
    #   quadratic self-join cheap there, and skipping k-means + boundary
    #   stitching is both faster and higher-recall at that scale)
    stitch_frac: float = 0.1       # fraction of boundary nodes beam-stitched

    def __post_init__(self):
        if self.expansion_width < 1:
            raise ValueError(
                f"expansion_width must be >= 1, got {self.expansion_width}")
        if self.bulk_mode not in ("auto", "level", "coarse"):
            raise ValueError(f"bulk_mode must be auto|level|coarse, "
                             f"got {self.bulk_mode!r}")
        if self.build_batch < 1:
            raise ValueError(
                f"build_batch must be >= 1, got {self.build_batch}")
        if self.coarse_cluster < 1:
            raise ValueError(
                f"coarse_cluster must be >= 1, got {self.coarse_cluster}")
        if not 0.0 <= self.stitch_frac <= 1.0:
            raise ValueError(
                f"stitch_frac must be in [0, 1], got {self.stitch_frac}")

    @property
    def m0(self) -> int:
        return self.M0 if self.M0 is not None else 2 * self.M

    @property
    def mL(self) -> float:
        return 1.0 / math.log(self.M)


@dataclasses.dataclass
class PackedHNSW:
    """Fixed-shape dense-graph representation consumed by the jitted search.

    vectors are stored metric-preprocessed (unit-normalized for cosine) so the
    device search can use the cheap dot/L2 form directly.
    """

    config: HNSWConfig
    vectors: np.ndarray        # (N, D) float32, preprocessed
    adj0: np.ndarray           # (N, M0) int32 global ids, PAD-filled
    upper_ids: np.ndarray      # (n_upper,) int32: upper-slot -> global id
    upper_adj: np.ndarray      # (n_upper, L_top, M) int32 *upper-slot* ids
    levels: np.ndarray         # (N,) int8 node levels
    entry_global: int
    entry_upper: int
    max_level: int
    # builder observability (mode, batch/cluster/stitch counters); not
    # serialized — checkpoints restore it empty
    build_info: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return int(self.vectors.shape[0])

    def degree_stats(self) -> Dict[str, float]:
        deg0 = (self.adj0 != PAD).sum(1)
        return {"mean_deg0": float(deg0.mean()), "max_deg0": float(deg0.max()),
                "n_upper": float(len(self.upper_ids)),
                "max_level": float(self.max_level)}

    def state_dict(self) -> Dict[str, np.ndarray]:
        return {
            "vectors": self.vectors, "adj0": self.adj0,
            "upper_ids": self.upper_ids, "upper_adj": self.upper_adj,
            "levels": self.levels,
            "meta": np.array([self.entry_global, self.entry_upper,
                              self.max_level], dtype=np.int64),
        }

    @classmethod
    def from_state_dict(cls, state, config: HNSWConfig) -> "PackedHNSW":
        eg, eu, ml = (int(v) for v in state["meta"])
        return cls(config=config, vectors=state["vectors"], adj0=state["adj0"],
                   upper_ids=state["upper_ids"], upper_adj=state["upper_adj"],
                   levels=state["levels"], entry_global=eg, entry_upper=eu,
                   max_level=ml)


# ---------------------------------------------------------------------------
# metric preprocessing: map every metric onto "smaller raw score == closer"
# ---------------------------------------------------------------------------

def preprocess_vectors(x: np.ndarray, metric: str) -> np.ndarray:
    x = np.asarray(x, dtype=np.float32)
    if metric == "cosine":
        n = np.linalg.norm(x, axis=1, keepdims=True)
        return x / np.maximum(n, 1e-12)
    return x


def make_dist_fn(vectors: np.ndarray, metric: str) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    """(q (D,), ids (m,)) -> (m,) distances. cosine inputs are pre-normalized
    so cosine == 1 - dot == monotone in dot; we use -dot for speed."""
    if metric in ("cosine", "dot"):
        def fn(q, ids):
            return -(vectors[ids] @ q)
    elif metric == "l2":
        def fn(q, ids):
            d = vectors[ids] - q[None, :]
            return np.einsum("md,md->m", d, d)
    else:  # pragma: no cover
        raise ValueError(f"unsupported metric {metric}")
    return fn


# ---------------------------------------------------------------------------
# Faithful incremental builder
# ---------------------------------------------------------------------------

class _GraphBuilder:
    """Adjacency as python lists during construction; packed at the end."""

    def __init__(self, cfg: HNSWConfig, vectors: np.ndarray):
        self.cfg = cfg
        self.vectors = vectors
        self.n = vectors.shape[0]
        self.levels = np.zeros((self.n,), dtype=np.int8)
        # adj[layer][node] -> list[int]; layer 0 exists for every node.
        self.adj: List[Dict[int, List[int]]] = [dict()]
        self.entry: int = -1
        self.max_level: int = -1
        self.dist = make_dist_fn(vectors, cfg.metric)
        self._rng = np.random.RandomState(cfg.seed)

    # -- Alg 2: search one layer ------------------------------------------------
    def search_layer(self, q: np.ndarray, eps: List[int], ef: int,
                     layer: int) -> List[Tuple[float, int]]:
        adj = self.adj[layer]
        dist = self.dist
        visited = set(eps)
        ep_d = dist(q, np.fromiter(eps, np.int64, len(eps)))
        cand: List[Tuple[float, int]] = [(float(d), e) for d, e in zip(ep_d, eps)]
        heapq.heapify(cand)                       # min-heap on distance
        res: List[Tuple[float, int]] = [(-d, e) for d, e in cand]
        heapq.heapify(res)                        # max-heap via negation
        while len(res) > ef:
            heapq.heappop(res)
        while cand:
            d_c, c = heapq.heappop(cand)
            if d_c > -res[0][0] and len(res) >= ef:
                break
            fresh = [e for e in adj.get(c, ()) if e not in visited]
            if not fresh:
                continue
            visited.update(fresh)
            ids = np.fromiter(fresh, np.int64, len(fresh))
            ds = dist(q, ids)                     # vectorized expansion
            bound = -res[0][0]
            for d_e, e in zip(ds, fresh):
                d_e = float(d_e)
                if len(res) < ef or d_e < bound:
                    heapq.heappush(cand, (d_e, e))
                    heapq.heappush(res, (-d_e, e))
                    if len(res) > ef:
                        heapq.heappop(res)
                    bound = -res[0][0]
        return sorted((-d, e) for d, e in res)    # ascending distance

    # -- Alg 4: heuristic neighbour selection ----------------------------------
    def select_neighbors(self, q_vec: np.ndarray,
                         cand: List[Tuple[float, int]], m: int,
                         layer: int) -> List[int]:
        cfg = self.cfg
        work = list(cand)
        if cfg.extend_candidates:
            seen = {e for _, e in work}
            extra = []
            for _, e in cand:
                for nb in self.adj[layer].get(e, ()):  # pragma: no cover (off by default)
                    if nb not in seen:
                        seen.add(nb)
                        extra.append(nb)
            if extra:
                ids = np.fromiter(extra, np.int64, len(extra))
                ds = self.dist(q_vec, ids)
                work.extend((float(d), e) for d, e in zip(ds, extra))
        work.sort()
        selected: List[int] = []
        pruned: List[Tuple[float, int]] = []
        for d_e, e in work:
            if len(selected) >= m:
                break
            if not selected:
                selected.append(e)
                continue
            sel_ids = np.fromiter(selected, np.int64, len(selected))
            d_to_sel = self.dist(self.vectors[e], sel_ids)
            if d_e < float(d_to_sel.min()):       # closer to q than to selection
                selected.append(e)
            else:
                pruned.append((d_e, e))
        if self.cfg.keep_pruned:
            for d_e, e in pruned:
                if len(selected) >= m:
                    break
                selected.append(e)
        return selected

    def _link(self, a: int, b: int, layer: int) -> None:
        self.adj[layer].setdefault(a, []).append(b)

    def _shrink(self, e: int, layer: int) -> None:
        m_max = self.cfg.m0 if layer == 0 else self.cfg.M
        nbrs = self.adj[layer].get(e, [])
        if len(nbrs) <= m_max:
            return
        ids = np.fromiter(nbrs, np.int64, len(nbrs))
        ds = self.dist(self.vectors[e], ids)
        cand = sorted((float(d), nb) for d, nb in zip(ds, nbrs))
        self.adj[layer][e] = self.select_neighbors(self.vectors[e], cand,
                                                   m_max, layer)

    # -- Alg 1: insert ----------------------------------------------------------
    def insert(self, idx: int) -> None:
        cfg = self.cfg
        q = self.vectors[idx]
        l_new = int(-math.log(max(self._rng.random_sample(), 1e-12)) * cfg.mL)
        self.levels[idx] = min(l_new, 127)
        while len(self.adj) <= l_new:
            self.adj.append(dict())
        for layer in range(l_new + 1):
            self.adj[layer].setdefault(idx, [])

        if self.entry < 0:                         # first element
            self.entry, self.max_level = idx, l_new
            return

        ep = [self.entry]
        # greedy descent with ef=1 above the insertion level
        for layer in range(self.max_level, l_new, -1):
            ep = [self.search_layer(q, ep, 1, layer)[0][1]]
        # beam insert at each layer <= min(l_new, max_level)
        for layer in range(min(self.max_level, l_new), -1, -1):
            cand = self.search_layer(q, ep, cfg.ef_construction, layer)
            m = cfg.m0 if layer == 0 else cfg.M
            nbrs = self.select_neighbors(q, cand, m, layer)
            for e in nbrs:
                self._link(idx, e, layer)
                self._link(e, idx, layer)
                self._shrink(e, layer)
            ep = [e for _, e in cand]
        if l_new > self.max_level:
            self.entry, self.max_level = idx, l_new


def _pack(builder: _GraphBuilder) -> PackedHNSW:
    cfg, n = builder.cfg, builder.n
    adj0 = np.full((n, cfg.m0), PAD, dtype=np.int32)
    for node, nbrs in builder.adj[0].items():
        row = nbrs[: cfg.m0]
        adj0[node, : len(row)] = row

    upper_ids = np.where(builder.levels >= 1)[0].astype(np.int32)
    slot_of = {int(g): s for s, g in enumerate(upper_ids)}
    l_top = max(builder.max_level, 1)
    upper_adj = np.full((max(len(upper_ids), 1), l_top, cfg.M), PAD,
                        dtype=np.int32)
    for layer in range(1, builder.max_level + 1):
        for node, nbrs in builder.adj[layer].items():
            s = slot_of[node]
            row = [slot_of[e] for e in nbrs[: cfg.M]]
            upper_adj[s, layer - 1, : len(row)] = row

    entry_upper = slot_of.get(builder.entry, 0) if len(upper_ids) else 0
    return PackedHNSW(
        config=cfg, vectors=builder.vectors, adj0=adj0,
        upper_ids=upper_ids if len(upper_ids) else np.zeros((1,), np.int32),
        upper_adj=upper_adj, levels=builder.levels,
        entry_global=builder.entry, entry_upper=entry_upper,
        max_level=builder.max_level)


def build(vectors: np.ndarray, config: HNSWConfig = HNSWConfig(),
          insert_order: Optional[np.ndarray] = None,
          progress: Optional[ProgressFn] = None) -> PackedHNSW:
    """Faithful incremental HNSW build."""
    vecs = preprocess_vectors(vectors, config.metric)
    b = _GraphBuilder(config, vecs)
    order = (np.arange(b.n) if insert_order is None
             else np.asarray(insert_order, dtype=np.int64))
    report_every = max(1, b.n // 20)
    for i, idx in enumerate(order):
        b.insert(int(idx))
        done = i + 1
        if done % report_every == 0 or done == b.n:
            logger.debug("incremental build: %d/%d inserted", done, b.n)
            if progress is not None:
                progress("insert", done, b.n)
    return _pack(b)


# ---------------------------------------------------------------------------
# Bulk builder (beyond-paper): exact-kNN graph -> pruned navigable graph
# ---------------------------------------------------------------------------

def bulk_build(vectors: np.ndarray, config: HNSWConfig = HNSWConfig(),
               knn_indices: Optional[np.ndarray] = None,
               chunk: int = 4096,
               progress: Optional[ProgressFn] = None) -> PackedHNSW:
    """Build the packed structure from an exact kNN graph (one GEMM per chunk).

    Level structure is sampled with the same geometric distribution; layer-l
    adjacency connects each upper node to its nearest peers *within the same
    layer's node set* — preserving the hierarchy's coarse-to-fine routing.
    The base layer applies the Alg-4 diversification heuristic to the kNN
    candidate list (this is what turns a kNN graph into a navigable graph).
    """
    cfg = config
    vecs = preprocess_vectors(vectors, cfg.metric)
    n, d = vecs.shape
    rng = np.random.RandomState(cfg.seed)
    k = min(cfg.m0 + cfg.M, n - 1)

    if knn_indices is None:
        knn_indices = exact_knn(vecs, vecs, k + 1, metric=cfg.metric,
                                chunk=chunk)[:, 1:]  # drop self

    # long-range candidates: a pure kNN graph fragments on strongly
    # clustered data (no inter-cluster edges); random extras let the Alg-4
    # diversification heuristic keep a few far links per node — the
    # "small-world" property the incremental builder gets from its
    # insertion-time beam search.
    n_rand = min(cfg.M, max(n - 1, 1))
    rand_cands = rng.randint(0, n, size=(n, n_rand)).astype(np.int32)

    dist = make_dist_fn(vecs, cfg.metric)

    # base layer: heuristic-prune each node's kNN candidates to m0
    report_every = max(1, n // 10)
    adj0 = np.full((n, cfg.m0), PAD, dtype=np.int32)
    for i in range(n):
        if (i + 1) % report_every == 0 or i + 1 == n:
            logger.debug("bulk_ref prune: %d/%d", i + 1, n)
            if progress is not None:
                progress("prune", i + 1, n)
        cand_ids = np.unique(np.concatenate(
            [knn_indices[i], rand_cands[i]]))
        cand_ids = cand_ids[cand_ids != i]
        ds = dist(vecs[i], cand_ids.astype(np.int64))
        order = np.argsort(ds)
        selected: List[int] = []
        pruned: List[int] = []
        for o in order:
            e = int(cand_ids[o])
            if len(selected) >= cfg.m0:
                break
            if not selected:
                selected.append(e)
                continue
            sel = np.asarray(selected, dtype=np.int64)
            if float(ds[o]) < float(dist(vecs[e], sel).min()):
                selected.append(e)
            else:
                pruned.append(e)
        for e in pruned:
            if len(selected) >= cfg.m0:
                break
            selected.append(e)
        adj0[i, : len(selected)] = selected

    # symmetrize (bidirectional links), then cap at m0
    sym: List[List[int]] = [list(adj0[i][adj0[i] != PAD]) for i in range(n)]
    for i in range(n):
        for e in adj0[i]:
            if e != PAD and i not in sym[e]:
                sym[int(e)].append(i)
    adj0 = np.full((n, cfg.m0), PAD, dtype=np.int32)
    for i in range(n):
        row = sym[i]
        if len(row) > cfg.m0:
            ids = np.asarray(row, dtype=np.int64)
            ds = dist(vecs[i], ids)
            row = [row[j] for j in np.argsort(ds)[: cfg.m0]]
        adj0[i, : len(row)] = row

    # hierarchy: geometric level sampling, per-layer kNN among layer members
    levels = np.minimum((-np.log(np.maximum(rng.random_sample(n), 1e-12))
                         * cfg.mL).astype(np.int64), 127).astype(np.int8)
    max_level = int(levels.max()) if n else 0
    upper_ids = np.where(levels >= 1)[0].astype(np.int32)
    if len(upper_ids) == 0:
        upper_ids = np.array([0], dtype=np.int32)
        levels[0] = 1
        max_level = max(max_level, 1)
    slot_of = {int(g): s for s, g in enumerate(upper_ids)}
    l_top = max(max_level, 1)
    upper_adj = np.full((len(upper_ids), l_top, cfg.M), PAD, dtype=np.int32)
    for layer in range(1, max_level + 1):
        members = upper_ids[levels[upper_ids] >= layer]
        if len(members) <= 1:
            continue
        kk = min(max(cfg.M - 2, 1), len(members) - 1)
        nn = exact_knn(vecs[members], vecs[members], kk + 1,
                       metric=cfg.metric, chunk=chunk)[:, 1:]
        # symmetrized kNN + a couple of random member links per node —
        # upper-layer routing must not fragment on clustered data
        links = {int(g): set(int(members[j]) for j in nn[row_i])
                 for row_i, g in enumerate(members)}
        for row_i, g in enumerate(members):
            for j in rng.randint(0, len(members), size=2):
                if int(members[j]) != int(g):
                    links[int(g)].add(int(members[j]))
            for nb in list(links[int(g)]):
                links[nb].add(int(g))
        for g, nbrs in links.items():
            s = slot_of[g]
            row = [slot_of[nb] for nb in list(nbrs)[: cfg.M]]
            upper_adj[s, layer - 1, : len(row)] = row

    top_members = upper_ids[levels[upper_ids] >= max_level]
    entry_global = int(top_members[0]) if len(top_members) else int(upper_ids[0])
    return PackedHNSW(config=cfg, vectors=vecs, adj0=adj0, upper_ids=upper_ids,
                      upper_adj=upper_adj, levels=levels,
                      entry_global=entry_global,
                      entry_upper=slot_of.get(entry_global, 0),
                      max_level=max_level)


def exact_knn(queries: np.ndarray, corpus: np.ndarray, k: int,
              metric: str = "cosine", chunk: int = 4096) -> np.ndarray:
    """Host-side exact kNN ids (chunked GEMM); ground truth for recall tests."""
    q = preprocess_vectors(queries, metric)
    x = preprocess_vectors(corpus, metric)
    return knn_ids_dists(q, x, k, metric=metric, chunk=chunk)[0]


def knn_ids_dists(q: np.ndarray, x: np.ndarray, k: int, metric: str,
                  chunk: int = 4096,
                  corpus_chunk: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact kNN over *preprocessed* vectors, chunked on BOTH axes.

    Never materializes more than a (chunk, corpus_chunk) distance block, so
    the self-join survives 100k+ row corpora where the old single-axis
    chunking allocated a full (chunk, N) row panel.  Returns (ids, dists)
    sorted ascending by raw score (squared L2 / negated dot).
    """
    n = x.shape[0]
    k = min(k, n)
    if corpus_chunk is None:
        # bound the block to ~16M floats (64 MB) regardless of chunk size
        corpus_chunk = max(1024, (1 << 24) // max(chunk, 1))
    nq = q.shape[0]
    out_i = np.zeros((nq, k), dtype=np.int32)
    out_d = np.zeros((nq, k), dtype=np.float32)
    xx_all = (x * x).sum(1) if metric == "l2" else None
    for lo in range(0, nq, chunk):
        qc = q[lo: lo + chunk]
        qq = (qc * qc).sum(1)[:, None] if metric == "l2" else None
        best_d = np.full((qc.shape[0], k), np.inf, dtype=np.float32)
        best_i = np.full((qc.shape[0], k), PAD, dtype=np.int32)
        for clo in range(0, n, corpus_chunk):
            xc = x[clo: clo + corpus_chunk]
            if metric == "l2":
                d = qq + xx_all[clo: clo + corpus_chunk][None, :] \
                    - 2.0 * qc @ xc.T
            else:
                d = -(qc @ xc.T)
            kk = min(k, d.shape[1])
            idx = np.argpartition(d, kk - 1, axis=1)[:, :kk] \
                if kk < d.shape[1] else np.broadcast_to(
                    np.arange(d.shape[1], dtype=np.int64), d.shape)
            dd = np.take_along_axis(d, idx, axis=1)
            cat_d = np.concatenate([best_d, dd.astype(np.float32)], axis=1)
            cat_i = np.concatenate(
                [best_i, (idx + clo).astype(np.int32)], axis=1)
            sel = np.argpartition(cat_d, k - 1, axis=1)[:, :k] \
                if k < cat_d.shape[1] else np.broadcast_to(
                    np.arange(cat_d.shape[1], dtype=np.int64), cat_d.shape)
            best_d = np.take_along_axis(cat_d, sel, axis=1)
            best_i = np.take_along_axis(cat_i, sel, axis=1)
        order = np.argsort(best_d, axis=1, kind="stable")
        out_d[lo: lo + chunk] = np.take_along_axis(best_d, order, axis=1)
        out_i[lo: lo + chunk] = np.take_along_axis(best_i, order, axis=1)
    return out_i, out_d
