"""Device-parallel bulk HNSW construction.

The incremental builder (hnsw_build.py) inserts one node at a time — each
insert beam-searches the graph built so far, so construction is inherently
serial and dominates total indexing cost (~109 s for 6k vectors vs ~7 s of
search sweep in BENCH_hnsw.json).  This module rebuilds the same packed
structure with batched, device-friendly phases; every per-node Python loop
of the seed ``bulk_build`` is lifted to fixed-shape jitted array programs:

  * **Vectorized Alg-4 prune** (`_prune_batch`): SELECT-NEIGHBORS-HEURISTIC
    for a whole batch at once — candidate lists are distance-sorted, the
    candidate×candidate pair-distance matrix comes from the fused
    ``pair_gather`` kernel (kernels/bulk_prune.py), and a masked
    ``lax.scan`` walks the C candidate slots maintaining the selected set,
    exactly the "closer to q than to every selected neighbour" rule with
    keepPruned fill-up.
  * **Deterministic scatter/cap symmetrize** (`_merge_cap`): forward +
    reverse edges and the existing adjacency are merged as one edge list,
    deduplicated by (target, source), ranked per target by (distance, id)
    with composed stable sorts, and scattered back capped at M — the
    intra-batch conflict resolution pass, fully on device.
  * **Level-wise batched inserts** (`_bulk_level`): nodes are inserted in
    descending-level order; the first batch bootstraps the graph (and all
    upper-layer nodes) from exact kNN, each following batch runs vmapped
    wide-beam descents (hnsw_search.search — PR 4's fused ``beam_gather``
    kernels) over the *frozen prefix* graph to collect candidates, plus an
    intra-batch kNN block so batch-mates can link to each other.
  * **Two-phase coarse mode** (`_bulk_coarse`): for cold-start bulk loads
    the beam descents are replaced by k-means coarse clustering (the
    ``ivf.py``/``pq.py`` machinery) → intra-cluster exact kNN (each node
    sees the union of its two nearest clusters, so boundary nodes get
    cross-cluster candidates) → one global prune + symmetrize → boundary
    nodes (smallest assignment margin) re-linked through batched beam
    searches over the built graph.  Build cost scales ~O(n·k·d) instead of
    the O(n²) brute-force self-join.

Both modes share the level sampling, upper-hierarchy construction and
connectivity repair, and produce a `PackedHNSW` interchangeable with the
incremental builder's output.  Mode "auto" picks coarse at
``coarse_threshold`` rows and level-wise below it; corpora too small for
fixed-shape batching fall back to the numpy reference ``bulk_build``.
"""

from __future__ import annotations

import functools
import logging
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .hnsw_build import (PAD, HNSWConfig, PackedHNSW, ProgressFn, bulk_build,
                         knn_ids_dists, preprocess_vectors)
from .hnsw_search import HNSWGraph
from .hnsw_search import search as beam_search
from .pq import _fit_one_subspace

logger = logging.getLogger(__name__)

INF = np.float32(np.inf)

PRUNE_CHUNK = 512        # nodes pruned per jitted call (fixed shape)
MIN_DEVICE_N = 32        # below this the numpy reference builder is used
STITCH_EF = 64           # beam width cap for cross-cluster stitching
KMEANS_ITERS = 8


# ---------------------------------------------------------------------------
# vectorized Alg-4 SELECT-NEIGHBORS-HEURISTIC
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "mode", "keep_pruned"))
def _prune_batch(corpus: jax.Array, q_ids: jax.Array, cand_ids: jax.Array,
                 cand_d: jax.Array, *, m: int, mode: str,
                 keep_pruned: bool) -> Tuple[jax.Array, jax.Array]:
    """Batched diversification prune: (B, C) candidates -> (B, m) selected.

    Candidate j survives iff it is closer to the query than to every
    already-selected neighbour (the paper's Alg 4), evaluated as a masked
    scan over the distance-sorted candidate slots; the candidate-pair
    distances come from the fused pair-gather kernel.  PAD / self /
    duplicate / out-of-range candidates are masked out first.  Returns
    (ids PAD-padded, raw scores inf-padded), both in selection order.
    """
    b, c = cand_ids.shape
    n = corpus.shape[0]
    sentinel = jnp.int32(n)
    rows = jnp.arange(b)[:, None]

    invalid = (cand_ids < 0) | (cand_ids >= n) \
        | (cand_ids == q_ids[:, None].astype(jnp.int32))
    # duplicate candidates: cluster ids (invalid -> sentinel) with a stable
    # sort, flag repeats, scatter the flags back to original slots
    ids_key = jnp.where(invalid, sentinel, cand_ids)
    o_id = jnp.argsort(ids_key, axis=1)
    sid = jnp.take_along_axis(ids_key, o_id, axis=1)
    dup_s = jnp.concatenate(
        [jnp.zeros((b, 1), bool),
         (sid[:, 1:] == sid[:, :-1]) & (sid[:, 1:] < sentinel)], axis=1)
    invalid = invalid | jnp.zeros((b, c), bool).at[rows, o_id].set(dup_s)

    d = jnp.where(invalid, jnp.inf, cand_d.astype(jnp.float32))
    o_d = jnp.argsort(d, axis=1)                   # stable: ties keep order
    cid = jnp.take_along_axis(cand_ids, o_d, axis=1)
    cd = jnp.take_along_axis(d, o_d, axis=1)
    valid = jnp.isfinite(cd)

    safe = jnp.where(valid, cid, 0)
    pair = jax.vmap(
        lambda r: ops.pair_gather_distances(r, corpus, mode=mode))(safe)

    def step(carry, j):
        sel, nsel = carry                          # (B, C) bool, (B,) int32
        dj = cd[:, j]
        pj = jnp.take(pair, j, axis=1)             # (B, C): d(cand_j, ·)
        dmin = jnp.min(jnp.where(sel, pj, jnp.inf), axis=1)
        ok = valid[:, j] & (nsel < m) & ((nsel == 0) | (dj < dmin))
        sel = sel.at[:, j].set(ok)
        return (sel, nsel + ok.astype(jnp.int32)), None

    init = (jnp.zeros((b, c), bool), jnp.zeros((b,), jnp.int32))
    (sel, nsel), _ = jax.lax.scan(step, init, jnp.arange(c))

    # final order: selected (already distance-sorted) first, then — with
    # keepPruned — the pruned survivors by distance, invalid slots last
    idx = jnp.broadcast_to(jnp.arange(c)[None, :], (b, c))
    if keep_pruned:
        key = jnp.where(sel, idx,
                        jnp.where(valid, c + idx, 2 * c + idx))
        limit = jnp.minimum(m, valid.sum(axis=1))
    else:
        key = jnp.where(sel, idx, 2 * c + idx)
        limit = jnp.minimum(m, nsel)
    o_f = jnp.argsort(key, axis=1)
    fid = jnp.take_along_axis(cid, o_f, axis=1)[:, :m]
    fd = jnp.take_along_axis(cd, o_f, axis=1)[:, :m]
    pos_ok = jnp.arange(m)[None, :] < limit[:, None]
    # slot priority: 0 = heuristically selected (diverse — must survive
    # later degree capping), 1 = keepPruned fill (nearest, replaceable)
    pri = (jnp.arange(m)[None, :] >= nsel[:, None]).astype(jnp.int32)
    return (jnp.where(pos_ok, fid, PAD).astype(jnp.int32),
            jnp.where(pos_ok, fd, jnp.inf),
            jnp.where(pos_ok, pri, 1))


def _prune_chunks(corpus_dev: jax.Array, q_ids: np.ndarray,
                  cand_ids: np.ndarray, cand_d: np.ndarray, *, m: int,
                  mode: str, keep_pruned: bool, chunk: int = PRUNE_CHUNK
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run `_prune_batch` over fixed-size chunks (one compile per shape)."""
    nq, c = cand_ids.shape
    n = int(corpus_dev.shape[0])
    step = min(chunk, nq)
    out_i = np.full((nq, m), PAD, dtype=np.int32)
    out_d = np.full((nq, m), INF, dtype=np.float32)
    out_p = np.ones((nq, m), dtype=np.int32)
    for lo in range(0, nq, step):
        hi = min(lo + step, nq)
        real = hi - lo
        qs = q_ids[lo:hi].astype(np.int32)
        ci = cand_ids[lo:hi]
        cd = cand_d[lo:hi]
        if real < step:                            # pad the tail chunk
            qs = np.concatenate([qs, np.full(step - real, n, np.int32)])
            ci = np.vstack([ci, np.full((step - real, c), PAD, np.int32)])
            cd = np.vstack([cd, np.full((step - real, c), INF, np.float32)])
        si, sd, sp = _prune_batch(corpus_dev, jnp.asarray(qs),
                                  jnp.asarray(ci), jnp.asarray(cd), m=m,
                                  mode=mode, keep_pruned=keep_pruned)
        out_i[lo:hi] = np.asarray(si)[:real]
        out_d[lo:hi] = np.asarray(sd)[:real]
        out_p[lo:hi] = np.asarray(sp)[:real]
    return out_i, out_d, out_p


# ---------------------------------------------------------------------------
# deterministic scatter/cap symmetrize (intra-batch conflict resolution)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m",))
def _merge_cap(adj: jax.Array, adj_d: jax.Array, adj_p: jax.Array,
               new_tgt: jax.Array, new_src: jax.Array, new_d: jax.Array,
               new_p: jax.Array, *, m: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge incoming directed edges into the adjacency, capped at m.

    adj / adj_d / adj_p are (N+1, m) — row N is a scratch row absorbing
    masked writes.  Existing rows and the incoming (tgt, src, dist,
    priority) edges form one edge list; (target, source) duplicates are
    dropped keeping the best copy, entries are ranked per target by
    (priority, distance, source id) via composed stable sorts, and ranks
    < m are scattered back.  Priority 0 marks heuristically-selected
    (Alg 4) edges, 1 marks keepPruned fill and reverse edges: ranking
    priority first means degree capping evicts nearest-fill edges before
    the diverse long-range links the heuristic chose — the same outcome
    as the incremental builder's `_shrink` re-prune, without re-running
    the heuristic per overflow.  Every result row is self-loop-free and
    duplicate-free regardless of how many same-batch nodes targeted the
    same neighbour.
    """
    np1, _ = adj.shape
    scratch = np1 - 1

    ex_tgt = jnp.broadcast_to(
        jnp.arange(np1, dtype=jnp.int32)[:, None], adj.shape).reshape(-1)
    tgt = jnp.concatenate([ex_tgt, new_tgt.astype(jnp.int32)])
    src = jnp.concatenate([adj.reshape(-1), new_src.astype(jnp.int32)])
    dd = jnp.concatenate([adj_d.reshape(-1).astype(jnp.float32),
                          new_d.astype(jnp.float32)])
    pri = jnp.concatenate([adj_p.reshape(-1).astype(jnp.int32),
                           new_p.astype(jnp.int32)])
    bad = (src < 0) | (src >= scratch) | (tgt < 0) | (tgt >= scratch) \
        | (src == tgt) | ~jnp.isfinite(dd)
    tgt = jnp.where(bad, scratch, tgt)
    src_k = jnp.where(bad, scratch, src)
    dd = jnp.where(bad, jnp.inf, dd)
    pri = jnp.where(bad, 1, pri)

    # dedup by (target, source): stable lexicographic sort on
    # (target, source, priority, distance), flag adjacent repeats, scatter
    # the flags back.  The surviving copy is the best (priority, distance)
    # one — a reverse duplicate must not demote a selected edge to fill.
    o = jnp.argsort(dd)
    o = o[jnp.argsort(pri[o])]
    o = o[jnp.argsort(src_k[o])]
    perm = o[jnp.argsort(tgt[o])]
    t_s, s_s = tgt[perm], src_k[perm]
    dup_s = jnp.concatenate(
        [jnp.zeros((1,), bool),
         (t_s[1:] == t_s[:-1]) & (s_s[1:] == s_s[:-1]) & (t_s[1:] < scratch)])
    dup = jnp.zeros_like(dup_s).at[perm].set(dup_s)
    tgt = jnp.where(dup, scratch, tgt)
    dd = jnp.where(dup, jnp.inf, dd)
    pri = jnp.where(dup, 1, pri)

    # rank per target by (priority, distance, source id): composed sorts
    o = jnp.argsort(src_k)
    tgt, src, dd, pri = tgt[o], src[o], dd[o], pri[o]
    o = jnp.argsort(dd)
    tgt, src, dd, pri = tgt[o], src[o], dd[o], pri[o]
    o = jnp.argsort(pri)
    tgt, src, dd, pri = tgt[o], src[o], dd[o], pri[o]
    o = jnp.argsort(tgt)
    tgt, src, dd, pri = tgt[o], src[o], dd[o], pri[o]
    pos = jnp.arange(tgt.shape[0], dtype=jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool), tgt[1:] != tgt[:-1]])
    group_start = jax.lax.cummax(jnp.where(first, pos, 0))
    rank = pos - group_start

    keep = (rank < m) & (tgt < scratch) & jnp.isfinite(dd)
    row = jnp.where(keep, tgt, scratch)
    col = jnp.where(keep, rank, 0)
    out = jnp.full((np1, m), PAD, jnp.int32).at[row, col].set(
        jnp.where(keep, src, PAD))
    out_d = jnp.full((np1, m), jnp.inf, jnp.float32).at[row, col].set(
        jnp.where(keep, dd, jnp.inf))
    out_p = jnp.ones((np1, m), jnp.int32).at[row, col].set(
        jnp.where(keep, pri, 1))
    return out, out_d, out_p


def _edges_both_ways(sel_ids: np.ndarray, sel_d: np.ndarray,
                     sel_p: np.ndarray, node_ids: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
    """Pruned selections -> forward + reverse directed edge arrays.

    Forward edges carry the prune's slot priority (0 = heuristic pick);
    reverse edges are always priority 1 — they were not chosen by the
    target's own diversification, so they compete as fill."""
    m = sel_ids.shape[1]
    tgt_f = np.repeat(node_ids.astype(np.int32), m)
    src_f = sel_ids.reshape(-1)
    d_f = sel_d.reshape(-1)
    p_f = sel_p.reshape(-1).astype(np.int32)
    return (np.concatenate([tgt_f, src_f]),
            np.concatenate([src_f, tgt_f]),
            np.concatenate([d_f, d_f]),
            np.concatenate([p_f, np.ones_like(p_f)]))


# ---------------------------------------------------------------------------
# shared phases: levels, upper hierarchy, candidate helpers, repair
# ---------------------------------------------------------------------------

def _sample_levels(n: int, cfg: HNSWConfig,
                   rng: np.random.RandomState) -> np.ndarray:
    lv = np.minimum((-np.log(np.maximum(rng.random_sample(n), 1e-12))
                     * cfg.mL).astype(np.int64), 127).astype(np.int8)
    if not (lv >= 1).any():
        lv[0] = 1                                  # guarantee a hierarchy
    return lv


def _rowwise_dists(vecs: np.ndarray, row_ids: np.ndarray,
                   nbr_ids: np.ndarray, metric: str,
                   chunk: int = 2048) -> np.ndarray:
    """d(vecs[row_ids[i]], vecs[nbr_ids[i, j]]) -> (len, r) raw scores."""
    out = np.empty(nbr_ids.shape, dtype=np.float32)
    r = nbr_ids.shape[1]
    for lo in range(0, len(row_ids), chunk):
        hi = min(lo + chunk, len(row_ids))
        a = vecs[row_ids[lo:hi]]
        b = vecs[nbr_ids[lo:hi].reshape(-1)].reshape(hi - lo, r, -1)
        if metric == "l2":
            diff = b - a[:, None, :]
            out[lo:hi] = np.einsum("crd,crd->cr", diff, diff)
        else:
            out[lo:hi] = -np.einsum("cd,crd->cr", a, b)
    return out


def _build_upper(vecs: np.ndarray, levels: np.ndarray, cfg: HNSWConfig,
                 rng: np.random.RandomState, mode: str
                 ) -> Tuple[np.ndarray, np.ndarray, int, int, int]:
    """Per-layer kNN hierarchy among layer members (seed-builder semantics:
    symmetrized member kNN + a couple of random member links per node)."""
    max_level = int(levels.max())
    upper_ids = np.where(levels >= 1)[0].astype(np.int32)
    slot_of = {int(g): s for s, g in enumerate(upper_ids)}
    l_top = max(max_level, 1)
    upper_adj = np.full((len(upper_ids), l_top, cfg.M), PAD, dtype=np.int32)
    for layer in range(1, max_level + 1):
        members = upper_ids[levels[upper_ids] >= layer]
        if len(members) <= 1:
            continue
        kk = min(max(cfg.M - 2, 1), len(members) - 1)
        nn = knn_ids_dists(vecs[members], vecs[members], kk + 1,
                           metric=mode)[0][:, 1:]
        links = {int(g): set(int(members[j]) for j in nn[row_i])
                 for row_i, g in enumerate(members)}
        for row_i, g in enumerate(members):
            for j in rng.randint(0, len(members), size=2):
                if int(members[j]) != int(g):
                    links[int(g)].add(int(members[j]))
            links[int(g)].discard(int(g))
            for nb in list(links[int(g)]):
                links[nb].add(int(g))
        for g, nbrs in links.items():
            s = slot_of[g]
            row = [slot_of[nb] for nb in sorted(nbrs)[: cfg.M]]
            upper_adj[s, layer - 1, : len(row)] = row
    top_members = upper_ids[levels[upper_ids] >= max_level]
    entry_global = (int(top_members[0]) if len(top_members)
                    else int(upper_ids[0]))
    return (upper_ids, upper_adj, max_level, entry_global,
            slot_of.get(entry_global, 0))


def _bfs_reachable(adj0: np.ndarray, entry: int) -> np.ndarray:
    n = adj0.shape[0]
    seen = np.zeros(n, dtype=bool)
    frontier = np.array([entry], dtype=np.int64)
    seen[entry] = True
    while len(frontier):
        nxt = adj0[frontier].reshape(-1)
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt)
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return seen


def _repair_connectivity(vecs: np.ndarray, adj0: np.ndarray,
                         adj0_d: np.ndarray, entry: int,
                         mode: str) -> int:
    """Attach components unreachable from the entry point: every stranded
    node gets a bidirectional link to its nearest reachable node (replacing
    the farthest slot when the row is full).  Mutates adj0/adj0_d in place;
    returns the number of repaired nodes."""
    seen = _bfs_reachable(adj0, entry)
    lost = np.where(~seen)[0]
    if len(lost) == 0:
        return 0
    anchors = np.where(seen)[0]
    ids, dd = knn_ids_dists(vecs[lost], vecs[anchors], 1, metric=mode)
    near = anchors[ids[:, 0]]
    for u, a, d in zip(lost, near, dd[:, 0]):
        for node, other in ((int(a), int(u)), (int(u), int(a))):
            row = adj0[node]
            if other in row:
                continue
            slot = int(np.argmax(row == PAD)) if (row == PAD).any() \
                else row.shape[0] - 1
            row[slot] = other
            adj0_d[node, slot] = d
    return int(len(lost))


# ---------------------------------------------------------------------------
# mode 1: level-wise batched inserts over the frozen prefix
# ---------------------------------------------------------------------------

def _bulk_level(vecs: np.ndarray, cfg: HNSWConfig, rng: np.random.RandomState,
                levels: np.ndarray, graph_meta, mode: str,
                progress: Optional[ProgressFn]) -> Tuple[
                    np.ndarray, np.ndarray, Dict]:
    upper_ids, upper_adj, max_level, entry_global, entry_upper = graph_meta
    n, _ = vecs.shape
    m0 = cfg.m0
    ef_build = cfg.ef_build or cfg.ef_construction
    k_base = min(m0 + cfg.M, n - 1)
    r = min(cfg.M, 8, n - 1)

    corpus_dev = jnp.asarray(vecs)
    adj = jnp.full((n + 1, m0), PAD, jnp.int32)
    adj_d = jnp.full((n + 1, m0), jnp.inf, jnp.float32)
    adj_p = jnp.ones((n + 1, m0), jnp.int32)

    # descending-level insertion order puts every upper-layer node (entry
    # point included) into the bootstrap set, so beam descents always land
    # on linked prefix nodes
    order = np.argsort(-levels.astype(np.int64), kind="stable")
    batch = min(cfg.build_batch, n)
    b0 = min(n, max(batch, len(upper_ids)))
    boot = order[:b0]

    def add_edges(sel_i, sel_d, sel_p, node_ids, m):
        nonlocal adj, adj_d, adj_p
        tgt, src, dd, pp = _edges_both_ways(sel_i, sel_d, sel_p, node_ids)
        adj, adj_d, adj_p = _merge_cap(
            adj, adj_d, adj_p, jnp.asarray(tgt), jnp.asarray(src),
            jnp.asarray(dd), jnp.asarray(pp), m=m)

    # ---- bootstrap: exact kNN + prune among the first b0 nodes
    kb = min(k_base + 1, b0)
    loc_ids, loc_d = knn_ids_dists(vecs[boot], vecs[boot], kb, metric=mode)
    cand_i = boot[loc_ids].astype(np.int32)
    cand_d = loc_d
    if r > 0:
        rnd = boot[rng.randint(0, b0, size=(b0, r))].astype(np.int32)
        cand_i = np.concatenate([cand_i, rnd], axis=1)
        cand_d = np.concatenate(
            [cand_d, _rowwise_dists(vecs, boot, rnd, mode)], axis=1)
    sel_i, sel_d, sel_p = _prune_chunks(corpus_dev, boot, cand_i, cand_d,
                                        m=m0, mode=mode,
                                        keep_pruned=cfg.keep_pruned)
    add_edges(sel_i, sel_d, sel_p, boot, m0)
    if progress is not None:
        progress("insert", b0, n)
    logger.debug("bulk level: bootstrap %d/%d", b0, n)

    # ---- batched level-wise growth over the frozen prefix
    g_upper = (jnp.asarray(upper_ids), jnp.asarray(upper_adj))
    k_beam = min(k_base, ef_build)
    k_intra = min(8, batch - 1) if batch > 1 else 0
    width = max(cfg.expansion_width, 8)
    n_batches = 0
    for lo in range(b0, n, batch):
        hi = min(lo + batch, n)
        bids = order[lo:hi]
        if len(bids) < batch:                      # pad the tail batch
            bids = np.concatenate(
                [bids, np.full(batch - len(bids), n, np.int64)])
        q = vecs[np.minimum(bids, n - 1)]
        g = HNSWGraph(vectors=corpus_dev, adj0=adj[:n],
                      upper_ids=g_upper[0], upper_adj=g_upper[1],
                      entry_global=jnp.asarray(entry_global, jnp.int32),
                      entry_upper=jnp.asarray(entry_upper, jnp.int32))
        bd, bi = beam_search(g, jnp.asarray(q), k=k_beam, ef=ef_build,
                             max_level=max_level, metric=mode,
                             expansion_width=width)
        cand_i = [np.asarray(bi)]
        cand_d = [np.asarray(bd)]
        if k_intra > 0:
            ii, idd = knn_ids_dists(q, q, k_intra + 1, metric=mode)
            cand_i.append(bids[ii].astype(np.int32))
            cand_d.append(idd)
        if r > 0:
            rnd = order[rng.randint(0, hi, size=(batch, r))].astype(np.int32)
            cand_i.append(rnd)
            cand_d.append(_rowwise_dists(
                vecs, np.minimum(bids, n - 1), rnd, mode))
        ci = np.concatenate(cand_i, axis=1)
        cd = np.concatenate(cand_d, axis=1)
        sel_i, sel_d, sel_p = _prune_chunks(corpus_dev, bids, ci, cd, m=m0,
                                            mode=mode,
                                            keep_pruned=cfg.keep_pruned,
                                            chunk=batch)
        pad_rows = bids >= n
        sel_i[pad_rows] = PAD
        sel_d[pad_rows] = INF
        add_edges(sel_i, sel_d, sel_p, bids.astype(np.int32), m0)
        n_batches += 1
        if progress is not None:
            progress("insert", hi, n)
        logger.debug("bulk level: %d/%d inserted", hi, n)

    adj0 = np.array(adj[:n])
    adj0_d = np.array(adj_d[:n])
    return adj0, adj0_d, {"build_batches": n_batches + 1,
                          "build_bootstrap": int(b0)}


# ---------------------------------------------------------------------------
# mode 2: two-phase coarse build (cluster -> link -> stitch)
# ---------------------------------------------------------------------------

def _coarse_candidates(vecs: np.ndarray, cfg: HNSWConfig,
                       rng: np.random.RandomState, mode: str,
                       progress: Optional[ProgressFn]
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """k-means cluster the corpus, then exact-kNN each node against the
    union of its two nearest clusters.  Returns (cand_ids, cand_d,
    boundary_margin, nlist); margin is the assignment-score gap (small =
    near a cluster boundary = stitch candidate)."""
    n, _ = vecs.shape
    nlist = max(1, int(round(n / cfg.coarse_cluster)))
    # candidate pool per node: one full adjacency row of slots plus half the
    # construction beam.  Priority-aware merge capping preserves the
    # heuristic's diverse picks, so the pool does not need to match the full
    # ef_construction beam — prune time grows roughly linearly with kc.
    ef_b = cfg.ef_build or cfg.ef_construction
    kc = min(max(cfg.m0 + cfg.M, ef_b // 2) + 2, n)

    if nlist <= 1:
        ids, dd = knn_ids_dists(vecs, vecs, kc, metric=mode)
        return ids, dd, np.zeros(n, np.float32), 1

    samp = rng.choice(n, size=min(n, max(nlist * 64, 4096)), replace=False)
    cent = np.asarray(_fit_one_subspace(
        jax.random.PRNGKey(cfg.seed), jnp.asarray(vecs[samp]), nlist,
        KMEANS_ITERS))
    if progress is not None:
        progress("cluster", nlist, nlist)

    # two nearest centroids per node: boundary nodes see both clusters
    a1 = np.empty(n, np.int32)
    a2 = np.empty(n, np.int32)
    margin = np.empty(n, np.float32)
    cc = (cent * cent).sum(1)
    for lo in range(0, n, 8192):
        hi = min(lo + 8192, n)
        blk = vecs[lo:hi]
        if mode == "l2":
            d = ((blk * blk).sum(1)[:, None] + cc[None, :]
                 - 2.0 * blk @ cent.T)
        else:
            d = -(blk @ cent.T)
        top2 = np.argpartition(d, 1, axis=1)[:, :2]
        dt = np.take_along_axis(d, top2, axis=1)
        swap = dt[:, 0] > dt[:, 1]
        top2[swap] = top2[swap][:, ::-1]
        dt[swap] = dt[swap][:, ::-1]
        a1[lo:hi], a2[lo:hi] = top2[:, 0], top2[:, 1]
        margin[lo:hi] = dt[:, 1] - dt[:, 0]

    cand_i = np.full((n, kc), PAD, dtype=np.int32)
    cand_d = np.full((n, kc), INF, dtype=np.float32)
    for c in range(nlist):
        prim = np.where(a1 == c)[0]
        if len(prim) == 0:
            continue
        mem = np.where((a1 == c) | (a2 == c))[0]
        kk = min(kc, len(mem))
        loc, dd = knn_ids_dists(vecs[prim], vecs[mem], kk, metric=mode)
        cand_i[prim, :kk] = mem[loc].astype(np.int32)
        cand_d[prim, :kk] = dd
        if progress is not None:
            progress("link", c + 1, nlist)
        logger.debug("bulk coarse: cluster %d/%d linked (%d members)",
                     c + 1, nlist, len(mem))
    return cand_i, cand_d, margin, nlist


def _bulk_coarse(vecs: np.ndarray, cfg: HNSWConfig,
                 rng: np.random.RandomState, levels: np.ndarray, graph_meta,
                 mode: str, progress: Optional[ProgressFn]
                 ) -> Tuple[np.ndarray, np.ndarray, Dict]:
    upper_ids, upper_adj, max_level, entry_global, entry_upper = graph_meta
    n, _ = vecs.shape
    m0 = cfg.m0
    r = min(cfg.M, 8, n - 1)

    cand_i, cand_d, margin, nlist = _coarse_candidates(
        vecs, cfg, rng, mode, progress)
    if r > 0:
        rnd = rng.randint(0, n, size=(n, r)).astype(np.int32)
        cand_i = np.concatenate([cand_i, rnd], axis=1)
        cand_d = np.concatenate(
            [cand_d, _rowwise_dists(vecs, np.arange(n), rnd, mode)], axis=1)

    corpus_dev = jnp.asarray(vecs)
    all_ids = np.arange(n, dtype=np.int32)
    sel_i, sel_d, sel_p = _prune_chunks(corpus_dev, all_ids, cand_i, cand_d,
                                        m=m0, mode=mode,
                                        keep_pruned=cfg.keep_pruned)
    if progress is not None:
        progress("prune", n, n)

    adj = jnp.full((n + 1, m0), PAD, jnp.int32)
    adj_d = jnp.full((n + 1, m0), jnp.inf, jnp.float32)
    adj_p = jnp.ones((n + 1, m0), jnp.int32)
    tgt, src, dd, pp = _edges_both_ways(sel_i, sel_d, sel_p, all_ids)
    adj, adj_d, adj_p = _merge_cap(
        adj, adj_d, adj_p, jnp.asarray(tgt), jnp.asarray(src),
        jnp.asarray(dd), jnp.asarray(pp), m=m0)

    # ---- cross-cluster stitching: boundary nodes re-search the built graph
    n_stitch = int(round(cfg.stitch_frac * n)) if nlist > 1 else 0
    if n_stitch > 0:
        ef_st = max(min(cfg.ef_build or STITCH_EF, STITCH_EF), cfg.M)
        k_st = min(min(m0 + cfg.M, n - 1), ef_st)
        width = max(cfg.expansion_width, 8)
        boundary = np.argsort(margin, kind="stable")[:n_stitch]
        batch = min(cfg.build_batch, n_stitch)
        for lo in range(0, n_stitch, batch):
            hi = min(lo + batch, n_stitch)
            bids = boundary[lo:hi]
            if len(bids) < batch:
                bids = np.concatenate(
                    [bids, np.full(batch - len(bids), n, np.int64)])
            q = vecs[np.minimum(bids, n - 1)]
            g = HNSWGraph(vectors=corpus_dev, adj0=adj[:n],
                          upper_ids=jnp.asarray(upper_ids),
                          upper_adj=jnp.asarray(upper_adj),
                          entry_global=jnp.asarray(entry_global, jnp.int32),
                          entry_upper=jnp.asarray(entry_upper, jnp.int32))
            bd, bi = beam_search(g, jnp.asarray(q), k=k_st, ef=ef_st,
                                 max_level=max_level, metric=mode,
                                 expansion_width=width)
            # merge beam hits with the node's existing row, re-prune
            ci = np.concatenate(
                [np.asarray(bi), np.asarray(adj[np.minimum(bids, n - 1)])],
                axis=1)
            cd = np.concatenate(
                [np.asarray(bd), np.asarray(adj_d[np.minimum(bids, n - 1)])],
                axis=1)
            sel_i, sel_d, sel_p = _prune_chunks(corpus_dev, bids, ci, cd,
                                                m=m0, mode=mode,
                                                keep_pruned=cfg.keep_pruned,
                                                chunk=batch)
            pad_rows = bids >= n
            sel_i[pad_rows] = PAD
            sel_d[pad_rows] = INF
            tgt, src, dd, pp = _edges_both_ways(sel_i, sel_d, sel_p,
                                                bids.astype(np.int32))
            adj, adj_d, adj_p = _merge_cap(
                adj, adj_d, adj_p, jnp.asarray(tgt), jnp.asarray(src),
                jnp.asarray(dd), jnp.asarray(pp), m=m0)
            if progress is not None:
                progress("stitch", hi, n_stitch)
        logger.debug("bulk coarse: stitched %d boundary nodes", n_stitch)

    adj0 = np.array(adj[:n])
    adj0_d = np.array(adj_d[:n])
    return adj0, adj0_d, {"build_clusters": nlist,
                          "build_stitched": n_stitch}


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def bulk_build_device(vectors: np.ndarray,
                      config: HNSWConfig = HNSWConfig(),
                      progress: Optional[ProgressFn] = None) -> PackedHNSW:
    """Device-parallel bulk HNSW build (the `builder="bulk"` engine path).

    Dispatches on ``config.bulk_mode``: "level" = batched level-wise
    inserts via beam descents over the frozen prefix; "coarse" = two-phase
    k-means clustering + intra-cluster linking + boundary stitching;
    "auto" picks coarse at ``coarse_threshold`` rows.  Corpora below
    ``MIN_DEVICE_N`` rows fall back to the numpy reference ``bulk_build``
    (fixed-shape batching has no leverage there).
    """
    cfg = config
    vecs = preprocess_vectors(vectors, cfg.metric)
    n = vecs.shape[0]
    if n < MIN_DEVICE_N:
        packed = bulk_build(vectors, cfg, progress=progress)
        packed.build_info = {"builder_mode": "ref_small_n"}
        return packed

    mode = cfg.bulk_mode
    if mode == "auto":
        mode = "coarse" if n >= cfg.coarse_threshold else "level"
    dev_metric = "l2" if cfg.metric == "l2" else "dot"

    rng = np.random.RandomState(cfg.seed)
    levels = _sample_levels(n, cfg, rng)
    graph_meta = _build_upper(vecs, levels, cfg, rng, dev_metric)
    upper_ids, upper_adj, max_level, entry_global, entry_upper = graph_meta

    build_fn = _bulk_coarse if mode == "coarse" else _bulk_level
    adj0, adj0_d, info = build_fn(vecs, cfg, rng, levels, graph_meta,
                                  dev_metric, progress)

    repaired = _repair_connectivity(vecs, adj0, adj0_d, entry_global,
                                    dev_metric)
    if repaired:
        logger.info("bulk build: reattached %d stranded nodes", repaired)
    info.update({"builder_mode": mode, "build_repaired": repaired})

    return PackedHNSW(config=cfg, vectors=vecs, adj0=adj0,
                      upper_ids=upper_ids, upper_adj=upper_adj,
                      levels=levels, entry_global=entry_global,
                      entry_upper=entry_upper, max_level=max_level,
                      build_info=info)
