"""Flat (exact) index — the paper's precision-first baseline (§III-C).

"Flat Indexing, while simple, offers the guarantee of finding the actual exact
nearest neighbors" — a linear scan with top-k selection.  On TPU the scan is a
single MXU GEMM; for corpora too large for one distance matrix we chunk over
the corpus dimension and merge partial top-k results (streaming top-k), which
is also the primitive the distributed shard_map search reuses.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .distances import get_metric

Array = jax.Array


def merge_topk(d_a: Array, i_a: Array, d_b: Array, i_b: Array, k: int) -> Tuple[Array, Array]:
    """Merge two (Q, ka)/(Q, kb) candidate sets into the best-k (ascending).

    Associative + commutative (up to ties) — property-tested; used by both the
    chunked scan and the cross-shard merge.
    """
    d = jnp.concatenate([d_a, d_b], axis=-1)
    i = jnp.concatenate([i_a, i_b], axis=-1)
    neg_d, sel = jax.lax.top_k(-d, k)
    return -neg_d, jnp.take_along_axis(i, sel, axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def flat_search(
    queries: Array,
    corpus: Array,
    k: int,
    metric: str = "cosine",
    chunk: Optional[int] = None,
    mask: Optional[Array] = None,
    base_index: int = 0,
) -> Tuple[Array, Array]:
    """Exact top-k scan.

    Args:
      queries: (Q, D).
      corpus: (N, D).
      k: neighbours to return.
      metric: registry name.
      chunk: if set, scan the corpus in chunks of this many rows (bounds the
        transient (Q, chunk) distance matrix — the streaming top-k used when
        N·Q is too big for one buffer).
      mask: optional (N,) bool — MEVS metadata filter; False rows are excluded
        (distance = +inf).
      base_index: offset added to returned indices (shard-local -> global ids).

    Returns:
      (distances (Q,k) ascending, indices (Q,k) int32).
    """
    pair = get_metric(metric)
    n = corpus.shape[0]
    k = min(k, n)

    if chunk is None or chunk >= n:
        d = pair(queries, corpus)
        if mask is not None:
            d = jnp.where(mask[None, :], d, jnp.inf)
        neg_d, idx = jax.lax.top_k(-d, k)
        return -neg_d, (idx + base_index).astype(jnp.int32)

    # Streaming top-k over corpus chunks.  Pad N up to a chunk multiple with
    # +inf rows so every scan step has a fixed shape.
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    corpus_p = jnp.pad(corpus, ((0, pad), (0, 0)))
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    mask_p = jnp.pad(mask, (0, pad), constant_values=False)
    corpus_c = corpus_p.reshape(n_chunks, chunk, corpus.shape[1])
    mask_c = mask_p.reshape(n_chunks, chunk)

    q_count = queries.shape[0]
    init = (
        jnp.full((q_count, k), jnp.inf, dtype=jnp.float32),
        jnp.full((q_count, k), -1, dtype=jnp.int32),
    )

    def step(carry, inp):
        best_d, best_i = carry
        chunk_vecs, chunk_mask, chunk_idx = inp
        d = pair(queries, chunk_vecs)
        d = jnp.where(chunk_mask[None, :], d, jnp.inf)
        local_ids = (chunk_idx * chunk + jnp.arange(chunk) + base_index).astype(jnp.int32)
        neg_d, sel = jax.lax.top_k(-d, min(k, chunk))
        cand_d = -neg_d
        cand_i = local_ids[sel]
        return merge_topk(best_d, best_i, cand_d, cand_i, k), None

    (best_d, best_i), _ = jax.lax.scan(
        step, init, (corpus_c, mask_c, jnp.arange(n_chunks)))
    return best_d, best_i


@dataclass
class FlatIndex:
    """Thin stateful wrapper used by the engine; all compute is in flat_search."""

    metric: str = "cosine"
    chunk: Optional[int] = None

    def search(self, corpus: Array, queries: Array, k: int,
               mask: Optional[Array] = None) -> Tuple[Array, Array]:
        return flat_search(queries, corpus, k, metric=self.metric,
                           chunk=self.chunk, mask=mask)
