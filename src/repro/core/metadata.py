"""Columnar metadata store + predicate evaluation for MEVS (paper §III-A).

"Metadata-Enhanced Vector Search … starts with metadata-based filtering and
then proceeds to vector similarity analysis."  The store keeps one numpy
column per attribute; a predicate tree evaluates to a boolean mask over the
corpus, which the engine threads into the (masked) similarity search.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

_OPS: Dict[str, Callable[[np.ndarray, Any], np.ndarray]] = {
    "eq": lambda c, v: c == v,
    "ne": lambda c, v: c != v,
    "lt": lambda c, v: c < v,
    "le": lambda c, v: c <= v,
    "gt": lambda c, v: c > v,
    "ge": lambda c, v: c >= v,
    "in": lambda c, v: np.isin(c, np.asarray(list(v))),
}


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Leaf predicate: column <op> value."""

    column: str
    op: str
    value: Any

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; have {sorted(_OPS)}")


@dataclasses.dataclass(frozen=True)
class And:
    clauses: Sequence["Filter"]


@dataclasses.dataclass(frozen=True)
class Or:
    clauses: Sequence["Filter"]


@dataclasses.dataclass(frozen=True)
class Not:
    clause: "Filter"


Filter = Union[Predicate, And, Or, Not]


class MetadataStore:
    """Append-only columnar store aligned with the vector corpus by row id."""

    def __init__(self):
        self._columns: Dict[str, List[Any]] = {}
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def columns(self):
        return sorted(self._columns)

    def append_batch(self, records: Sequence[Optional[Dict[str, Any]]]) -> None:
        """Add one record per inserted vector (None allowed -> all-missing)."""
        for rec in records:
            rec = rec or {}
            for key in rec:
                if key not in self._columns:
                    self._columns[key] = [None] * self._n
            for key, col in self._columns.items():
                col.append(rec.get(key))
            self._n += 1

    def column(self, name: str) -> np.ndarray:
        if name not in self._columns:
            raise KeyError(f"no metadata column {name!r}")
        return np.asarray(self._columns[name])

    def record(self, row: int) -> Dict[str, Any]:
        """The row's metadata record (missing values omitted)."""
        if not 0 <= row < self._n:
            raise IndexError(f"row {row} out of range [0, {self._n})")
        return {name: col[row] for name, col in self._columns.items()
                if col[row] is not None}

    def evaluate(self, flt: Filter) -> np.ndarray:
        """Predicate tree -> (N,) bool mask. Missing values never match —
        including a column no record has ever written: it is all-missing,
        not an error (the schema layer has already vetted the name)."""
        if isinstance(flt, Predicate):
            if flt.op == "in" and len(tuple(flt.value)) == 0:
                # an empty value set matches nothing, by definition; don't
                # hand np.isin an empty (dtype-less float64) array to
                # compare against an object column
                return np.zeros((self._n,), dtype=bool)
            if flt.column not in self._columns:
                return np.zeros((self._n,), dtype=bool)
            col = self.column(flt.column)
            present = col != np.array(None)
            mask = np.zeros((self._n,), dtype=bool)
            if present.any():
                vals = col[present]
                try:
                    vals = vals.astype(type(flt.value))
                except (TypeError, ValueError):
                    pass
                mask[present] = _OPS[flt.op](vals, flt.value)
            return mask
        if isinstance(flt, And):
            out = np.ones((self._n,), dtype=bool)
            for c in flt.clauses:
                out &= self.evaluate(c)
            return out
        if isinstance(flt, Or):
            out = np.zeros((self._n,), dtype=bool)
            for c in flt.clauses:
                out |= self.evaluate(c)
            return out
        if isinstance(flt, Not):
            return ~self.evaluate(flt.clause)
        raise TypeError(f"not a filter: {flt!r}")

    # persistence -----------------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out = {"__n__": np.array([self._n], dtype=np.int64)}
        for name, col in self._columns.items():
            out[f"col:{name}"] = np.asarray(col, dtype=object)
        return out

    @classmethod
    def from_state_dict(cls, state) -> "MetadataStore":
        ms = cls()
        ms._n = int(state["__n__"][0])
        for key, val in state.items():
            if key.startswith("col:"):
                ms._columns[key[4:]] = list(val)
        return ms
