"""Product Quantization (paper §II-B-2).

Faithful to the paper's formulation:

  1) Partition x ∈ R^d into m sub-vectors x = [x^(1) … x^(m)], each in R^{d/m}.
  2) Learn a k-centroid codebook C^(i) per sub-space (Lloyd's k-means).
  3) Encode each sub-vector as its nearest centroid id (uint8 for k ≤ 256).
  4) Search with Asymmetric Distance Computation (ADC): a (m, k) lookup table
     of query-subvector→centroid distances is built once per query; the
     distance to a database code is the sum of m table lookups — no float
     arithmetic against the corpus at all.

TPU adaptation: k-means is vmapped across the m sub-spaces (one batched
program instead of m sequential fits); ADC is a gather+reduce that the Pallas
kernel in kernels/pq_adc.py tiles through VMEM (LUT resident, codes streamed).

Cosine support follows the standard construction: unit-normalize vectors
before codebook training/encoding, then squared-L2 ADC is monotone in cosine
distance (‖x−y‖² = 2 − 2·cosθ on the unit sphere).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import normalize

Array = jax.Array


@dataclass(frozen=True)
class PQConfig:
    m: int = 16          # number of sub-vectors
    k: int = 256         # codebook size per sub-space (uint8 codes)
    iters: int = 25      # Lloyd iterations
    metric: str = "l2"   # "l2" | "cosine"  (cosine == l2 on normalized inputs)

    def validate(self, d: int) -> None:
        if d % self.m != 0:
            raise ValueError(f"d={d} not divisible by m={self.m}")
        if self.k > 65536:
            raise ValueError("k > 65536 unsupported")

    def code_dtype(self):
        return jnp.uint8 if self.k <= 256 else jnp.uint16


# ---------------------------------------------------------------------------
# k-means (single sub-space) — vmapped over sub-spaces below
# ---------------------------------------------------------------------------

def _kmeans_plus_plus_ish_init(key: Array, x: Array, k: int) -> Array:
    """Cheap seeding: random distinct samples (k-means‖ is overkill at d/m dims)."""
    n = x.shape[0]
    idx = jax.random.choice(key, n, shape=(k,), replace=n < k)
    return x[idx]


def _lloyd_step(x: Array, centroids: Array) -> Tuple[Array, Array]:
    """One Lloyd iteration. x: (n, s), centroids: (k, s) -> (new_centroids, assign)."""
    # pairwise squared L2 via GEMM
    xx = jnp.sum(x * x, axis=1)
    cc = jnp.sum(centroids * centroids, axis=1)
    d = xx[:, None] + cc[None, :] - 2.0 * (x @ centroids.T)
    assign = jnp.argmin(d, axis=1)
    k = centroids.shape[0]
    one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (n, k)
    counts = one_hot.sum(0)  # (k,)
    sums = one_hot.T @ x  # (k, s)
    new = sums / jnp.maximum(counts, 1.0)[:, None]
    # Empty clusters keep their old centroid (standard fallback).
    new = jnp.where(counts[:, None] > 0, new, centroids)
    return new, assign


def _fit_one_subspace(key: Array, x: Array, k: int, iters: int) -> Array:
    cent = _kmeans_plus_plus_ish_init(key, x, k)

    def body(_, c):
        c2, _ = _lloyd_step(x, c)
        return c2

    return jax.lax.fori_loop(0, iters, body, cent)


@functools.partial(jax.jit, static_argnames=("m", "k", "iters", "normalize_inputs"))
def train_codebooks(key: Array, vectors: Array, m: int, k: int,
                    iters: int = 25, normalize_inputs: bool = False) -> Array:
    """Learn (m, k, d/m) codebooks with a vmapped batched k-means."""
    if normalize_inputs:
        vectors = normalize(vectors)
    n, d = vectors.shape
    s = d // m
    sub = vectors.astype(jnp.float32).reshape(n, m, s).transpose(1, 0, 2)  # (m, n, s)
    keys = jax.random.split(key, m)
    return jax.vmap(lambda kk, xx: _fit_one_subspace(kk, xx, k, iters))(keys, sub)


@functools.partial(jax.jit, static_argnames=("normalize_inputs",))
def encode(vectors: Array, codebooks: Array, normalize_inputs: bool = False) -> Array:
    """Quantize: (n, d) -> (n, m) codes (argmin centroid per sub-space)."""
    if normalize_inputs:
        vectors = normalize(vectors)
    m, k, s = codebooks.shape
    n = vectors.shape[0]
    sub = vectors.astype(jnp.float32).reshape(n, m, s)

    def per_sub(x_ms, cb):  # x_ms: (n, s), cb: (k, s)
        d = (jnp.sum(x_ms * x_ms, 1)[:, None] + jnp.sum(cb * cb, 1)[None, :]
             - 2.0 * x_ms @ cb.T)
        return jnp.argmin(d, axis=1)

    codes = jax.vmap(per_sub, in_axes=(1, 0), out_axes=1)(sub, codebooks)
    dtype = jnp.uint8 if k <= 256 else jnp.uint16
    return codes.astype(dtype)


@jax.jit
def decode(codes: Array, codebooks: Array) -> Array:
    """Reconstruct (n, d) float32 vectors from (n, m) codes."""
    m, k, s = codebooks.shape
    # gather per sub-space: codebooks[i, codes[:, i]]  -> (n, m, s)
    recon = jax.vmap(lambda cb, c: cb[c], in_axes=(0, 1), out_axes=1)(
        codebooks, codes.astype(jnp.int32))
    return recon.reshape(codes.shape[0], m * s)


@functools.partial(jax.jit, static_argnames=("normalize_inputs",))
def build_adc_lut(queries: Array, codebooks: Array,
                  normalize_inputs: bool = False) -> Array:
    """Per-query lookup tables: (Q, m, k) squared-L2 from query sub-vectors to
    every centroid.  ADC distance(code) = sum_i LUT[q, i, code[i]]."""
    if normalize_inputs:
        queries = normalize(queries)
    m, k, s = codebooks.shape
    q = queries.astype(jnp.float32).reshape(queries.shape[0], m, s)

    def per_sub(q_ms, cb):  # (Q, s), (k, s) -> (Q, k)
        return (jnp.sum(q_ms * q_ms, 1)[:, None] + jnp.sum(cb * cb, 1)[None, :]
                - 2.0 * q_ms @ cb.T)

    return jax.vmap(per_sub, in_axes=(1, 0), out_axes=1)(q, codebooks)


@jax.jit
def adc_distances(lut: Array, codes: Array) -> Array:
    """ADC scan: lut (Q, m, k) × codes (N, m) -> (Q, N) distances.

    Pure-jnp formulation (oracle); the Pallas kernel pq_adc implements the
    same contraction with the LUT pinned in VMEM.
    """
    c = codes.astype(jnp.int32)  # (N, m)

    def per_sub(lut_i, c_i):  # lut_i (Q, k), c_i (N,) -> (Q, N)
        return lut_i[:, c_i]

    g = jax.vmap(per_sub, in_axes=(1, 1))(lut, c)  # (m, Q, N)
    return jnp.sum(g, axis=0)


@functools.partial(jax.jit, static_argnames=("k",))
def adc_topk(lut: Array, codes: Array, k: int) -> Tuple[Array, Array]:
    d = adc_distances(lut, codes)
    neg_d, idx = jax.lax.top_k(-d, k)
    return -neg_d, idx.astype(jnp.int32)


class ProductQuantizer:
    """Stateful convenience wrapper (engine-facing)."""

    def __init__(self, config: PQConfig):
        self.config = config
        self.codebooks: Optional[Array] = None

    @property
    def is_trained(self) -> bool:
        return self.codebooks is not None

    def _norm(self) -> bool:
        return self.config.metric == "cosine"

    def train(self, vectors: Array, seed: int = 0) -> None:
        self.config.validate(vectors.shape[1])
        key = jax.random.PRNGKey(seed)
        self.codebooks = train_codebooks(
            key, vectors, self.config.m, self.config.k,
            iters=self.config.iters, normalize_inputs=self._norm())

    def encode(self, vectors: Array) -> Array:
        assert self.is_trained, "train() before encode()"
        return encode(vectors, self.codebooks, normalize_inputs=self._norm())

    def decode(self, codes: Array) -> Array:
        return decode(codes, self.codebooks)

    def search(self, codes: Array, queries: Array, k: int) -> Tuple[Array, Array]:
        lut = build_adc_lut(queries, self.codebooks, normalize_inputs=self._norm())
        return adc_topk(lut, codes, k)

    def compression_ratio(self, d: int, dtype_bytes: int = 4) -> float:
        code_bytes = self.config.m * (1 if self.config.k <= 256 else 2)
        return (d * dtype_bytes) / code_bytes

    # --- persistence hooks (checkpoint store uses these) ---
    def state_dict(self):
        return {"codebooks": np.asarray(self.codebooks)}

    def load_state_dict(self, state):
        self.codebooks = jnp.asarray(state["codebooks"])
