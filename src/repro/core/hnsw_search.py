"""Jittable HNSW search — the TPU-native adaptation (DESIGN.md §2).

The CPU algorithm's dynamic structures are re-expressed as fixed-shape tensor
ops so the whole search jits, vmaps over query batches, and shards:

  greedy upper-layer descent   -> ``lax.while_loop`` over a gathered (M,)
                                  neighbour row + masked argmin
  candidate min-heap / results -> one fused (ef,) candidate buffer maintained
                                  by ``lax.top_k`` over (ef + M0) merged rows
  visited hash-set             -> packed bitmask, ``ceil(N/32)`` uint32 words,
                                  updated with a scatter-add of unique bits
  per-neighbour distance calls -> one (M0, D) gather + one matvec per
                                  expansion (MXU/VPU work, not scalar chasing)

Every expansion touches exactly one candidate, so the loop trip count is
bounded (``max_iters``), giving XLA a fully static program.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hnsw_build import PAD, PackedHNSW

Array = jax.Array
INF = jnp.inf


class HNSWGraph(NamedTuple):
    """Device-resident packed graph (all jnp arrays; static meta travels
    separately as jit-static args)."""

    vectors: Array      # (N, D) float32, metric-preprocessed
    adj0: Array         # (N, M0) int32, PAD = -1
    upper_ids: Array    # (U,) int32 upper-slot -> global id
    upper_adj: Array    # (U, L_top, M) int32 upper-slot ids, PAD = -1
    entry_global: Array  # () int32
    entry_upper: Array   # () int32


def to_device(packed: PackedHNSW) -> Tuple[HNSWGraph, int, str]:
    """Returns (graph arrays, static max_level, static metric)."""
    g = HNSWGraph(
        vectors=jnp.asarray(packed.vectors, dtype=jnp.float32),
        adj0=jnp.asarray(packed.adj0),
        upper_ids=jnp.asarray(packed.upper_ids),
        upper_adj=jnp.asarray(packed.upper_adj),
        entry_global=jnp.asarray(packed.entry_global, dtype=jnp.int32),
        entry_upper=jnp.asarray(packed.entry_upper, dtype=jnp.int32),
    )
    metric = "l2" if packed.config.metric == "l2" else "dot"
    return g, int(packed.max_level), metric


def _dist_rows(q: Array, rows: Array, metric: str) -> Array:
    """q (D,) vs rows (M, D) -> (M,) raw scores (smaller == closer)."""
    if metric == "l2":
        d = rows - q[None, :]
        return jnp.sum(d * d, axis=-1)
    return -(rows @ q)  # dot / pre-normalized cosine


def _descend(q: Array, g: HNSWGraph, layer: int, cur: Array,
             metric: str) -> Array:
    """Greedy move-to-nearest at one upper layer; cur is an upper-slot id."""

    def cur_dist(slot):
        return _dist_rows(q, g.vectors[g.upper_ids[slot]][None, :], metric)[0]

    def cond(state):
        _, _, moved = state
        return moved

    def body(state):
        slot, d_cur, _ = state
        nbrs = g.upper_adj[slot, layer]              # (M,) upper-slot ids
        valid = nbrs != PAD
        safe = jnp.maximum(nbrs, 0)
        rows = g.vectors[g.upper_ids[safe]]          # (M, D) gather
        d = jnp.where(valid, _dist_rows(q, rows, metric), INF)
        j = jnp.argmin(d)
        better = d[j] < d_cur
        return (jnp.where(better, nbrs[j], slot),
                jnp.where(better, d[j], d_cur), better)

    slot, _, _ = jax.lax.while_loop(
        cond, body, (cur, cur_dist(cur), jnp.array(True)))
    return slot


def _beam_search_base(q: Array, g: HNSWGraph, ep_global: Array, ef: int,
                      max_iters: int, metric: str,
                      n_words: int) -> Tuple[Array, Array]:
    """Fixed-ef beam search on layer 0. Returns (dists (ef,), ids (ef,))."""
    m0 = g.adj0.shape[1]

    # init: buffer holds just the entry point
    cand_d = jnp.full((ef,), INF).at[0].set(
        _dist_rows(q, g.vectors[ep_global][None, :], metric)[0])
    cand_id = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(ep_global)
    expanded = jnp.zeros((ef,), dtype=bool)
    visited = jnp.zeros((n_words,), dtype=jnp.uint32).at[ep_global // 32].set(
        jnp.uint32(1) << (ep_global % 32).astype(jnp.uint32))

    def cond(state):
        cand_d, _, expanded, _, it = state
        frontier = jnp.any(~expanded & jnp.isfinite(cand_d))
        return frontier & (it < max_iters)

    def body(state):
        cand_d, cand_id, expanded, visited, it = state
        # pop nearest unexpanded candidate
        masked = jnp.where(~expanded, cand_d, INF)
        c = jnp.argmin(masked)
        expanded = expanded.at[c].set(True)
        node = cand_id[c]

        nbrs = g.adj0[node]                         # (M0,) global ids
        valid = nbrs != PAD
        safe = jnp.maximum(nbrs, 0)
        word = safe // 32
        bit = (safe % 32).astype(jnp.uint32)
        seen = (visited[word] >> bit) & jnp.uint32(1)
        fresh = valid & (seen == 0)
        # scatter-OR: bits are unique per (word,bit) among fresh neighbours
        # (adjacency rows are duplicate-free — graph invariant, tested) and
        # previously 0 (fresh-mask), so add == or.
        add_val = jnp.where(fresh, jnp.uint32(1) << bit, jnp.uint32(0))
        visited = visited.at[word].add(add_val)

        rows = g.vectors[safe]                      # (M0, D)
        d = jnp.where(fresh, _dist_rows(q, rows, metric), INF)
        new_id = jnp.where(fresh, nbrs, -1)

        merged_d = jnp.concatenate([cand_d, d])
        merged_id = jnp.concatenate([cand_id, new_id])
        merged_exp = jnp.concatenate([expanded, ~fresh])  # stale -> never expand

        neg_top, sel = jax.lax.top_k(-merged_d, ef)
        return (-neg_top, merged_id[sel], merged_exp[sel], visited, it + 1)

    state = (cand_d, cand_id, expanded, visited, jnp.array(0, jnp.int32))
    cand_d, cand_id, _, _, _ = jax.lax.while_loop(cond, body, state)
    return cand_d, cand_id


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "max_iters", "max_level", "metric"))
def search(g: HNSWGraph, queries: Array, *, k: int, ef: int,
           max_level: int, metric: str = "dot",
           max_iters: Optional[int] = None) -> Tuple[Array, Array]:
    """Batched HNSW search.

    Args:
      g: device graph from :func:`to_device`.
      queries: (Q, D) — pre-normalize for cosine (to_device stores the corpus
        normalized; use metric="dot").
      k: neighbours to return (k <= ef).
      ef: beam width.
      max_level: static top layer of the graph.
      metric: "dot" | "l2" (cosine == dot on normalized inputs).
      max_iters: expansion budget; default 4*ef.

    Returns:
      (distances (Q, k) ascending raw scores, ids (Q, k) int32; -1 = unfilled).
    """
    if max_iters is None:
        max_iters = 4 * ef
    if k > ef:
        raise ValueError(f"k={k} > ef={ef}")
    n = g.vectors.shape[0]
    n_words = (n + 31) // 32
    queries = queries.astype(jnp.float32)

    def one(q):
        slot = g.entry_upper
        for layer in range(max_level, 0, -1):       # static unroll, tiny
            slot = _descend(q, g, layer - 1, slot, metric)
        ep = jnp.where(jnp.asarray(max_level > 0),
                       g.upper_ids[slot], g.entry_global)
        d, ids = _beam_search_base(q, g, ep, ef, max_iters, metric, n_words)
        return d[:k], ids[:k]

    return jax.vmap(one)(queries)


def search_numpy_reference(packed: PackedHNSW, queries: np.ndarray, k: int,
                           ef: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host oracle mirroring the fixed-shape device algorithm (test parity)."""
    from .hnsw_build import make_dist_fn, preprocess_vectors

    metric = packed.config.metric
    vecs = packed.vectors
    dist = make_dist_fn(vecs, metric)
    q_all = preprocess_vectors(queries, metric)
    out_d = np.full((len(q_all), k), np.inf, dtype=np.float32)
    out_i = np.full((len(q_all), k), -1, dtype=np.int32)

    for qi, q in enumerate(q_all):
        # descent
        slot = packed.entry_upper
        for layer in range(packed.max_level, 0, -1):
            while True:
                nbrs = packed.upper_adj[slot, layer - 1]
                nbrs = nbrs[nbrs != PAD]
                if len(nbrs) == 0:
                    break
                d_cur = dist(q, np.array([packed.upper_ids[slot]], np.int64))[0]
                ds = dist(q, packed.upper_ids[nbrs].astype(np.int64))
                j = int(np.argmin(ds))
                if ds[j] < d_cur:
                    slot = int(nbrs[j])
                else:
                    break
        ep = int(packed.upper_ids[slot]) if packed.max_level > 0 \
            else packed.entry_global
        # beam
        cand_d = np.full((ef,), np.inf, np.float32)
        cand_i = np.full((ef,), -1, np.int64)
        expanded = np.zeros((ef,), bool)
        cand_d[0] = dist(q, np.array([ep], np.int64))[0]
        cand_i[0] = ep
        visited = {ep}
        for _ in range(4 * ef):
            masked = np.where(~expanded, cand_d, np.inf)
            c = int(np.argmin(masked))
            if not np.isfinite(masked[c]):
                break
            expanded[c] = True
            nbrs = packed.adj0[cand_i[c]]
            nbrs = [int(e) for e in nbrs if e != PAD and e not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            ds = dist(q, np.asarray(nbrs, np.int64))
            md = np.concatenate([cand_d, ds])
            mi = np.concatenate([cand_i, nbrs])
            me = np.concatenate([expanded, np.zeros(len(nbrs), bool)])
            sel = np.argsort(md, kind="stable")[:ef]
            cand_d, cand_i, expanded = md[sel], mi[sel], me[sel]
        out_d[qi] = cand_d[:k]
        out_i[qi] = cand_i[:k]
    return out_d, out_i


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean fraction of true k-NN recovered (ann-benchmarks style)."""
    hits = 0
    k = true_ids.shape[1]
    for f, t in zip(found_ids, true_ids):
        hits += len(set(int(x) for x in f[:k]) & set(int(x) for x in t))
    return hits / (len(true_ids) * k)
