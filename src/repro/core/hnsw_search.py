"""Jittable HNSW search — the TPU-native adaptation (DESIGN.md §2).

The CPU algorithm's dynamic structures are re-expressed as fixed-shape tensor
ops so the whole search jits, vmaps over query batches, and shards:

  greedy upper-layer descent   -> ``lax.while_loop`` over a gathered (M,)
                                  neighbour row + masked argmin
  candidate min-heap / results -> one fused (ef,) candidate buffer maintained
                                  by ``lax.top_k`` over (ef + B·M0) merged rows
  visited hash-set             -> packed bitmask, ``ceil(N/32)`` uint32 words,
                                  per-word OR-updated in a static B-step
                                  unrolled scatter sequence (each popped row's
                                  bits land before the next row's membership
                                  test, so neighbours shared across the block
                                  are visited exactly once)
  per-neighbour distance calls -> one fused (B·M0,) gather-distance block per
                                  iteration (kernels/beam_gather.py)

**Wide-beam traversal**: each layer-0 iteration pops the top-``B`` unexpanded
candidates (``expansion_width``, static), gathers their adjacency rows into
one (B·M0,) id block, evaluates every distance in a single fused contraction,
and merges into the ``ef`` buffer with one ``top_k``.  The while-loop trip
count — the sequential bottleneck, since vmapped queries step the loop until
the *slowest* query finishes — drops ~B×, while per-iteration arithmetic
becomes one big MXU-friendly block instead of B small ones.  ``B=1``
reproduces the classic single-pop traversal bit-for-bit.

Distance evaluation is pluggable per graph payload (``metric``):

  "l2" / "dot"  float traversal over ``g.vectors``        (beam_gather)
  "adc"         PQ code-domain: per-query LUT over (N, m) uint codes
                (beam_gather_adc) — ADC == squared-L2-to-reconstruction
  "hamming"     BQ code-domain: packed XOR+popcount over (N, W) uint32
                words (beam_gather_hamming) — monotone affine in -dot of
                the ±1 sign vectors

so quantized engines traverse in code domain; upper-layer descent (a handful
of scalar steps) keeps using the float proxy vectors.  The loop trip count is
bounded (``max_iters``), giving XLA a fully static program; ``with_iters``
returns the per-query trip counter for benchmarks/observability.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .hnsw_build import PAD, PackedHNSW, make_dist_fn, preprocess_vectors

Array = jax.Array
INF = jnp.inf

DEFAULT_EXPANSION_WIDTH = 4


class HNSWGraph(NamedTuple):
    """Device-resident packed graph (all jnp arrays; static meta travels
    separately as jit-static args)."""

    vectors: Array      # (N, D) float32, metric-preprocessed
    adj0: Array         # (N, M0) int32, PAD = -1
    upper_ids: Array    # (U,) int32 upper-slot -> global id
    upper_adj: Array    # (U, L_top, M) int32 upper-slot ids, PAD = -1
    entry_global: Array  # () int32
    entry_upper: Array   # () int32
    codes: Optional[Array] = None  # (N, m) PQ codes / (N, W) packed BQ words


def to_device(packed: PackedHNSW,
              codes: Optional[np.ndarray] = None) -> Tuple[HNSWGraph, int, str]:
    """Returns (graph arrays, static max_level, static metric).

    ``codes`` optionally ships the quantized corpus (PQ uint codes or packed
    BQ uint32 words) alongside the float proxy vectors, enabling the
    code-domain traversal modes ("adc"/"hamming") of :func:`search`.
    """
    g = HNSWGraph(
        vectors=jnp.asarray(packed.vectors, dtype=jnp.float32),
        adj0=jnp.asarray(packed.adj0),
        upper_ids=jnp.asarray(packed.upper_ids),
        upper_adj=jnp.asarray(packed.upper_adj),
        entry_global=jnp.asarray(packed.entry_global, dtype=jnp.int32),
        entry_upper=jnp.asarray(packed.entry_upper, dtype=jnp.int32),
        codes=None if codes is None else jnp.asarray(codes),
    )
    metric = "l2" if packed.config.metric == "l2" else "dot"
    return g, int(packed.max_level), metric


def _dist_rows(q: Array, rows: Array, metric: str) -> Array:
    """q (D,) vs rows (M, D) -> (M,) raw scores (smaller == closer)."""
    if metric == "l2":
        d = rows - q[None, :]
        return jnp.sum(d * d, axis=-1)
    return -(rows @ q)  # dot / pre-normalized cosine


def _descend(q: Array, g: HNSWGraph, layer: int, cur: Array,
             metric: str) -> Array:
    """Greedy move-to-nearest at one upper layer; cur is an upper-slot id."""

    def cur_dist(slot):
        return _dist_rows(q, g.vectors[g.upper_ids[slot]][None, :], metric)[0]

    def cond(state):
        _, _, moved = state
        return moved

    def body(state):
        slot, d_cur, _ = state
        nbrs = g.upper_adj[slot, layer]              # (M,) upper-slot ids
        valid = nbrs != PAD
        safe = jnp.maximum(nbrs, 0)
        rows = g.vectors[g.upper_ids[safe]]          # (M, D) gather
        d = jnp.where(valid, _dist_rows(q, rows, metric), INF)
        j = jnp.argmin(d)
        better = d[j] < d_cur
        return (jnp.where(better, nbrs[j], slot),
                jnp.where(better, d[j], d_cur), better)

    slot, _, _ = jax.lax.while_loop(
        cond, body, (cur, cur_dist(cur), jnp.array(True)))
    return slot


def _make_block_dist(g: HNSWGraph, q: Array, q_code: Optional[Array],
                     metric: str):
    """The per-query fused distance evaluator: (L,) safe ids -> (L,) f32."""
    if metric == "adc":
        return lambda ids: ops.beam_gather_adc(q_code, ids, g.codes)
    if metric == "hamming":
        return lambda ids: ops.beam_gather_hamming(
            q_code, ids, g.codes).astype(jnp.float32)
    return lambda ids: ops.beam_gather_distances(q, ids, g.vectors,
                                                 mode=metric)


def _beam_search_base(g: HNSWGraph, ep_global: Array, ef: int, width: int,
                      max_iters: int, n_words: int,
                      block_dist) -> Tuple[Array, Array, Array]:
    """Fixed-ef wide-beam search on layer 0.

    Returns (dists (ef,), ids (ef,), iterations ()).
    """
    m0 = g.adj0.shape[1]
    l = width * m0

    # init: buffer holds just the entry point
    cand_d = jnp.full((ef,), INF).at[0].set(block_dist(ep_global[None])[0])
    cand_id = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(ep_global)
    expanded = jnp.zeros((ef,), dtype=bool)
    visited = jnp.zeros((n_words,), dtype=jnp.uint32).at[ep_global // 32].set(
        jnp.uint32(1) << (ep_global % 32).astype(jnp.uint32))

    def cond(state):
        cand_d, _, expanded, _, it = state
        frontier = jnp.any(~expanded & jnp.isfinite(cand_d))
        return frontier & (it < max_iters)

    def body(state):
        cand_d, cand_id, expanded, visited, it = state
        # pop the top-B nearest unexpanded candidates in one shot
        masked = jnp.where(~expanded, cand_d, INF)
        neg_d, sel = jax.lax.top_k(-masked, width)
        pop_ok = jnp.isfinite(neg_d)
        # surplus sel slots (pop_ok False) are INF: either empty (-1 id,
        # marking them expanded is moot) or already-expanded (idempotent)
        expanded = expanded.at[sel].set(True)
        nodes = jnp.where(pop_ok, cand_id[sel], PAD)        # (B,)

        adj_rows = g.adj0[jnp.maximum(nodes, 0)]            # (B, M0)
        adj_rows = jnp.where(pop_ok[:, None], adj_rows, PAD)
        # per-word OR-reduction of the visited bits, unrolled over the B
        # popped rows (B is static and small): each row's bits land before
        # the next row's membership test, so a neighbour shared by several
        # popped candidates is fresh exactly once.  Within one row bits are
        # unique (adjacency rows are duplicate-free — graph invariant,
        # tested) and previously 0 by the fresh mask, so add == or.
        fresh_rows = []
        for b in range(width):                              # static unroll
            nbrs_b = adj_rows[b]
            valid_b = nbrs_b != PAD
            safe_b = jnp.maximum(nbrs_b, 0)
            word_b = safe_b // 32
            bit_b = (safe_b % 32).astype(jnp.uint32)
            seen_b = (visited[word_b] >> bit_b) & jnp.uint32(1)
            fresh_b = valid_b & (seen_b == 0)
            add_b = jnp.where(fresh_b, jnp.uint32(1) << bit_b, jnp.uint32(0))
            visited = visited.at[word_b].add(add_b)
            fresh_rows.append(fresh_b)
        nbrs = adj_rows.reshape(l)
        fresh = jnp.stack(fresh_rows).reshape(l)
        safe = jnp.maximum(nbrs, 0)

        d = jnp.where(fresh, block_dist(safe), INF)         # (B·M0,) fused
        new_id = jnp.where(fresh, nbrs, -1)

        merged_d = jnp.concatenate([cand_d, d])
        merged_id = jnp.concatenate([cand_id, new_id])
        merged_exp = jnp.concatenate([expanded, ~fresh])  # stale -> never expand

        neg_top, keep = jax.lax.top_k(-merged_d, ef)
        return (-neg_top, merged_id[keep], merged_exp[keep], visited, it + 1)

    state = (cand_d, cand_id, expanded, visited, jnp.array(0, jnp.int32))
    cand_d, cand_id, _, _, iters = jax.lax.while_loop(cond, body, state)
    return cand_d, cand_id, iters


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "max_iters", "max_level", "metric",
                     "expansion_width", "with_iters"))
def search(g: HNSWGraph, queries: Array, *, k: int, ef: int,
           max_level: int, metric: str = "dot",
           expansion_width: int = DEFAULT_EXPANSION_WIDTH,
           max_iters: Optional[int] = None,
           q_codes: Optional[Array] = None,
           with_iters: bool = False):
    """Batched HNSW search.

    Args:
      g: device graph from :func:`to_device`.
      queries: (Q, D) — pre-normalize for cosine (to_device stores the corpus
        normalized; use metric="dot").  For code-domain metrics this is the
        float proxy used by the upper-layer descent (PQ: the normalized
        query; BQ: the ±1 sign vector).
      k: neighbours to return (k <= ef).
      ef: beam width (result-buffer size).
      max_level: static top layer of the graph.
      metric: "dot" | "l2" (cosine == dot on normalized inputs), or the
        code-domain modes "adc" / "hamming" (require ``g.codes`` +
        ``q_codes``).
      expansion_width: candidates popped (and adjacency rows fused) per
        layer-0 iteration; 1 == classic single-pop traversal.
      max_iters: expansion-iteration budget; default 4*ef.
      q_codes: per-query code-domain payload — (Q, m, k) ADC LUTs for
        metric="adc", (Q, W) packed uint32 query codes for "hamming".
      with_iters: additionally return the (Q,) int32 layer-0 loop-trip
        counters (the benchmark/observability hook).

    Returns:
      (distances (Q, k) ascending raw scores, ids (Q, k) int32; -1 = unfilled)
      [, iterations (Q,) if with_iters].
    """
    if max_iters is None:
        max_iters = 4 * ef
    if k > ef:
        raise ValueError(f"k={k} > ef={ef}")
    if metric in ("adc", "hamming") and (g.codes is None or q_codes is None):
        raise ValueError(f"metric {metric!r} needs g.codes and q_codes")
    # a beam can't pop more candidates than the buffer holds (tiny corpora)
    width = max(1, min(int(expansion_width), ef))
    descent_metric = {"adc": "l2", "hamming": "dot"}.get(metric, metric)
    n = g.vectors.shape[0]
    n_words = (n + 31) // 32
    queries = queries.astype(jnp.float32)

    def one(q, qc):
        slot = g.entry_upper
        for layer in range(max_level, 0, -1):       # static unroll, tiny
            slot = _descend(q, g, layer - 1, slot, descent_metric)
        ep = jnp.where(jnp.asarray(max_level > 0),
                       g.upper_ids[slot], g.entry_global)
        block_dist = _make_block_dist(g, q, qc, metric)
        d, ids, iters = _beam_search_base(g, ep, ef, width, max_iters,
                                          n_words, block_dist)
        return d[:k], ids[:k], iters

    if q_codes is None:
        d, ids, iters = jax.vmap(lambda q: one(q, None))(queries)
    else:
        d, ids, iters = jax.vmap(one)(queries, q_codes)
    return (d, ids, iters) if with_iters else (d, ids)


def search_numpy_reference(packed: PackedHNSW, queries: np.ndarray, k: int,
                           ef: int,
                           expansion_width: int = DEFAULT_EXPANSION_WIDTH,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Host oracle mirroring the fixed-shape device algorithm (test parity),
    width-aware: pops ``expansion_width`` candidates per iteration, expands
    their neighbour rows as one first-occurrence-deduplicated block, and
    merges with a single stable top-ef selection — the same visit order and
    tie-breaking as the device wide-beam loop."""
    metric = packed.config.metric
    vecs = packed.vectors
    dist = make_dist_fn(vecs, metric)
    q_all = preprocess_vectors(queries, metric)
    width = max(1, int(expansion_width))
    out_d = np.full((len(q_all), k), np.inf, dtype=np.float32)
    out_i = np.full((len(q_all), k), -1, dtype=np.int32)

    width = min(width, ef)                     # mirror the device clamp
    for qi, q in enumerate(q_all):
        # descent
        slot = packed.entry_upper
        for layer in range(packed.max_level, 0, -1):
            while True:
                nbrs = packed.upper_adj[slot, layer - 1]
                nbrs = nbrs[nbrs != PAD]
                if len(nbrs) == 0:
                    break
                d_cur = dist(q, np.array([packed.upper_ids[slot]], np.int64))[0]
                ds = dist(q, packed.upper_ids[nbrs].astype(np.int64))
                j = int(np.argmin(ds))
                if ds[j] < d_cur:
                    slot = int(nbrs[j])
                else:
                    break
        ep = int(packed.upper_ids[slot]) if packed.max_level > 0 \
            else packed.entry_global
        # wide beam
        cand_d = np.full((ef,), np.inf, np.float32)
        cand_i = np.full((ef,), -1, np.int64)
        expanded = np.zeros((ef,), bool)
        cand_d[0] = dist(q, np.array([ep], np.int64))[0]
        cand_i[0] = ep
        visited = {ep}
        for _ in range(4 * ef):
            masked = np.where(~expanded, cand_d, np.inf)
            pops = [int(c) for c in np.argsort(masked, kind="stable")[:width]
                    if np.isfinite(masked[c])]
            if not pops:
                break
            block: list = []
            for c in pops:
                expanded[c] = True
                nbrs = packed.adj0[cand_i[c]]
                # sequential visited update == the device block's
                # first-occurrence dedup in flattened row-major order
                fresh = [int(e) for e in nbrs
                         if e != PAD and e not in visited]
                visited.update(fresh)
                block.extend(fresh)
            if not block:
                continue
            ds = dist(q, np.asarray(block, np.int64))
            md = np.concatenate([cand_d, ds])
            mi = np.concatenate([cand_i, block])
            me = np.concatenate([expanded, np.zeros(len(block), bool)])
            keep = np.argsort(md, kind="stable")[:ef]
            cand_d, cand_i, expanded = md[keep], mi[keep], me[keep]
        out_d[qi] = cand_d[:k]
        out_i[qi] = cand_i[:k]
    return out_d, out_i


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean fraction of true k-NN recovered (ann-benchmarks style).

    Vectorized: true ids are unique per row, so counting, for each true id,
    whether it appears among the first k found ids equals the per-row set
    intersection size — no Python loop over Q."""
    found = np.asarray(found_ids)
    true = np.asarray(true_ids)
    k = true.shape[1]
    hits = (true[:, :, None] == found[:, None, :k]).any(axis=2).sum()
    return float(hits) / (true.shape[0] * k)
