"""Quantixar core: the paper's contribution as composable JAX modules."""

from .distances import (available_metrics, brute_force_topk, get_metric,
                        normalize, pairwise_cosine, pairwise_dot,
                        pairwise_hamming, pairwise_l2)
from .engine import EngineConfig, QuantixarEngine
from .flat import FlatIndex, flat_search, merge_topk
from .hnsw_build import HNSWConfig, PackedHNSW, build, bulk_build, exact_knn
from .hnsw_bulk import bulk_build_device
from .hnsw_search import HNSWGraph, recall_at_k, search, to_device
from .metadata import And, Filter, MetadataStore, Not, Or, Predicate
from .bq import BinaryQuantizer, BQConfig
from .ivf import IVFConfig, IVFIndex
from .pq import PQConfig, ProductQuantizer
from .segment import DeltaSegment, SealPolicy, merge_candidates

__all__ = [
    "available_metrics", "brute_force_topk", "get_metric", "normalize",
    "pairwise_cosine", "pairwise_dot", "pairwise_hamming", "pairwise_l2",
    "EngineConfig", "QuantixarEngine", "FlatIndex", "flat_search",
    "merge_topk", "HNSWConfig", "PackedHNSW", "build", "bulk_build",
    "exact_knn", "HNSWGraph", "recall_at_k", "search", "to_device",
    "And", "Filter", "MetadataStore", "Not", "Or", "Predicate",
    "BinaryQuantizer", "BQConfig", "IVFConfig", "IVFIndex",
    "PQConfig", "ProductQuantizer",
    "DeltaSegment", "SealPolicy", "merge_candidates",
]
