"""QuantixarEngine — config-driven composition of index × quantization × metric
(paper §III: Query Processing + Quantization + Indexing modules).

Composition matrix (all user-configurable, as the paper emphasises):

  index ∈ {flat, hnsw}   ×   quantization ∈ {none, pq, bq}   ×   metric
  + optional exact-rescore pass for quantized first-pass candidates
  + MEVS: predicate filter -> mask threaded into the search

Quantized HNSW traversal uses the *exact ADC identity*: the ADC distance of a
PQ code equals the squared-L2 distance to its reconstruction, and packed-code
Hamming distance is monotone in the dot product of ±1 sign vectors.  The
device graph therefore stores the reconstruction (PQ) or sign (BQ) vectors,
giving traversal orderings identical to code-domain arithmetic.  On a real TPU
deployment the same traversal gathers codes and evaluates the Pallas ADC /
Hamming kernels (see kernels/); numerics are the same by construction.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import bq as bq_mod
from . import pq as pq_mod
from .distances import get_metric
from .flat import flat_search
from .hnsw_build import HNSWConfig, PackedHNSW, build, bulk_build, preprocess_vectors
from .ivf import IVFConfig, IVFIndex
from .hnsw_search import to_device, search as hnsw_search
from .metadata import Filter, MetadataStore


@dataclasses.dataclass
class EngineConfig:
    dim: int
    metric: str = "cosine"               # default per paper §I
    index: str = "hnsw"                  # "hnsw" | "flat" | "ivf"
    quantization: str = "none"           # "none" | "pq" | "bq"
    pq: pq_mod.PQConfig = dataclasses.field(default_factory=pq_mod.PQConfig)
    bq: bq_mod.BQConfig = dataclasses.field(default_factory=bq_mod.BQConfig)
    hnsw: HNSWConfig = dataclasses.field(default_factory=HNSWConfig)
    ivf: IVFConfig = dataclasses.field(default_factory=IVFConfig)
    builder: str = "incremental"         # "incremental" (faithful) | "bulk"
    ef_search: int = 64
    rescore: bool = True                 # exact second pass for quantized search
    rescore_multiplier: int = 4          # first pass fetches k * multiplier
    filter_flat_threshold: float = 0.10  # MEVS: selectivity below which we
    #                                      scan the filtered subset exactly

    def __post_init__(self):
        if self.index not in ("hnsw", "flat", "ivf"):
            raise ValueError(f"index {self.index!r}")
        self.ivf = dataclasses.replace(self.ivf, metric=(
            "cosine" if self.metric == "cosine" else "l2"))
        if self.quantization not in ("none", "pq", "bq"):
            raise ValueError(f"quantization {self.quantization!r}")
        # HNSW metric follows the engine metric
        self.hnsw = dataclasses.replace(self.hnsw, metric=self.metric)


class QuantixarEngine:
    """The paper's "Quantixar Engine": entities in, similarity queries out."""

    def __init__(self, config: EngineConfig):
        self.config = config
        self._vectors: List[np.ndarray] = []      # raw entity vectors (chunks)
        self._n = 0
        self.metadata = MetadataStore()
        self._pq: Optional[pq_mod.ProductQuantizer] = None
        self._bq: Optional[bq_mod.BinaryQuantizer] = None
        self._codes: Optional[np.ndarray] = None   # pq codes or bq packed words
        self._packed: Optional[PackedHNSW] = None
        self._device_graph = None                  # (HNSWGraph, max_level, metric)
        self._ivf: Optional[IVFIndex] = None
        self._dirty = True
        self.build_seconds: float = 0.0
        self.insert_seconds: float = 0.0

    # ------------------------------------------------------------------ data
    def __len__(self) -> int:
        return self._n

    @property
    def vectors(self) -> np.ndarray:
        if not self._vectors:
            return np.zeros((0, self.config.dim), dtype=np.float32)
        if len(self._vectors) > 1:
            self._vectors = [np.concatenate(self._vectors, axis=0)]
        return self._vectors[0]

    def add(self, vectors: np.ndarray,
            metadata: Optional[Sequence[Optional[Dict[str, Any]]]] = None) -> None:
        """Insert a batch of entities (vector + optional metadata record)."""
        t0 = time.perf_counter()
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim != 2 or vectors.shape[1] != self.config.dim:
            raise ValueError(
                f"expected (n, {self.config.dim}) vectors, got {vectors.shape}")
        if metadata is None:
            metadata = [None] * len(vectors)
        if len(metadata) != len(vectors):
            raise ValueError("metadata length mismatch")
        self._vectors.append(vectors)
        self._n += len(vectors)
        self.metadata.append_batch(metadata)
        self._dirty = True
        self.insert_seconds += time.perf_counter() - t0

    # ----------------------------------------------------------------- build
    def build(self, seed: int = 0) -> None:
        """Train quantizers + build the index over everything inserted so far."""
        t0 = time.perf_counter()
        cfg = self.config
        raw = self.vectors
        if len(raw) == 0:
            raise RuntimeError("nothing to build: add() vectors first")

        if cfg.quantization == "pq":
            self._pq = pq_mod.ProductQuantizer(
                dataclasses.replace(cfg.pq, metric=(
                    "cosine" if cfg.metric == "cosine" else "l2")))
            self._pq.train(jnp.asarray(raw), seed=seed)
            self._codes = np.asarray(self._pq.encode(jnp.asarray(raw)))
        elif cfg.quantization == "bq":
            self._bq = bq_mod.BinaryQuantizer(cfg.bq)
            self._bq.train(jnp.asarray(raw), seed=seed)
            self._codes = np.asarray(self._bq.encode(jnp.asarray(raw)))
        else:
            self._codes = None

        if cfg.index == "hnsw":
            eff, eff_metric = self._effective_vectors()
            hnsw_cfg = dataclasses.replace(cfg.hnsw, metric=eff_metric)
            builder = bulk_build if cfg.builder == "bulk" else build
            self._packed = builder(eff, hnsw_cfg)
            self._device_graph = to_device(self._packed)
        elif cfg.index == "ivf":
            # IVF-PQ scans probed lists over reconstructions (the ADC
            # identity, as in the quantized-HNSW path).  BQ's ±1 sign vectors
            # live in code space (bits ≠ dim), so IVF+BQ probes and scans
            # raw vectors — BQ then only compresses the stored codes.
            if cfg.quantization == "pq":
                eff, eff_metric = self._effective_vectors()
            else:
                eff, eff_metric = raw, cfg.metric
            self._ivf = IVFIndex(dataclasses.replace(
                cfg.ivf, metric="l2" if eff_metric != "cosine" else "cosine"))
            self._ivf.train(jnp.asarray(raw), seed=seed)
            self._ivf.build_lists(jnp.asarray(raw))
            self._ivf_effective = eff
        else:
            self._packed = None
            self._device_graph = None
        self._dirty = False
        self.build_seconds = time.perf_counter() - t0

    def _effective_vectors(self) -> Tuple[np.ndarray, str]:
        """Vectors the graph traverses + the traversal metric (see module doc)."""
        cfg = self.config
        raw = self.vectors
        if cfg.quantization == "pq":
            recon = np.asarray(self._pq.decode(jnp.asarray(self._codes)))
            # ADC == L2-to-reconstruction (exact identity); cosine inputs were
            # normalized inside the quantizer already.
            return recon, "l2"
        if cfg.quantization == "bq":
            signs = np.asarray(bq_mod.unpack_bits(
                jnp.asarray(self._codes), cfg.bq.bits), dtype=np.float32)
            return signs * 2.0 - 1.0, "dot"   # hamming ~ -dot of ±1 vectors
        return raw, cfg.metric

    # ---------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: int,
               flt: Optional[Filter] = None,
               ef: Optional[int] = None,
               mask: Optional[np.ndarray] = None,
               rescore: Optional[bool] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k similarity search (Vector Query / MEVS).

        `mask` is an optional precomputed (N,) bool row mask (e.g. the API
        layer's tombstone liveness mask) AND-ed with the metadata filter.
        `rescore` overrides the config's exact-rescore setting per query.

        Returns (distances (Q,k) in the engine metric, ids (Q,k); -1 = none).
        """
        if self._dirty:
            self.build()
        cfg = self.config
        queries = np.asarray(queries, dtype=np.float32)
        if queries.ndim == 1:
            queries = queries[None, :]
        ef = ef or max(cfg.ef_search, k)
        flt_mask = self.metadata.evaluate(flt) if flt is not None else None
        if mask is not None:
            mask = np.asarray(mask, dtype=bool)
            mask = flt_mask & mask if flt_mask is not None else mask
        else:
            mask = flt_mask
        do_rescore = cfg.rescore if rescore is None else rescore
        do_rescore = do_rescore and cfg.quantization != "none"

        fetch = k * cfg.rescore_multiplier if do_rescore else k

        if cfg.index == "flat" or self._route_to_flat(mask):
            d, ids = self._flat_pass(queries, fetch, mask)
        elif cfg.index == "ivf":
            d, ids = self._ivf_pass(queries, fetch, mask)
        else:
            d, ids = self._hnsw_pass(queries, fetch, ef, mask)

        if do_rescore:
            d, ids = self._rescore(queries, ids, k, mask=mask)
        else:
            d, ids = d[:, :k], ids[:, :k]
        # contract: +inf slots (masked-out / padded) never expose a row id
        return d, np.where(np.isfinite(d), ids, -1)

    def _route_to_flat(self, mask: Optional[np.ndarray]) -> bool:
        """MEVS routing (paper: filter first, then search the subset): at low
        selectivity an exact masked scan is both faster and exact."""
        if mask is None:
            return False
        sel = mask.mean() if len(mask) else 0.0
        return sel <= self.config.filter_flat_threshold

    def _flat_pass(self, queries, k, mask):
        cfg = self.config
        mask_j = None if mask is None else jnp.asarray(mask)
        if cfg.quantization == "pq":
            lut = pq_mod.build_adc_lut(
                jnp.asarray(queries), self._pq.codebooks,
                normalize_inputs=cfg.metric == "cosine")
            d = pq_mod.adc_distances(lut, jnp.asarray(self._codes))
            if mask_j is not None:
                d = jnp.where(mask_j[None, :], d, jnp.inf)
            neg_d, ids = jnp.array(-d), None
            import jax
            neg_top, idx = jax.lax.top_k(neg_d, min(k, d.shape[1]))
            return np.asarray(-neg_top), np.asarray(idx, dtype=np.int32)
        if cfg.quantization == "bq":
            q_codes = self._bq.encode(jnp.asarray(queries))
            d = bq_mod.hamming_distances(q_codes, jnp.asarray(self._codes))
            d = d.astype(jnp.float32)
            if mask_j is not None:
                d = jnp.where(mask_j[None, :], d, jnp.inf)
            import jax
            neg_top, idx = jax.lax.top_k(-d, min(k, d.shape[1]))
            return np.asarray(-neg_top), np.asarray(idx, dtype=np.int32)
        d, ids = flat_search(jnp.asarray(queries), jnp.asarray(self.vectors),
                             min(k, self._n), metric=cfg.metric, mask=mask_j)
        return np.asarray(d), np.asarray(ids)

    def _hnsw_pass(self, queries, k, ef, mask):
        cfg = self.config
        g, max_level, metric = self._device_graph
        ef_eff = max(ef, k)
        if mask is not None:
            ef_eff = min(max(ef_eff * 2, k * 4), self._n)
        q = queries
        if metric == "dot" and cfg.quantization == "none":
            q = preprocess_vectors(queries, cfg.metric)
        elif cfg.quantization == "bq":
            signs = np.asarray(bq_mod.unpack_bits(
                self._bq.encode(jnp.asarray(queries)), cfg.bq.bits),
                dtype=np.float32)
            q = signs * 2.0 - 1.0
        elif cfg.quantization == "pq" and cfg.metric == "cosine":
            q = preprocess_vectors(queries, "cosine")
        d, ids = hnsw_search(g, jnp.asarray(q), k=min(ef_eff, self._n),
                             ef=min(ef_eff, self._n), max_level=max_level,
                             metric=metric)
        d, ids = np.asarray(d), np.asarray(ids)
        if mask is not None:
            allowed = np.concatenate([mask, [False]])  # -1 -> False
            ok = allowed[ids]
            d = np.where(ok, d, np.inf)
            order = np.argsort(d, axis=1, kind="stable")
            d = np.take_along_axis(d, order, axis=1)
            ids = np.where(np.take_along_axis(ok, order, axis=1),
                           np.take_along_axis(ids, order, axis=1), -1)
            # top-up from exact masked scan if the beam under-delivered
            if (ids[:, :k] == -1).any():
                return self._flat_pass(queries, k, mask)
        return d[:, :k], ids[:, :k]

    def _ivf_pass(self, queries, k, mask):
        d, ids = self._ivf.search(jnp.asarray(self._ivf_effective),
                                  jnp.asarray(queries), k)
        d, ids = np.asarray(d), np.asarray(ids)
        if mask is not None:
            allowed = np.concatenate([mask, [False]])
            ok = allowed[ids]
            d = np.where(ok, d, np.inf)
            order = np.argsort(d, axis=1, kind="stable")
            d = np.take_along_axis(d, order, axis=1)
            ids = np.where(np.take_along_axis(ok, order, axis=1),
                           np.take_along_axis(ids, order, axis=1), -1)
            if (ids[:, : min(k, ids.shape[1])] == -1).any():
                return self._flat_pass(queries, k, mask)
        return d[:, :k], ids[:, :k]

    def _rescore(self, queries, cand_ids, k, mask=None):
        """Exact re-ranking of quantized first-pass candidates (paper's
        optional precision knob).  The row mask must be re-applied here:
        exact distances would otherwise resurrect masked-out candidates that
        the first pass only demoted to +inf."""
        pair = get_metric(self.config.metric)
        raw = self.vectors
        safe = np.maximum(cand_ids, 0)
        cand_vecs = raw[safe]                      # (Q, k', D)
        d = np.stack([
            np.asarray(pair(jnp.asarray(queries[i: i + 1]),
                            jnp.asarray(cand_vecs[i])))[0]
            for i in range(len(queries))])
        ok = cand_ids >= 0
        if mask is not None:
            ok &= mask[safe]
        d = np.where(ok, d, np.inf)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        d = np.take_along_axis(d, order, axis=1)
        ids = np.take_along_axis(cand_ids, order, axis=1)
        return d, np.where(np.isfinite(d), ids, -1)

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, Any]:
        state: Dict[str, Any] = {
            "vectors": self.vectors,
            "n": np.array([self._n], dtype=np.int64),
            # rows added after the last build() are only in `vectors`; the
            # loader must rebuild rather than trust the serialized index
            "dirty": np.array([self._dirty]),
        }
        if self._codes is not None:
            state["codes"] = self._codes
        if self._pq is not None:
            state.update({f"pq.{k}": v for k, v in self._pq.state_dict().items()})
        if self._bq is not None:
            state.update({f"bq.{k}": v for k, v in self._bq.state_dict().items()})
        if self._packed is not None:
            state.update({f"hnsw.{k}": v
                          for k, v in self._packed.state_dict().items()})
        if self._ivf is not None:
            state.update({f"ivf.{k}": v
                          for k, v in self._ivf.state_dict().items()})
        state.update({f"meta.{k}": v
                      for k, v in self.metadata.state_dict().items()})
        return state

    @classmethod
    def from_state_dict(cls, config: EngineConfig,
                        state: Dict[str, Any]) -> "QuantixarEngine":
        eng = cls(config)
        eng._vectors = [np.asarray(state["vectors"], dtype=np.float32)]
        eng._n = int(state["n"][0])
        eng.metadata = MetadataStore.from_state_dict(
            {k[5:]: v for k, v in state.items() if k.startswith("meta.")})
        if "codes" in state:
            eng._codes = np.asarray(state["codes"])
        pq_state = {k[3:]: v for k, v in state.items() if k.startswith("pq.")}
        if pq_state:
            eng._pq = pq_mod.ProductQuantizer(dataclasses.replace(
                config.pq, metric="cosine" if config.metric == "cosine" else "l2"))
            eng._pq.load_state_dict(pq_state)
        bq_state = {k[3:]: v for k, v in state.items() if k.startswith("bq.")}
        if bq_state:
            eng._bq = bq_mod.BinaryQuantizer(config.bq)
            eng._bq.load_state_dict(bq_state)
        ivf_state = {k[4:]: v for k, v in state.items()
                     if k.startswith("ivf.")}
        if ivf_state:
            eng._ivf = IVFIndex(config.ivf)
            eng._ivf.load_state_dict(ivf_state)
            eng._ivf_effective, _ = eng._effective_vectors()
            eng._dirty = False
        hnsw_state = {k[5:]: v for k, v in state.items()
                      if k.startswith("hnsw.")}
        if hnsw_state:
            eff_metric = ("l2" if config.quantization == "pq" else
                          "dot" if config.quantization == "bq" else config.metric)
            eng._packed = PackedHNSW.from_state_dict(
                hnsw_state, dataclasses.replace(config.hnsw, metric=eff_metric))
            eng._device_graph = to_device(eng._packed)
            eng._dirty = False
        elif config.index == "flat" and eng._n:
            eng._dirty = False
        if "dirty" in state and bool(state["dirty"][0]):
            eng._dirty = True
        return eng

    def stats(self) -> Dict[str, Any]:
        out = {"n": self._n, "dim": self.config.dim,
               "index": self.config.index,
               "quantization": self.config.quantization,
               "metric": self.config.metric,
               "build_seconds": self.build_seconds,
               "insert_seconds": self.insert_seconds}
        if self._packed is not None:
            out.update(self._packed.degree_stats())
        if self._pq is not None:
            out["compression"] = self._pq.compression_ratio(self.config.dim)
        if self._bq is not None:
            out["compression"] = self._bq.compression_ratio(self.config.dim)
        return out
