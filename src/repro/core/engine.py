"""QuantixarEngine — config-driven composition of index × quantization × metric
(paper §III: Query Processing + Quantization + Indexing modules).

Composition matrix (all user-configurable, as the paper emphasises):

  index ∈ {flat, hnsw}   ×   quantization ∈ {none, pq, bq}   ×   metric
  + optional exact-rescore pass for quantized first-pass candidates
  + MEVS: predicate filter -> mask threaded into the search

Quantized HNSW traversal uses the *exact ADC identity*: the ADC distance of a
PQ code equals the squared-L2 distance to its reconstruction, and packed-code
Hamming distance is monotone in the dot product of ±1 sign vectors.  The
device graph therefore stores the reconstruction (PQ) or sign (BQ) vectors,
giving traversal orderings identical to code-domain arithmetic.  On a real TPU
deployment the same traversal gathers codes and evaluates the Pallas ADC /
Hamming kernels (see kernels/); numerics are the same by construction.

Segmented write path (see segment.py): after the first `build()`, inserts
land in a mutable **delta segment** — encode-only against the trained
codebooks, exact flat scan at query time — while the **sealed segment**
keeps its quantizers and graph.  `search()` fans out over sealed + delta and
merges top-k in the sealed pass's distance space; `seal()` folds the delta
into a new sealed segment (graph rebuild, no quantizer retraining) on the
`SealPolicy` schedule instead of billing an O(N) rebuild to one query.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bq as bq_mod
from . import pq as pq_mod
from .distances import get_metric
from .executor import AnnParams
from .flat import flat_search
from .hnsw_build import (HNSWConfig, PackedHNSW, ProgressFn, build,
                         bulk_build, preprocess_vectors)
from .hnsw_bulk import bulk_build_device
from .ivf import IVFConfig, IVFIndex
from .hnsw_search import to_device, search as hnsw_search
from .metadata import Filter, MetadataStore
from .segment import (ChunkedArray, DeltaSegment, SealPolicy,
                      merge_candidates)


@dataclasses.dataclass
class EngineConfig:
    dim: int
    metric: str = "cosine"               # default per paper §I
    index: str = "hnsw"                  # "hnsw" | "flat" | "ivf"
    quantization: str = "none"           # "none" | "pq" | "bq"
    pq: pq_mod.PQConfig = dataclasses.field(default_factory=pq_mod.PQConfig)
    bq: bq_mod.BQConfig = dataclasses.field(default_factory=bq_mod.BQConfig)
    hnsw: HNSWConfig = dataclasses.field(default_factory=HNSWConfig)
    ivf: IVFConfig = dataclasses.field(default_factory=IVFConfig)
    # "incremental" (faithful one-at-a-time inserts) | "bulk" (device-
    # parallel batched build, core/hnsw_bulk.py) | "bulk_ref" (the slow
    # numpy exactness reference)
    builder: str = "incremental"
    ef_search: int = 64
    # wide-beam candidates popped per HNSW iteration; None defers to
    # hnsw.expansion_width (per-query override rides search())
    expansion_width: Optional[int] = None
    rescore: bool = True                 # exact second pass for quantized search
    rescore_multiplier: int = 4          # first pass fetches k * multiplier
    filter_flat_threshold: float = 0.10  # MEVS: selectivity below which we
    #                                      scan the filtered subset exactly
    seal: SealPolicy = dataclasses.field(default_factory=SealPolicy)

    def __post_init__(self):
        if self.index not in ("hnsw", "flat", "ivf"):
            raise ValueError(f"index {self.index!r}")
        self.ivf = dataclasses.replace(self.ivf, metric=(
            "cosine" if self.metric == "cosine" else "l2"))
        if self.quantization not in ("none", "pq", "bq"):
            raise ValueError(f"quantization {self.quantization!r}")
        if self.builder not in ("incremental", "bulk", "bulk_ref"):
            raise ValueError(f"builder {self.builder!r}")
        # HNSW metric follows the engine metric
        self.hnsw = dataclasses.replace(self.hnsw, metric=self.metric)


class QuantixarEngine:
    """The paper's "Quantixar Engine": entities in, similarity queries out."""

    def __init__(self, config: EngineConfig):
        self.config = config
        self._vectors = ChunkedArray()            # raw entity vectors
        self._n = 0
        self.metadata = MetadataStore()
        self._pq: Optional[pq_mod.ProductQuantizer] = None
        self._bq: Optional[bq_mod.BinaryQuantizer] = None
        self._code_chunks = ChunkedArray()         # pq codes or bq packed words
        self._packed: Optional[PackedHNSW] = None
        self._device_graph = None                  # (HNSWGraph, max_level, metric)
        self._ivf: Optional[IVFIndex] = None
        self._ivf_effective: Optional[np.ndarray] = None
        self._dirty = True          # no usable sealed segment yet: build first
        self._sealed_n = 0          # rows covered by the sealed segment
        self._delta: Optional[DeltaSegment] = None  # exists once sealed
        self._delta_cache = None    # (delta, version, eff_device, metric)
        self.build_seconds: float = 0.0
        self.insert_seconds: float = 0.0
        # observability for the segmented write path: a post-build add() must
        # bump none of these; seal() bumps seal/index, never quantizer_trains
        self.index_builds = 0       # HNSW-graph / IVF-list constructions
        self.quantizer_trains = 0   # PQ/BQ codebook (re)trainings
        self.seals = 0              # delta -> sealed folds

    # ------------------------------------------------------------------ data
    def __len__(self) -> int:
        return self._n

    @property
    def vectors(self) -> np.ndarray:
        v = self._vectors.view()
        return v if v is not None \
            else np.zeros((0, self.config.dim), dtype=np.float32)

    @property
    def _codes(self) -> Optional[np.ndarray]:
        """Full-corpus code matrix, concatenated lazily: a post-build add()
        only appends its batch chunk — an eager concat would make every
        quantized insert O(corpus) instead of O(batch)."""
        return self._code_chunks.view()

    @_codes.setter
    def _codes(self, value: Optional[np.ndarray]) -> None:
        self._code_chunks = ChunkedArray(
            [] if value is None else [value])

    @property
    def delta_rows(self) -> int:
        return len(self._delta) if self._delta is not None else 0

    def add(self, vectors: np.ndarray,
            metadata: Optional[Sequence[Optional[Dict[str, Any]]]] = None) -> None:
        """Insert a batch of entities (vector + optional metadata record).

        Before the first `build()` this only appends (the build is lazy).
        After it, the batch lands in the delta segment: quantized engines
        encode the rows against the existing codebooks (no retraining), the
        sealed graph is untouched, and the rows are immediately searchable
        via the exact delta scan.  The seal policy may then fold the delta.
        """
        t0 = time.perf_counter()
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim != 2 or vectors.shape[1] != self.config.dim:
            raise ValueError(
                f"expected (n, {self.config.dim}) vectors, got {vectors.shape}")
        if metadata is None:
            metadata = [None] * len(vectors)
        if len(metadata) != len(vectors):
            raise ValueError("metadata length mismatch")
        self._vectors.append(vectors)
        self._n += len(vectors)
        self.metadata.append_batch(metadata)
        if self._dirty or self._delta is None:
            self._dirty = True                    # first build covers everything
        else:
            codes = self._encode(vectors)
            self._delta.append(vectors, codes)
            if codes is not None:
                self._code_chunks.append(codes)
            if self.config.seal.auto and self.config.seal.should_seal(
                    self._sealed_n, len(self._delta)):
                self.seal()
        self.insert_seconds += time.perf_counter() - t0

    def _encode(self, vectors: np.ndarray) -> Optional[np.ndarray]:
        """Encode-only against trained codebooks (never retrains)."""
        if self._pq is not None:
            return np.asarray(self._pq.encode(jnp.asarray(vectors)))
        if self._bq is not None:
            return np.asarray(self._bq.encode(jnp.asarray(vectors)))
        return None

    # ----------------------------------------------------------------- build
    def build(self, seed: int = 0,
              progress: Optional[ProgressFn] = None) -> None:
        """Train quantizers + build the index over everything inserted so far.

        This is the full O(N) path — retrains codebooks and rebuilds the
        graph.  Post-build inserts do *not* re-enter it; they ride the delta
        segment until `seal()` folds them (encode-only, no retraining).
        ``progress`` is an optional ``(phase, done, total)`` callback
        threaded through to the graph builder (serve layers report build
        progress without builders writing to stdout).
        """
        t0 = time.perf_counter()
        cfg = self.config
        raw = self.vectors
        if len(raw) == 0:
            raise RuntimeError("nothing to build: add() vectors first")

        if cfg.quantization == "pq":
            self._pq = pq_mod.ProductQuantizer(
                dataclasses.replace(cfg.pq, metric=(
                    "cosine" if cfg.metric == "cosine" else "l2")))
            self._pq.train(jnp.asarray(raw), seed=seed)
            self._codes = np.asarray(self._pq.encode(jnp.asarray(raw)))
            self.quantizer_trains += 1
        elif cfg.quantization == "bq":
            self._bq = bq_mod.BinaryQuantizer(cfg.bq)
            self._bq.train(jnp.asarray(raw), seed=seed)
            self._codes = np.asarray(self._bq.encode(jnp.asarray(raw)))
            self.quantizer_trains += 1
        else:
            self._codes = None

        self._ivf = None                    # full build retrains coarse centroids
        self._build_index(raw, seed, progress=progress)
        self._mark_sealed()
        self._dirty = False
        self.build_seconds = time.perf_counter() - t0

    def seal(self, seed: int = 0,
             progress: Optional[ProgressFn] = None) -> bool:
        """Fold the delta segment into a new sealed segment.

        Codebooks are reused (the delta rows were already encoded at insert),
        so this rebuilds only the index structure — the size-/ratio-triggered
        merge of the segmented write path, also reachable through
        `Collection.compact()`.  Returns True if anything changed.
        """
        if self._dirty or self._delta is None:
            if self._n == 0:
                return False                # nothing inserted yet
            self.build(seed, progress=progress)  # never built: train + build
            return True
        if len(self._delta) == 0:
            return False
        t0 = time.perf_counter()
        self._build_index(self.vectors, seed, progress=progress)
        self._mark_sealed()
        self.seals += 1
        self.build_seconds = time.perf_counter() - t0
        return True

    def _mark_sealed(self) -> None:
        self._sealed_n = self._n
        self._delta = DeltaSegment(start=self._n, dim=self.config.dim)
        self._delta_cache = None

    def _build_index(self, raw: np.ndarray, seed: int,
                     progress: Optional[ProgressFn] = None) -> None:
        """(Re)build the sealed index structure over `raw` using whatever
        quantizers/codes currently exist — trains nothing except an IVF
        coarse quantizer that does not exist yet."""
        cfg = self.config
        if cfg.index == "hnsw":
            eff, eff_metric = self._effective_vectors()
            hnsw_cfg = dataclasses.replace(cfg.hnsw, metric=eff_metric)
            builder = {"incremental": build, "bulk": bulk_build_device,
                       "bulk_ref": bulk_build}[cfg.builder]
            self._packed = builder(eff, hnsw_cfg, progress=progress)
            self._device_graph = self._to_device_graph()
        elif cfg.index == "ivf":
            # IVF-PQ scans probed lists over reconstructions (the ADC
            # identity, as in the quantized-HNSW path).  BQ's ±1 sign vectors
            # live in code space (bits ≠ dim), so IVF+BQ probes and scans
            # raw vectors — BQ then only compresses the stored codes.
            if cfg.quantization == "pq":
                eff, eff_metric = self._effective_vectors()
            else:
                eff, eff_metric = raw, cfg.metric
            if self._ivf is None or not self._ivf.is_trained:
                self._ivf = IVFIndex(dataclasses.replace(
                    cfg.ivf, metric="l2" if eff_metric != "cosine" else "cosine"))
                self._ivf.train(jnp.asarray(raw), seed=seed)
            self._ivf.build_lists(jnp.asarray(raw))
            self._ivf_effective = eff
        else:
            self._packed = None
            self._device_graph = None
        self.index_builds += 1

    def _to_device_graph(self):
        """Ship the sealed graph to device.  Quantized engines additionally
        ship the code matrix (PQ uint codes / packed BQ uint32 words) so
        layer-0 traversal runs in code domain through the fused beam-gather
        kernels; the float proxy vectors stay aboard for upper-layer
        descent."""
        codes = None
        if self.config.quantization in ("pq", "bq") and self._codes is not None:
            codes = self._codes[: self._packed.n]
        return to_device(self._packed, codes=codes)

    def _effective_vectors(self) -> Tuple[np.ndarray, str]:
        """Vectors the graph traverses + the traversal metric (see module doc)."""
        cfg = self.config
        raw = self.vectors
        if cfg.quantization == "pq":
            recon = np.asarray(self._pq.decode(jnp.asarray(self._codes)))
            # ADC == L2-to-reconstruction (exact identity); cosine inputs were
            # normalized inside the quantizer already.
            return recon, "l2"
        if cfg.quantization == "bq":
            signs = np.asarray(bq_mod.unpack_bits(
                jnp.asarray(self._codes), cfg.bq.bits), dtype=np.float32)
            return signs * 2.0 - 1.0, "dot"   # hamming ~ -dot of ±1 vectors
        return raw, cfg.metric

    # ---------------------------------------------------------------- search
    def search(self, queries: np.ndarray, k: int,
               flt: Optional[Filter] = None,
               ef: Optional[int] = None,
               mask: Optional[np.ndarray] = None,
               rescore: Optional[bool] = None,
               expansion_width: Optional[int] = None,
               params: Optional[AnnParams] = None,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k similarity search (Vector Query / MEVS).

        `mask` is an optional precomputed (N,) bool row mask (e.g. the API
        layer's tombstone liveness mask) AND-ed with the metadata filter.
        `rescore` overrides the config's exact-rescore setting per query.
        `expansion_width` overrides the configured wide-beam width for HNSW
        traversal (1 == classic single-pop).  `params` carries the same
        three knobs as one `AnnParams` struct — the form the API layer's
        plan executor and serving batcher thread through — and is mutually
        exclusive with the individual keywords.

        The sealed segment is searched through its index; a non-empty delta
        segment is exact-scanned in the same distance space and merged, so
        freshly inserted rows are visible without any rebuild.  Masks and the
        rescore pass apply across the sealed+delta union.

        Returns (distances (Q,k) in the engine metric, ids (Q,k); -1 = none).
        """
        if params is not None:
            if (ef, rescore, expansion_width) != (None, None, None):
                raise ValueError(
                    "pass ef/rescore/expansion_width either as keywords or "
                    "inside params=AnnParams(...), not both")
            ef, rescore = params.ef, params.rescore
            expansion_width = params.expansion_width
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if self._dirty:
            self.build()
        cfg = self.config
        queries = np.asarray(queries, dtype=np.float32)
        if queries.ndim == 1:
            queries = queries[None, :]
        # `ef or ...` would silently turn an explicit ef=0 into the default
        ef = ef if ef is not None else max(cfg.ef_search, k)
        flt_mask = self.metadata.evaluate(flt) if flt is not None else None
        if mask is not None:
            mask = np.asarray(mask, dtype=bool)
            mask = flt_mask & mask if flt_mask is not None else mask
        else:
            mask = flt_mask
        do_rescore = cfg.rescore if rescore is None else rescore
        do_rescore = do_rescore and cfg.quantization != "none"

        fetch = k * cfg.rescore_multiplier if do_rescore else k

        if cfg.index == "flat" or self._route_to_flat(mask):
            # the flat scan covers the whole corpus (delta rows included:
            # their codes were appended at insert time)
            d, ids = self._flat_pass(queries, fetch, mask)
        else:
            if cfg.index == "ivf":
                d, ids = self._ivf_pass(queries, fetch, mask)
            else:
                d, ids = self._hnsw_pass(queries, fetch, ef, mask,
                                         expansion_width)
            if self.delta_rows:
                dd, dids = self._delta_pass(queries, fetch, mask)
                d, ids = merge_candidates(d, ids, dd, dids, fetch)
            if mask is not None and (ids[:, : min(fetch, ids.shape[1])] == -1).any():
                # beam under-delivered under the filter: exact masked scan
                d, ids = self._flat_pass(queries, fetch, mask)

        if do_rescore:
            d, ids = self.exact_rescore(queries, ids, k, mask=mask)
        else:
            d, ids = d[:, :k], ids[:, :k]
        # contract: +inf slots (masked-out / padded) never expose a row id
        return d, np.where(np.isfinite(d), ids, -1)

    def _route_to_flat(self, mask: Optional[np.ndarray]) -> bool:
        """MEVS routing (paper: filter first, then search the subset): at low
        selectivity an exact masked scan is both faster and exact."""
        if mask is None:
            return False
        sel = mask.mean() if len(mask) else 0.0
        return sel <= self.config.filter_flat_threshold

    def _flat_pass(self, queries, k, mask):
        cfg = self.config
        mask_j = None if mask is None else jnp.asarray(mask)
        if cfg.quantization == "pq":
            lut = pq_mod.build_adc_lut(
                jnp.asarray(queries), self._pq.codebooks,
                normalize_inputs=cfg.metric == "cosine")
            d = pq_mod.adc_distances(lut, jnp.asarray(self._codes))
            if mask_j is not None:
                d = jnp.where(mask_j[None, :], d, jnp.inf)
            neg_top, idx = jax.lax.top_k(-d, min(k, d.shape[1]))
            return np.asarray(-neg_top), np.asarray(idx, dtype=np.int32)
        if cfg.quantization == "bq":
            q_codes = self._bq.encode(jnp.asarray(queries))
            d = bq_mod.hamming_distances(q_codes, jnp.asarray(self._codes))
            d = d.astype(jnp.float32)
            if mask_j is not None:
                d = jnp.where(mask_j[None, :], d, jnp.inf)
            neg_top, idx = jax.lax.top_k(-d, min(k, d.shape[1]))
            return np.asarray(-neg_top), np.asarray(idx, dtype=np.int32)
        d, ids = flat_search(jnp.asarray(queries), jnp.asarray(self.vectors),
                             min(k, self._n), metric=cfg.metric, mask=mask_j)
        return np.asarray(d), np.asarray(ids)

    def _hnsw_pass(self, queries, k, ef, mask, expansion_width=None):
        """Wide-beam-search the sealed graph only (delta rows merge
        separately).  Quantized engines traverse layer 0 in *code domain*:
        PQ pops evaluate per-query ADC LUTs against the uint code matrix,
        BQ pops XOR+popcount packed words — both through the fused
        beam-gather kernel path (kernels/ops.py), never a float32
        reconstruction gather."""
        cfg = self.config
        g, max_level, metric = self._device_graph
        n_sealed = self._packed.n
        width = self.effective_expansion_width(expansion_width)
        ef_eff = max(ef, k)
        if mask is not None:
            ef_eff = min(max(ef_eff * 2, k * 4), n_sealed)
        q = queries
        q_codes = None
        if metric == "dot" and cfg.quantization == "none":
            q = preprocess_vectors(queries, cfg.metric)
        elif cfg.quantization == "bq":
            packed_q = self._bq.encode(jnp.asarray(queries))   # (Q, W) uint32
            signs = np.asarray(bq_mod.unpack_bits(packed_q, cfg.bq.bits),
                               dtype=np.float32)
            q = signs * 2.0 - 1.0            # descent proxy (±1 sign vectors)
            if g.codes is not None:
                metric = "hamming"
                q_codes = packed_q
        elif cfg.quantization == "pq":
            if cfg.metric == "cosine":
                q = preprocess_vectors(queries, "cosine")
            if g.codes is not None:
                metric = "adc"
                q_codes = pq_mod.build_adc_lut(
                    jnp.asarray(queries), self._pq.codebooks,
                    normalize_inputs=cfg.metric == "cosine")
        d, ids = hnsw_search(g, jnp.asarray(q), k=min(ef_eff, n_sealed),
                             ef=min(ef_eff, n_sealed), max_level=max_level,
                             metric=metric, expansion_width=width,
                             q_codes=q_codes)
        d, ids = np.asarray(d), np.asarray(ids)
        if metric == "hamming":
            # back to the -dot space the delta scan / merge uses:
            # dot(±1) = bits - 2·hamming, so -dot = 2·hamming - bits (exact)
            d = np.where(np.isfinite(d), 2.0 * d - float(cfg.bq.bits), d)
        d, ids = self._apply_mask(d, ids, mask, n_sealed)
        return d[:, :k], ids[:, :k]

    def effective_expansion_width(self, override: Optional[int] = None) -> int:
        """Per-query override > EngineConfig.expansion_width > HNSWConfig."""
        width = (override if override is not None
                 else self.config.expansion_width
                 if self.config.expansion_width is not None
                 else self.config.hnsw.expansion_width)
        if width < 1:
            raise ValueError(f"expansion_width must be >= 1, got {width}")
        return int(width)

    def _ivf_pass(self, queries, k, mask):
        """Probe the sealed IVF lists only (delta rows merge separately)."""
        d, ids = self._ivf.search(jnp.asarray(self._ivf_effective),
                                  jnp.asarray(queries), k)
        d, ids = self._apply_mask(np.asarray(d), np.asarray(ids),
                                  mask, self._sealed_n)
        return d[:, :k], ids[:, :k]

    @staticmethod
    def _apply_mask(d, ids, mask, n_rows):
        """Demote masked-out candidates to +inf/-1 and re-sort.  `mask` is
        corpus-global; candidate ids come from the sealed structure, so only
        its first `n_rows` entries apply (-1 padding maps to False)."""
        if mask is None:
            return d, ids
        allowed = np.concatenate([mask[:n_rows], [False]])
        ok = allowed[ids]
        d = np.where(ok, d, np.inf)
        order = np.argsort(d, axis=1, kind="stable")
        d = np.take_along_axis(d, order, axis=1)
        ids = np.where(np.take_along_axis(ok, order, axis=1),
                       np.take_along_axis(ids, order, axis=1), -1)
        return d, ids

    def _delta_pass(self, queries, k, mask):
        """Exact scan of the delta segment in the *sealed pass's* distance
        space, so `merge_candidates` can interleave the two lists directly:

          * hnsw + none  — graph traverses preprocessed raw vectors with the
            device metric ("dot" for cosine/dot, "l2" for l2);
          * hnsw + pq    — squared L2 to reconstructions (== ADC, exactly);
          * hnsw + bq    — -dot of ±1 sign vectors (monotone in Hamming);
          * ivf          — squared L2 of `_prep`-ed vectors, the same
            contraction `_ivf_search` evaluates inside probed lists.

        Returned ids are global (delta start offset applied).
        """
        cfg = self.config
        delta = self._delta
        n_d = len(delta)
        eff_dev, metric = self._delta_effective()
        if cfg.index == "ivf":
            q = np.asarray(self._ivf._prep(jnp.asarray(queries)))
        elif cfg.quantization == "pq":
            q = preprocess_vectors(queries, "cosine") \
                if cfg.metric == "cosine" else queries
        elif cfg.quantization == "bq":
            q = np.asarray(bq_mod.unpack_bits(
                self._bq.encode(jnp.asarray(queries)), cfg.bq.bits),
                dtype=np.float32) * 2.0 - 1.0
        else:
            q = preprocess_vectors(queries, cfg.metric)
        padded = int(eff_dev.shape[0])
        live = (np.ones(n_d, dtype=bool) if mask is None
                else np.asarray(mask[delta.start:], dtype=bool))
        if padded > n_d:
            live = np.concatenate([live, np.zeros(padded - n_d, dtype=bool)])
        d, ids = flat_search(jnp.asarray(q), eff_dev, min(k, padded),
                             metric=metric, mask=jnp.asarray(live),
                             base_index=delta.start)
        return np.asarray(d), np.asarray(ids, dtype=np.int32)

    def _delta_effective(self):
        """Device-resident distance-space matrix for the delta scan, padded
        to a power of two.  Its inputs only change on append, so it is
        cached per (segment, version) — the padding additionally keeps the
        jitted scan from retracing as the delta grows row by row.  Returns
        (device matrix, flat_search metric)."""
        cfg = self.config
        delta = self._delta
        cached = self._delta_cache
        if (cached is not None and cached[0] is delta
                and cached[1] == delta.version):
            return cached[2], cached[3]
        if cfg.index == "ivf":
            eff = (np.asarray(self._pq.decode(jnp.asarray(delta.codes)))
                   if cfg.quantization == "pq" else delta.raw)
            eff = np.asarray(self._ivf._prep(jnp.asarray(eff)))
            metric = "l2"
        elif cfg.quantization == "pq":
            eff = np.asarray(self._pq.decode(jnp.asarray(delta.codes)))
            metric = "l2"
        elif cfg.quantization == "bq":
            eff = np.asarray(bq_mod.unpack_bits(
                jnp.asarray(delta.codes), cfg.bq.bits),
                dtype=np.float32) * 2.0 - 1.0
            metric = "dot"
        else:
            eff = preprocess_vectors(delta.raw, cfg.metric)
            metric = "l2" if cfg.metric == "l2" else "dot"
        n_d = len(delta)
        padded = 1 << max(0, n_d - 1).bit_length()
        if padded > n_d:
            eff = np.concatenate(
                [eff, np.zeros((padded - n_d, eff.shape[1]), eff.dtype)])
        eff_dev = jnp.asarray(eff)
        self._delta_cache = (delta, delta.version, eff_dev, metric)
        return eff_dev, metric

    def exact_rescore(self, queries, cand_ids, k, mask=None):
        """Exact re-ranking of first-pass candidates in the engine metric
        (paper's optional precision knob) — also the public backend of the
        plan layer's explicit `rescore` stage.  The row mask must be
        re-applied here: exact distances would otherwise resurrect
        masked-out candidates that the first pass only demoted to +inf."""
        pair = get_metric(self.config.metric)
        raw = self.vectors
        safe = np.maximum(cand_ids, 0)
        cand_vecs = raw[safe]                      # (Q, k', D)
        d = np.stack([
            np.asarray(pair(jnp.asarray(queries[i: i + 1]),
                            jnp.asarray(cand_vecs[i])))[0]
            for i in range(len(queries))])
        ok = cand_ids >= 0
        if mask is not None:
            ok &= mask[safe]
        d = np.where(ok, d, np.inf)
        order = np.argsort(d, axis=1, kind="stable")[:, :k]
        d = np.take_along_axis(d, order, axis=1)
        ids = np.take_along_axis(cand_ids, order, axis=1)
        return d, np.where(np.isfinite(d), ids, -1)

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, Any]:
        state: Dict[str, Any] = {
            "vectors": self.vectors,
            "n": np.array([self._n], dtype=np.int64),
            # rows in [0, sealed_n) are covered by the serialized index;
            # rows beyond it round-trip as the delta segment (no rebuild)
            "sealed_n": np.array([self._sealed_n], dtype=np.int64),
            "dirty": np.array([self._dirty]),
        }
        if self._codes is not None:
            state["codes"] = self._codes
        if self._pq is not None:
            state.update({f"pq.{k}": v for k, v in self._pq.state_dict().items()})
        if self._bq is not None:
            state.update({f"bq.{k}": v for k, v in self._bq.state_dict().items()})
        if self._packed is not None:
            state.update({f"hnsw.{k}": v
                          for k, v in self._packed.state_dict().items()})
        if self._ivf is not None:
            state.update({f"ivf.{k}": v
                          for k, v in self._ivf.state_dict().items()})
        state.update({f"meta.{k}": v
                      for k, v in self.metadata.state_dict().items()})
        return state

    @classmethod
    def from_state_dict(cls, config: EngineConfig,
                        state: Dict[str, Any]) -> "QuantixarEngine":
        eng = cls(config)
        eng._vectors = ChunkedArray(
            [np.asarray(state["vectors"], dtype=np.float32)])
        eng._n = int(state["n"][0])
        eng.metadata = MetadataStore.from_state_dict(
            {k[5:]: v for k, v in state.items() if k.startswith("meta.")})
        if "codes" in state:
            eng._codes = np.asarray(state["codes"])
        pq_state = {k[3:]: v for k, v in state.items() if k.startswith("pq.")}
        if pq_state:
            eng._pq = pq_mod.ProductQuantizer(dataclasses.replace(
                config.pq, metric="cosine" if config.metric == "cosine" else "l2"))
            eng._pq.load_state_dict(pq_state)
        bq_state = {k[3:]: v for k, v in state.items() if k.startswith("bq.")}
        if bq_state:
            eng._bq = bq_mod.BinaryQuantizer(config.bq)
            eng._bq.load_state_dict(bq_state)
        sealed_n = int(state["sealed_n"][0]) if "sealed_n" in state else eng._n
        ivf_state = {k[4:]: v for k, v in state.items()
                     if k.startswith("ivf.")}
        if ivf_state:
            # mirror _build_index exactly: PQ probes reconstructions under L2
            # (the ADC identity), everything else probes raw vectors under
            # the engine metric — a mismatch here silently changes results
            if config.quantization == "pq":
                eng._ivf = IVFIndex(dataclasses.replace(config.ivf,
                                                        metric="l2"))
                eff, _ = eng._effective_vectors()
            else:
                eng._ivf = IVFIndex(config.ivf)
                eff = eng.vectors
            eng._ivf.load_state_dict(ivf_state)
            eng._ivf_effective = eff[:sealed_n]   # lists cover sealed rows only
            eng._dirty = False
        hnsw_state = {k[5:]: v for k, v in state.items()
                      if k.startswith("hnsw.")}
        if hnsw_state:
            eff_metric = ("l2" if config.quantization == "pq" else
                          "dot" if config.quantization == "bq" else config.metric)
            eng._packed = PackedHNSW.from_state_dict(
                hnsw_state, dataclasses.replace(config.hnsw, metric=eff_metric))
            eng._device_graph = eng._to_device_graph()
            eng._dirty = False
        elif config.index == "flat" and eng._n:
            eng._dirty = False
        if "dirty" in state and bool(state["dirty"][0]):
            eng._dirty = True
        if not eng._dirty:
            # reconstruct the segment split: sealed index + delta tail
            eng._sealed_n = sealed_n
            eng._delta = DeltaSegment(start=sealed_n, dim=config.dim)
            if eng._n > sealed_n:
                tail_codes = (eng._codes[sealed_n:]
                              if eng._codes is not None else None)
                eng._delta.append(eng.vectors[sealed_n:], tail_codes)
        return eng

    def stats(self) -> Dict[str, Any]:
        out = {"n": self._n, "dim": self.config.dim,
               "index": self.config.index,
               "quantization": self.config.quantization,
               "metric": self.config.metric,
               "build_seconds": self.build_seconds,
               "insert_seconds": self.insert_seconds,
               "sealed_rows": self._sealed_n,
               "delta_rows": self.delta_rows,
               "index_builds": self.index_builds,
               "quantizer_trains": self.quantizer_trains,
               "seals": self.seals}
        if self.config.index == "hnsw":
            out["builder"] = self.config.builder
        if self._packed is not None:
            out.update(self._packed.degree_stats())
            out.update(self._packed.build_info)
        if self._ivf is not None and self._ivf.list_sizes is not None:
            sizes = np.asarray(self._ivf.list_sizes)
            out["ivf_lists"] = int(sizes.shape[0])
            out["ivf_mean_list"] = float(sizes.mean())
            out["ivf_max_list"] = int(sizes.max())
        if self._pq is not None:
            out["compression"] = self._pq.compression_ratio(self.config.dim)
        if self._bq is not None:
            out["compression"] = self._bq.compression_ratio(self.config.dim)
        return out
