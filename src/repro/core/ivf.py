"""IVF (inverted-file) index — beyond-paper extension.

The paper ships flat + HNSW; every system it cites as lineage (Qdrant,
Milvus, FAISS-family) also ships IVF, the workhorse for billion-scale
corpora: k-means coarse quantizer → per-centroid inverted lists → probe the
``nprobe`` nearest lists only.  Search cost drops from O(N) to
O(nprobe·N/nlist) with a smooth recall knob.

TPU-native layout: inverted lists are padded to a fixed ``max_list`` length
(PAD rows score +inf), so probing is two gathers + one distance kernel call
— fully jittable, batched over queries, and shardable by list id.
Composes with PQ: store codes instead of vectors (IVF-PQ) and run the ADC
kernel over probed candidates.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import normalize
from .pq import _fit_one_subspace

Array = jax.Array
PAD = -1


@dataclasses.dataclass(frozen=True)
class IVFConfig:
    nlist: int = 64           # coarse centroids
    nprobe: int = 8           # lists probed per query
    metric: str = "cosine"    # cosine (normalize + dot) | l2
    kmeans_iters: int = 20
    list_slack: float = 1.5   # max_list = slack * N/nlist (overflow drops
    #                           to the next-nearest list, never silently)


class IVFIndex:
    """Coarse-quantized inverted-file index (optionally over PQ codes)."""

    def __init__(self, config: IVFConfig):
        self.config = config
        self.centroids: Optional[Array] = None      # (nlist, D)
        self.lists: Optional[Array] = None          # (nlist, max_list) int32
        self.list_sizes: Optional[np.ndarray] = None

    @property
    def is_trained(self) -> bool:
        return self.centroids is not None

    def _prep(self, x: Array) -> Array:
        return normalize(x) if self.config.metric == "cosine" \
            else x.astype(jnp.float32)

    # ------------------------------------------------------------- build
    def train(self, vectors: Array, seed: int = 0) -> None:
        cfg = self.config
        x = self._prep(jnp.asarray(vectors))
        key = jax.random.PRNGKey(seed)
        self.centroids = _fit_one_subspace(key, x, cfg.nlist,
                                           cfg.kmeans_iters)

    def build_lists(self, vectors: Array) -> None:
        """Assign every vector to its nearest centroid; pad lists."""
        cfg = self.config
        x = self._prep(jnp.asarray(vectors))
        n = x.shape[0]
        d2 = (jnp.sum(x * x, 1)[:, None]
              + jnp.sum(self.centroids * self.centroids, 1)[None, :]
              - 2.0 * x @ self.centroids.T)
        order = np.asarray(jnp.argsort(d2, axis=1))   # (N, nlist) preference
        max_list = int(cfg.list_slack * n / cfg.nlist) + 1
        lists = [[] for _ in range(cfg.nlist)]
        for i in range(n):
            for c in order[i]:                        # overflow -> next list
                if len(lists[c]) < max_list:
                    lists[c].append(i)
                    break
        out = np.full((cfg.nlist, max_list), PAD, dtype=np.int32)
        for c, ids in enumerate(lists):
            out[c, : len(ids)] = ids
        self.lists = jnp.asarray(out)
        self.list_sizes = np.array([len(ids) for ids in lists])

    # ------------------------------------------------------------ search
    def search(self, corpus: Array, queries: Array,
               k: int) -> Tuple[Array, Array]:
        """Exact distances within probed lists. corpus: the raw (N, D)
        vectors (or reconstructions for IVF-PQ — same ADC identity as the
        engine's quantized HNSW path)."""
        cfg = self.config
        return _ivf_search(self._prep(jnp.asarray(corpus)),
                           self._prep(jnp.asarray(queries)),
                           self.centroids, self.lists, k, cfg.nprobe)

    def state_dict(self):
        return {"centroids": np.asarray(self.centroids),
                "lists": np.asarray(self.lists)}

    def load_state_dict(self, state):
        self.centroids = jnp.asarray(state["centroids"])
        self.lists = jnp.asarray(state["lists"])
        # list_sizes is derived state and is not serialized; recompute it so
        # stats/routing on a restored index don't trip over None
        self.list_sizes = np.asarray(
            (np.asarray(self.lists) != PAD).sum(axis=1))


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _ivf_search(corpus: Array, queries: Array, centroids: Array,
                lists: Array, k: int, nprobe: int) -> Tuple[Array, Array]:
    q = queries
    # 1. nearest nprobe centroids per query
    dc = (jnp.sum(q * q, 1)[:, None]
          + jnp.sum(centroids * centroids, 1)[None, :]
          - 2.0 * q @ centroids.T)                        # (Q, nlist)
    _, probe = jax.lax.top_k(-dc, nprobe)                 # (Q, nprobe)

    # 2. gather candidate ids: (Q, nprobe * max_list)
    cand = lists[probe].reshape(q.shape[0], -1)
    valid = cand != PAD
    safe = jnp.maximum(cand, 0)

    # 3. exact distances to candidates
    vecs = corpus[safe]                                   # (Q, C, D)
    d = (jnp.sum(q * q, 1)[:, None] + jnp.sum(vecs * vecs, -1)
         - 2.0 * jnp.einsum("qd,qcd->qc", q, vecs))
    d = jnp.where(valid, d, jnp.inf)

    kk = min(k, cand.shape[1])
    neg, idx = jax.lax.top_k(-d, kk)
    ids = jnp.take_along_axis(cand, idx, axis=1)
    return -neg, jnp.where(jnp.isfinite(-neg), ids, -1)
