"""Distance / similarity metrics for Quantixar.

The paper (§I, §III-A) uses cosine similarity as the default metric — chosen
for resilience to the curse of dimensionality — with L2 and inner-product as
alternatives, and Hamming distance over binary-quantized codes.

All functions are batched and jit-friendly: queries ``(Q, D)`` against a corpus
``(N, D)`` produce a ``(Q, N)`` distance matrix.  Smaller distance == closer,
for every metric (similarities are negated) so that downstream top-k code is
metric-agnostic.

The hot pairwise paths are expressed as a single GEMM plus rank-1 corrections
(``‖x−y‖² = ‖x‖² + ‖y‖² − 2·x·y``) so that on TPU they lower onto the MXU; the
Pallas kernels in :mod:`repro.kernels` implement the same contraction with
explicit VMEM tiling for the perf-critical scan.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

#: Registry of metric name -> pairwise fn (queries (Q,D), corpus (N,D)) -> (Q,N)
_METRICS: Dict[str, Callable[[Array, Array], Array]] = {}


def register_metric(name: str):
    def deco(fn):
        _METRICS[name] = fn
        return fn

    return deco


def get_metric(name: str) -> Callable[[Array, Array], Array]:
    try:
        return _METRICS[name]
    except KeyError:  # pragma: no cover - defensive
        raise ValueError(f"unknown metric {name!r}; have {sorted(_METRICS)}")


def available_metrics():
    return sorted(_METRICS)


# ---------------------------------------------------------------------------
# Float metrics
# ---------------------------------------------------------------------------

def l2_norm_sq(x: Array, axis: int = -1) -> Array:
    return jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axis)


def normalize(x: Array, eps: float = 1e-12) -> Array:
    """Unit-normalize rows (cosine preprocessing)."""
    x = x.astype(jnp.float32)
    n = jnp.sqrt(jnp.maximum(l2_norm_sq(x), eps))
    return x / n[..., None]


@register_metric("l2")
def pairwise_l2(queries: Array, corpus: Array) -> Array:
    """Squared L2 distances, GEMM formulation (MXU-friendly)."""
    q = queries.astype(jnp.float32)
    x = corpus.astype(jnp.float32)
    # (Q,N) = q2[:,None] + x2[None,:] - 2 q @ x.T  -- one big matmul.
    qq = l2_norm_sq(q)  # (Q,)
    xx = l2_norm_sq(x)  # (N,)
    cross = q @ x.T  # (Q,N) on the MXU
    d = qq[:, None] + xx[None, :] - 2.0 * cross
    return jnp.maximum(d, 0.0)  # clamp fp error


@register_metric("dot")
def pairwise_dot(queries: Array, corpus: Array) -> Array:
    """Negative inner product (so smaller == more similar)."""
    return -(queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T)


@register_metric("cosine")
def pairwise_cosine(queries: Array, corpus: Array) -> Array:
    """Cosine *distance* = 1 - cosine similarity. Default Quantixar metric."""
    return 1.0 + pairwise_dot(normalize(queries), normalize(corpus))


# ---------------------------------------------------------------------------
# Hamming (packed binary codes, uint32 words)
# ---------------------------------------------------------------------------

@register_metric("hamming")
def pairwise_hamming(q_codes: Array, x_codes: Array) -> Array:
    """Hamming distance between packed binary codes.

    Args:
      q_codes: ``(Q, W)`` uint32 packed codes.
      x_codes: ``(N, W)`` uint32 packed codes.
    Returns:
      ``(Q, N)`` int32 bit-difference counts.
    """
    x = jnp.bitwise_xor(q_codes[:, None, :], x_codes[None, :, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Single-pair conveniences (used by HNSW inner loops)
# ---------------------------------------------------------------------------

def point_l2(q: Array, x: Array) -> Array:
    d = q.astype(jnp.float32) - x.astype(jnp.float32)
    return jnp.sum(d * d, axis=-1)


def point_cosine(q: Array, x: Array) -> Array:
    return 1.0 - (normalize(q) * normalize(x)).sum(-1)


def point_dot(q: Array, x: Array) -> Array:
    return -(q.astype(jnp.float32) * x.astype(jnp.float32)).sum(-1)


POINT_METRICS = {"l2": point_l2, "cosine": point_cosine, "dot": point_dot}


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def brute_force_topk(queries: Array, corpus: Array, k: int, metric: str = "cosine"):
    """Exact top-k: the paper's Flat Index primitive.

    Returns (distances (Q,k) ascending, indices (Q,k)).
    """
    d = get_metric(metric)(queries, corpus)
    neg_d, idx = jax.lax.top_k(-d, k)  # top_k is max-k; negate for min-k
    return -neg_d, idx
