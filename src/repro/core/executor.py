"""`PlanExecutor`: staged interpreter for declarative query plans.

The API layer (`repro.api.plan`) compiles every search — the fluent
`Query`, legacy `Collection.search`, and the wire `Search` op — into a
`QueryPlan`: a tree of stage dataclasses.  This module is the single
execution path for those plans against a `QuantixarEngine`:

  * ``ann``      — one index pass (HNSW/flat/IVF, sealed + delta segments,
                   masks, per-query ef/width/rescore knobs) producing a
                   candidate set;
  * ``sparse``   — one BM25 pass over a text field's inverted index
                   (`repro.core.sparse.SparseIndex`), producing negated-
                   score candidates in the same lower-is-closer space;
  * ``rescore``  — exact float re-ranking of an oversampled candidate set
                   in the collection metric (the coarse-to-fine second
                   stage quantized collections are built around);
  * ``prefetch`` — N independent sub-plans, each with its own vector,
                   filter, and tuning knobs, executed recursively;
  * ``fusion``   — rank fusion (RRF) or score-normalized linear fusion of
                   the prefetch result lists into one candidate set.

The executor is deliberately decoupled from the plan *dataclasses*: stages
are dispatched on their ``op`` tag and read by attribute, so `repro.core`
never imports `repro.api` (which imports this module).  `AnnParams` — the
single struct that carries per-query search knobs through the collection
plumbing and into `QuantixarEngine.search` — lives here for the same
reason.

Every stage execution is timed and counted; `ExecResult.stages` is the
per-stage report `Query.explain()` surfaces (candidate counts in/out,
seconds, nested prefetch children).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class AnnParams:
    """Per-query ANN knobs, threaded as ONE struct from the API layer
    through the batcher into `QuantixarEngine.search` (replacing the old
    parallel ef/rescore/expansion_width keyword lists).

    ``None`` fields defer to the engine/collection config.  ``rescore``
    here is the *engine-internal* oversample-and-rescore toggle used by
    single-stage plans; multi-stage plans set it False and rescore via an
    explicit ``rescore`` stage instead.
    """

    ef: Optional[int] = None
    expansion_width: Optional[int] = None
    rescore: Optional[bool] = None

    @classmethod
    def or_none(cls, ef: Optional[int] = None,
                expansion_width: Optional[int] = None,
                rescore: Optional[bool] = None) -> Optional["AnnParams"]:
        """All-default knobs collapse to ``None`` so batcher extras keys
        (and wire bodies) stay identical to a knob-less request."""
        if ef is None and expansion_width is None and rescore is None:
            return None
        return cls(ef=ef, expansion_width=expansion_width, rescore=rescore)


@dataclasses.dataclass
class ExecResult:
    """One plan execution: padded (Q, k) candidate arrays + stage report."""

    distances: np.ndarray
    ids: np.ndarray
    stages: List[Dict[str, Any]]


def _valid_count(d: np.ndarray, ids: np.ndarray) -> int:
    """Candidates that are real rows (not padding / masked-out slots)."""
    return int(((ids >= 0) & np.isfinite(d)).sum())


def _pad_topk(pairs: List[Tuple[float, int]], k: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """(score, row) pairs, already sorted ascending -> padded (k,) arrays."""
    d = np.full(k, np.inf, dtype=np.float32)
    ids = np.full(k, -1, dtype=np.int64)
    for slot, (score, row) in enumerate(pairs[:k]):
        d[slot] = score
        ids[slot] = row
    return d, ids


def fuse_rrf(results: List[Tuple[np.ndarray, np.ndarray]], k: int,
             rrf_k: int = 60) -> Tuple[np.ndarray, np.ndarray]:
    """Reciprocal-rank fusion of per-query candidate lists.

    Each input is a (1, C_i) ranked list; a candidate's fused score is
    ``sum_i 1 / (rrf_k + rank_i)`` over the lists that contain it.  Scores
    are returned negated so the engine-wide "lower is closer" contract
    holds for fused hits too.
    """
    scores: Dict[int, float] = {}
    for d, ids in results:
        rank = 0
        for dist, row in zip(np.asarray(d).ravel(), np.asarray(ids).ravel()):
            if row < 0 or not np.isfinite(dist):
                continue
            scores[int(row)] = scores.get(int(row), 0.0) \
                + 1.0 / (rrf_k + rank)
            rank += 1
    ranked = sorted(((-s, row) for row, s in scores.items()),
                    key=lambda t: (t[0], t[1]))
    return _pad_topk(ranked, k)


def fuse_linear(results: List[Tuple[np.ndarray, np.ndarray]], k: int,
                weights: Optional[Tuple[float, ...]] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Score-normalized weighted fusion: each list's finite distances are
    min-max normalized to [0, 1]; a candidate absent from a list takes that
    list's worst score (1.0).  Lower fused score = better."""
    if weights is None:
        weights = tuple(1.0 / max(len(results), 1)
                        for _ in range(len(results)))
    per_list: List[Dict[int, float]] = []
    for d, ids in results:
        d, ids = np.asarray(d).ravel(), np.asarray(ids).ravel()
        ok = (ids >= 0) & np.isfinite(d)
        norm: Dict[int, float] = {}
        if ok.any():
            lo, hi = float(d[ok].min()), float(d[ok].max())
            span = (hi - lo) or 1.0
            for dist, row in zip(d[ok], ids[ok]):
                norm[int(row)] = (float(dist) - lo) / span
        per_list.append(norm)
    rows = set()
    for norm in per_list:
        rows.update(norm)
    fused = [(sum(w * norm.get(row, 1.0)
                  for w, norm in zip(weights, per_list)), row)
             for row in rows]
    fused.sort(key=lambda t: (t[0], t[1]))
    return _pad_topk(fused, k)


class PlanExecutor:
    """Executes a `QueryPlan` tree against one engine + row mask.

    ``search_fn(queries, k, flt=..., params=...)`` is the collection's
    masked first-pass search (so empty-corpus padding, liveness masks, and
    k clamping stay in one place); ``engine`` is used for the exact-rescore
    stage.  The executor itself is stateless across calls.
    """

    def __init__(self, search_fn: Callable[..., Tuple[np.ndarray, np.ndarray]],
                 engine, mask: Optional[np.ndarray] = None,
                 sparse_fn: Optional[Callable[
                     ..., Tuple[np.ndarray, np.ndarray]]] = None):
        self._search = search_fn
        self._engine = engine
        self._mask = mask
        # sparse_fn(field, text, k, flt=...) -> (1, k) negated-BM25
        # candidates; None when the collection has no text fields
        self._sparse = sparse_fn

    # ------------------------------------------------------------- execution
    def execute(self, plan, inherited: Optional[np.ndarray] = None,
                deadline: Optional[float] = None) -> ExecResult:
        """Run every stage of ``plan``; returns padded (Q, plan.k) arrays
        plus the per-stage report.  ``inherited`` is the parent plan's
        query matrix — prefetch sub-plans without their own vector reuse
        it, so the wire form carries the root vector once.  ``deadline``
        (a ``time.perf_counter()`` instant) is checked at every stage
        boundary: a plan that outlives it raises `TimeoutError` instead of
        holding the collection lock for the remaining stages."""
        queries = inherited
        if plan.vector is not None:
            queries = np.asarray(plan.vector, dtype=np.float32)
            if queries.ndim == 1:
                queries = queries[None, :]
        cand: Optional[Tuple[np.ndarray, np.ndarray]] = None
        prefetched: Optional[List[ExecResult]] = None
        stages: List[Dict[str, Any]] = []
        for stage in plan.stages:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(
                    f"plan exceeded its deadline before stage "
                    f"{stage.op!r}")
            cand_in = 0 if cand is None else _valid_count(*cand)
            t0 = time.perf_counter()
            children: Optional[List[List[Dict[str, Any]]]] = None
            if stage.op == "ann":
                cand = self._run_ann(stage, queries)
            elif stage.op == "sparse":
                cand = self._run_sparse(stage)
            elif stage.op == "rescore":
                cand = self._run_rescore(stage, queries, cand)
            elif stage.op == "prefetch":
                prefetched = [self.execute(sub, inherited=queries,
                                           deadline=deadline)
                              for sub in stage.plans]
                cand_in = 0
                cand = None
                children = [r.stages for r in prefetched]
            elif stage.op == "fusion":
                cand = self._run_fusion(stage, prefetched)
                cand_in = sum(_valid_count(r.distances, r.ids)
                              for r in (prefetched or []))
                prefetched = None
            else:                     # validate_plan rejects this earlier
                raise ValueError(f"unknown plan stage op {stage.op!r}")
            report: Dict[str, Any] = {
                "stage": stage.op,
                "k": int(getattr(stage, "k", 0) or 0),
                "candidates_in": cand_in,
                "candidates_out": (0 if cand is None
                                   else _valid_count(*cand)),
                "seconds": time.perf_counter() - t0,
            }
            if children is not None:
                report["candidates_out"] = sum(
                    _valid_count(r.distances, r.ids) for r in prefetched)
                report["children"] = children
            stages.append(report)
        if cand is None:
            raise ValueError("plan produced no candidate set "
                             "(prefetch without fusion?)")
        d, ids = cand
        d, ids = d[:, : plan.k], ids[:, : plan.k]
        if d.shape[1] < plan.k:            # corpus smaller than k: pad out
            pad = plan.k - d.shape[1]
            d = np.pad(d, ((0, 0), (0, pad)), constant_values=np.inf)
            ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        return ExecResult(distances=d, ids=ids, stages=stages)

    # ---------------------------------------------------------------- stages
    def _run_ann(self, stage, queries):
        if queries is None:
            raise ValueError("ann stage needs a query vector")
        params = AnnParams.or_none(ef=stage.ef,
                                   expansion_width=stage.expansion_width,
                                   rescore=stage.rescore)
        d, ids = self._search(queries, stage.k, flt=stage.filter,
                              params=params)
        return np.asarray(d), np.asarray(ids)

    def _run_sparse(self, stage):
        if self._sparse is None:
            # validate_plan rejects sparse stages against text-less
            # schemas, so this only guards hand-built executors
            raise ValueError("collection has no text fields; "
                             "sparse stages cannot execute")
        d, ids = self._sparse(stage.field, stage.text, stage.k,
                              flt=stage.filter)
        return np.asarray(d), np.asarray(ids)

    def _run_rescore(self, stage, queries, cand):
        if cand is None:
            raise ValueError("rescore stage needs a preceding candidate set")
        if queries is None:
            raise ValueError("rescore stage needs a query vector")
        d, ids = cand
        return self._engine.exact_rescore(queries, np.asarray(ids, np.int64),
                                          stage.k, mask=self._mask)

    def _run_fusion(self, stage, prefetched):
        if not prefetched:
            raise ValueError("fusion stage needs a preceding prefetch stage")
        q = prefetched[0].distances.shape[0]
        rows_d, rows_i = [], []
        for qi in range(q):
            lists = [(r.distances[qi: qi + 1], r.ids[qi: qi + 1])
                     for r in prefetched]
            if stage.method == "rrf":
                d, ids = fuse_rrf(lists, stage.k, rrf_k=stage.rrf_k)
            else:
                d, ids = fuse_linear(lists, stage.k, weights=stage.weights)
            rows_d.append(d)
            rows_i.append(ids)
        return np.stack(rows_d), np.stack(rows_i)
