"""Cross-version jax compatibility shims shared by every mesh program.

jax moved `shard_map` out of `jax.experimental` (>= 0.6, with the
replication checker renamed `check_rep` -> `check_vma`) and grew
`jax.lax.axis_size` as the static axis-size query.  Every module that
lowers a shard_map program needs the same two fallbacks; they live here
once so version bumps touch one file instead of each caller.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    def shard_map_compat(f, mesh, in_specs, out_specs):
        """`shard_map` with replication checking off (the varying-axes
        checker cannot see through cross-shard gather + top_k)."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map_compat(f, mesh, in_specs, out_specs):
        """`shard_map` with replication checking off (the varying-axes
        checker cannot see through cross-shard gather + top_k)."""
        return _sm(f, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:                                              # jax 0.4.x: folds to const
    def axis_size(ax):
        return jax.lax.psum(1, ax)
