"""Packed-Hamming-distance Pallas kernel (DESIGN.md §2).

The paper's BQ search is XOR + POPCNT over packed words; on TPU this is VPU
(vector unit) work: uint32 lanes, ``population_count`` per lane, lane-sum.

Grid: (Q/TQ, N/TN); both code tiles live in VMEM (W words per row — 256-bit
codes are W=8 uint32s, so a 256×512 tile pair is ~1.5 MiB).  The XOR+popcount
slab (TQ, TN, W) is materialized per tile in VMEM (256·512·8·4 = 4 MiB with
the defaults) and reduced on the fly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 128
DEFAULT_TN = 512


def _hamming_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...]                                # (TQ, W) uint32
    x = x_ref[...]                                # (TN, W) uint32
    xor = jnp.bitwise_xor(q[:, None, :], x[None, :, :])   # (TQ, TN, W)
    pc = jax.lax.population_count(xor).astype(jnp.int32)
    o_ref[...] = jnp.sum(pc, axis=-1)


@functools.partial(jax.jit, static_argnames=("tq", "tn", "interpret"))
def hamming_kernel(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    tq: int = DEFAULT_TQ,
    tn: int = DEFAULT_TN,
    interpret: bool = False,
) -> jax.Array:
    """(Q, W) uint32 × (N, W) uint32 -> (Q, N) int32 Hamming distances."""
    assert q_codes.dtype == jnp.uint32 and x_codes.dtype == jnp.uint32
    q_n, w = q_codes.shape
    x_n, w2 = x_codes.shape
    assert w == w2, (w, w2)

    tq = min(tq, max(8, q_n))
    tn = min(tn, max(128, x_n))
    gq = -(-q_n // tq)
    gn = -(-x_n // tn)
    qp = jnp.pad(q_codes, ((0, gq * tq - q_n), (0, 0)))
    xp = jnp.pad(x_codes, ((0, gn * tn - x_n), (0, 0)))

    out = pl.pallas_call(
        _hamming_kernel,
        grid=(gq, gn),
        in_specs=[
            pl.BlockSpec((tq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gq * tq, gn * tn), jnp.int32),
        interpret=interpret,
    )(qp, xp)
    return out[:q_n, :x_n]
