"""Fused gather-distance Pallas kernels for wide-beam HNSW traversal.

The wide-beam search pops B candidates per iteration and evaluates all
B·M0 neighbour distances in one shot.  The memory pattern is a *row gather*
(neighbour ids are data-dependent) followed by a dense contraction — exactly
the shape scalar-prefetch Pallas was built for:

  * the (L,) id vector rides as a scalar-prefetch argument, available before
    the kernel body runs;
  * the corpus (vectors / PQ codes / packed BQ words) stays in HBM
    (``memory_space=ANY``) — it never fits in VMEM and only L rows of it are
    touched per call;
  * each grid step issues TB row-DMAs into a VMEM scratch tile (one DMA
    semaphore per row, started together so the copies overlap), waits, and
    fuses the distance arithmetic on the landed tile — gather and distance
    never round-trip through HBM.

Three variants share the structure, differing only in the fused math:

  ``beam_gather_kernel``          rows (TB, D) fp32   -> L2 / -dot   (VPU/MXU)
  ``beam_gather_adc_kernel``      rows (TB, m) uint   -> LUT-sum ADC (MXU via
                                  the one-hot contraction of pq_adc.py)
  ``beam_gather_hamming_kernel``  rows (TB, W) uint32 -> XOR+popcount (VPU)

so quantized engines traverse the graph in *code domain* — the (N, m) code
matrix is the only corpus-sized buffer the search touches, not a float32
reconstruction.

Block shapes: TB defaults to 128 rows; ids are padded to a TB multiple with
row 0 (a valid row — padded lanes are sliced off before returning).  VMEM per
step: rows TB·D·4 = 64 KiB at D=128, q one row, out (1, TB) — far under
budget, leaving the pipeline to double-buffer the next tile's DMAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TB = 128
DEFAULT_M_CHUNK = 8


def _gather_rows(ids_ref, corpus_ref, rows, sems, tb: int):
    """DMA the tb rows named by this grid step's id slice into VMEM scratch.

    All copies start before any is awaited (per-row semaphores), so the
    gathers overlap instead of serializing on HBM latency.
    """
    i = pl.program_id(0)

    def start(t, carry):
        row = ids_ref[i * tb + t]
        pltpu.make_async_copy(corpus_ref.at[pl.ds(row, 1), :],
                              rows.at[pl.ds(t, 1), :], sems.at[t]).start()
        return carry

    jax.lax.fori_loop(0, tb, start, 0)

    def wait(t, carry):
        row = ids_ref[i * tb + t]
        pltpu.make_async_copy(corpus_ref.at[pl.ds(row, 1), :],
                              rows.at[pl.ds(t, 1), :], sems.at[t]).wait()
        return carry

    jax.lax.fori_loop(0, tb, wait, 0)


def _pad_ids(ids: jax.Array, tb: int):
    """ids (L,) -> (ceil(L/tb)*tb,) int32, padded with row 0."""
    l = ids.shape[0]
    g = -(-l // tb)
    return jnp.pad(ids.astype(jnp.int32), (0, g * tb - l)), g


# ------------------------------------------------------------------ L2 / dot
def _beam_kernel(ids_ref, q_ref, corpus_ref, o_ref, rows, sems, *,
                 tb: int, mode: str):
    _gather_rows(ids_ref, corpus_ref, rows, sems, tb)
    q = q_ref[...].astype(jnp.float32)            # (1, D)
    r = rows[...].astype(jnp.float32)             # (TB, D)
    if mode == "l2":
        # same float ops as the traversal historically used (diff-square-sum,
        # not the norm expansion) — keeps width=1 bit-compatible
        d = r - q
        o_ref[...] = jnp.sum(d * d, axis=-1)[None, :]
    else:  # dot
        o_ref[...] = -jax.lax.dot_general(
            q, r, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (1, TB)


@functools.partial(jax.jit, static_argnames=("mode", "tb", "interpret"))
def beam_gather_kernel(q: jax.Array, ids: jax.Array, corpus: jax.Array, *,
                       mode: str = "l2", tb: int = DEFAULT_TB,
                       interpret: bool = False) -> jax.Array:
    """q (D,) × ids (L,) × corpus (N, D) -> (L,) float32 distances."""
    if mode not in ("l2", "dot"):
        raise ValueError(f"mode {mode!r}")
    l = ids.shape[0]
    d = corpus.shape[1]
    tb = min(tb, l)
    ids_p, g = _pad_ids(ids, tb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, d), lambda i, ids: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, tb), lambda i, ids: (0, i)),
        scratch_shapes=[pltpu.VMEM((tb, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((tb,))],
    )
    out = pl.pallas_call(
        functools.partial(_beam_kernel, tb=tb, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, g * tb), jnp.float32),
        interpret=interpret,
    )(ids_p, q.astype(jnp.float32)[None, :], corpus.astype(jnp.float32))
    return out[0, :l]


# ---------------------------------------------------------------------- ADC
def _beam_adc_kernel(ids_ref, lut_ref, codes_ref, o_ref, rows, sems, *,
                     tb: int, m_chunk: int):
    _gather_rows(ids_ref, codes_ref, rows, sems, tb)
    lut = lut_ref[...].astype(jnp.float32)        # (m, k)
    codes = rows[...].astype(jnp.int32)           # (TB, m)
    m, k = lut.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
    acc = jnp.zeros((1, tb), dtype=jnp.float32)
    for m0 in range(0, m, m_chunk):               # static python loop
        mc = min(m_chunk, m - m0)
        onehot = (codes[:, m0:m0 + mc, None] == iota).astype(jnp.float32)
        lut_c = lut[m0:m0 + mc, :].reshape(1, mc * k)
        # MXU contraction over (mc·k): (1, mc·k) @ (mc·k, TB)
        acc += jax.lax.dot_general(
            lut_c, onehot.reshape(tb, mc * k),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tb", "m_chunk", "interpret"))
def beam_gather_adc_kernel(lut: jax.Array, ids: jax.Array, codes: jax.Array,
                           *, tb: int = DEFAULT_TB,
                           m_chunk: int = DEFAULT_M_CHUNK,
                           interpret: bool = False) -> jax.Array:
    """lut (m, k) × ids (L,) × codes (N, m) uint -> (L,) float32 ADC."""
    l = ids.shape[0]
    m = codes.shape[1]
    tb = min(tb, l)
    ids_p, g = _pad_ids(ids, tb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[pl.BlockSpec(lut.shape, lambda i, ids: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, tb), lambda i, ids: (0, i)),
        scratch_shapes=[pltpu.VMEM((tb, m), codes.dtype),
                        pltpu.SemaphoreType.DMA((tb,))],
    )
    out = pl.pallas_call(
        functools.partial(_beam_adc_kernel, tb=tb, m_chunk=m_chunk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, g * tb), jnp.float32),
        interpret=interpret,
    )(ids_p, lut.astype(jnp.float32), codes)
    return out[0, :l]


# ------------------------------------------------------------------ Hamming
def _beam_hamming_kernel(ids_ref, q_ref, codes_ref, o_ref, rows, sems, *,
                         tb: int):
    _gather_rows(ids_ref, codes_ref, rows, sems, tb)
    x = jnp.bitwise_xor(rows[...], q_ref[...])    # (TB, W)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    o_ref[...] = jnp.sum(pc, axis=-1)[None, :]


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def beam_gather_hamming_kernel(q_code: jax.Array, ids: jax.Array,
                               codes: jax.Array, *, tb: int = DEFAULT_TB,
                               interpret: bool = False) -> jax.Array:
    """q_code (W,) uint32 × ids (L,) × codes (N, W) uint32 -> (L,) int32."""
    assert q_code.dtype == jnp.uint32 and codes.dtype == jnp.uint32
    l = ids.shape[0]
    w = codes.shape[1]
    tb = min(tb, l)
    ids_p, g = _pad_ids(ids, tb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, w), lambda i, ids: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, tb), lambda i, ids: (0, i)),
        scratch_shapes=[pltpu.VMEM((tb, w), jnp.uint32),
                        pltpu.SemaphoreType.DMA((tb,))],
    )
    out = pl.pallas_call(
        functools.partial(_beam_hamming_kernel, tb=tb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, g * tb), jnp.int32),
        interpret=interpret,
    )(ids_p, q_code[None, :], codes)
    return out[0, :l]
