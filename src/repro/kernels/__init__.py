"""Pallas TPU kernels: the paper's perf-critical distance arithmetic
(l2/dot GEMM, PQ-ADC, packed Hamming) + the fused weight-resident sLSTM
sequence kernel motivated by the §Perf roofline work."""

from .ops import (beam_gather_adc, beam_gather_distances,
                  beam_gather_hamming, dot_distances, hamming_distances,
                  l2_distances, pq_adc_distances, slstm_sequence)

__all__ = ["beam_gather_adc", "beam_gather_distances", "beam_gather_hamming",
           "dot_distances", "hamming_distances", "l2_distances",
           "pq_adc_distances", "slstm_sequence"]
