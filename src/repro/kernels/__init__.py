"""Pallas TPU kernels: the paper's perf-critical distance arithmetic
(l2/dot GEMM, PQ-ADC, packed Hamming) + the fused weight-resident sLSTM
sequence kernel motivated by the §Perf roofline work."""

from .ops import (dot_distances, hamming_distances, l2_distances,
                  pq_adc_distances)

__all__ = ["dot_distances", "hamming_distances", "l2_distances",
           "pq_adc_distances"]
