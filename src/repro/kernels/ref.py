"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition with no tiling/padding tricks;
kernel tests sweep shapes & dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_distance_ref(queries: Array, corpus: Array) -> Array:
    """(Q, D) × (N, D) -> (Q, N) squared L2, float32 accumulation."""
    q = queries.astype(jnp.float32)
    x = corpus.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=1)
    xx = jnp.sum(x * x, axis=1)
    d = qq[:, None] + xx[None, :] - 2.0 * (q @ x.T)
    return jnp.maximum(d, 0.0)


def dot_distance_ref(queries: Array, corpus: Array) -> Array:
    """(Q, D) × (N, D) -> (Q, N) negative inner product, float32 accum."""
    return -(queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T)


def pq_adc_ref(lut: Array, codes: Array) -> Array:
    """ADC: lut (Q, m, k) float × codes (N, m) uint -> (Q, N) float32.

    out[q, n] = sum_i lut[q, i, codes[n, i]].
    """
    c = codes.astype(jnp.int32)

    def per_sub(lut_i, c_i):  # (Q, k), (N,) -> (Q, N)
        return lut_i[:, c_i]

    g = jax.vmap(per_sub, in_axes=(1, 1))(lut.astype(jnp.float32), c)
    return jnp.sum(g, axis=0)


def hamming_ref(q_codes: Array, x_codes: Array) -> Array:
    """Packed Hamming: (Q, W) uint32 × (N, W) uint32 -> (Q, N) int32."""
    x = jnp.bitwise_xor(q_codes[:, None, :], x_codes[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def beam_gather_l2_ref(q: Array, ids: Array, corpus: Array) -> Array:
    """Fused gather-distance: q (D,) × ids (L,) × corpus (N, D) -> (L,).

    Row gather followed by squared L2, written as the *same* float ops the
    in-loop traversal used historically (rows - q, square, sum) so the
    wide-beam search at width=1 reproduces the single-pop path bit-for-bit.
    """
    rows = corpus[ids]                     # (L, D)
    d = rows - q[None, :]
    return jnp.sum(d * d, axis=-1)


def beam_gather_dot_ref(q: Array, ids: Array, corpus: Array) -> Array:
    """Fused gather-distance, negative inner product variant -> (L,)."""
    return -(corpus[ids] @ q)


def beam_gather_adc_ref(lut: Array, ids: Array, codes: Array) -> Array:
    """Code-domain fused gather-ADC: lut (m, k) × ids (L,) × codes (N, m).

    out[l] = sum_i lut[i, codes[ids[l], i]] — the per-query PQ traversal
    distance, evaluated on uint codes instead of float32 reconstructions.
    """
    m = lut.shape[0]
    rows = codes[ids].astype(jnp.int32)    # (L, m)
    gathered = lut.astype(jnp.float32)[jnp.arange(m)[None, :], rows]
    return jnp.sum(gathered, axis=-1)


def beam_gather_hamming_ref(q_code: Array, ids: Array, codes: Array) -> Array:
    """Code-domain fused gather-Hamming: q_code (W,) uint32 × ids (L,) ×
    codes (N, W) uint32 -> (L,) int32 popcount distances."""
    rows = codes[ids]                      # (L, W)
    x = jnp.bitwise_xor(rows, q_code[None, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def pair_gather_l2_ref(ids: Array, corpus: Array) -> Array:
    """Fused gather + pairwise squared L2: ids (C,) × corpus (N, D) ->
    (C, C).  The Alg-4 bulk prune consults row j to test whether candidate
    j is closer to the query than to any already-selected candidate.
    Norm-expansion form (never materializes a (C, C, D) diff tensor — this
    oracle runs under vmap over prune batches on the CPU fallback path)."""
    rows = corpus[ids]                     # (C, D)
    g = rows @ rows.T
    nn = jnp.sum(rows * rows, axis=-1)
    return jnp.maximum(nn[:, None] + nn[None, :] - 2.0 * g, 0.0)


def pair_gather_dot_ref(ids: Array, corpus: Array) -> Array:
    """Fused gather + pairwise negated inner product -> (C, C)."""
    rows = corpus[ids]
    return -(rows @ rows.T)


def slstm_sequence_ref(gates_x: Array, r: Array, b: Array,
                       n_heads: int) -> Array:
    """Stabilised exp-gate sLSTM over a sequence (scan of the model cell).

    gates_x (B, S, 4d), r (4, H, blk, blk), b (4d,) -> h (B, S, d).
    Semantics identical to repro.models.recurrent._slstm_cell.
    """
    b_sz, s, d4 = gates_x.shape
    d = d4 // 4
    blk = d // n_heads

    def step(state, g_t):
        h, c, n, m = state
        hh = h.reshape(b_sz, n_heads, blk)
        rec = jnp.einsum("bnk,gnkl->bgnl", hh,
                         r.astype(jnp.float32)).reshape(b_sz, 4 * d)
        pre = g_t.astype(jnp.float32) + rec + b
        gi, gf, gz, go = jnp.split(pre, 4, axis=-1)
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(gz)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    init = (jnp.zeros((b_sz, d)), jnp.zeros((b_sz, d)),
            jnp.zeros((b_sz, d)), jnp.full((b_sz, d), -1e30))
    _, hs = jax.lax.scan(step, init, gates_x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(gates_x.dtype)
