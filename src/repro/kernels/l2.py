"""Blocked L2 / dot distance-matrix Pallas kernel — the paper's SIMD distance
loop re-derived for the TPU MXU (DESIGN.md §2).

The AVX2/FMA inner loop of the paper becomes one systolic contraction:
``‖q−x‖² = ‖q‖² + ‖x‖² − 2·q·x`` — the cross term is a (TQ, TK)·(TK, TN)
matmul on the MXU; the norm corrections ride along in the same tile.

Grid: (Q/TQ, N/TN, D/TK) with accumulation over the K axis — the canonical
Pallas matmul schedule.  Block shapes are 128-aligned for MXU occupancy; the
fp32 accumulator lives in the output VMEM tile across K steps (revisited,
same (i, j) block for every k), so no scratch is needed.

VMEM budget per grid cell (defaults TQ=TN=256, TK=512, fp32):
  q tile 256·512·4 = 512 KiB, x tile 512 KiB, out tile 256 KiB  ≈ 1.3 MiB
  « 16 MiB v5e VMEM, leaving room for double-buffered pipelining.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TN = 256
DEFAULT_TK = 512


def _l2_kernel(q_ref, x_ref, o_ref, *, n_k: int, mode: str):
    """One (TQ, TN) output tile; accumulates across the k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)            # (TQ, TK)
    x = x_ref[...].astype(jnp.float32)            # (TN, TK)
    # cross term on the MXU
    acc = -2.0 * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (TQ, TN)
    if mode == "l2":
        qq = jnp.sum(q * q, axis=1)[:, None]      # (TQ, 1)
        xx = jnp.sum(x * x, axis=1)[None, :]      # (1, TN)
        acc = acc + qq + xx
    else:  # dot: negative inner product = 0.5 * (-2 q.x)
        acc = 0.5 * acc
    o_ref[...] += acc

    if mode == "l2":
        @pl.when(k == n_k - 1)
        def _clamp():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "tq", "tn", "tk", "interpret"))
def l2_distance_kernel(
    queries: jax.Array,
    corpus: jax.Array,
    *,
    mode: str = "l2",
    tq: int = DEFAULT_TQ,
    tn: int = DEFAULT_TN,
    tk: int = DEFAULT_TK,
    interpret: bool = False,
) -> jax.Array:
    """(Q, D) × (N, D) -> (Q, N) blocked distance matrix.

    Inputs of any shape are zero-padded up to tile multiples (zero rows don't
    disturb the cross/norm terms of real rows); output is sliced back.
    """
    if mode not in ("l2", "dot"):
        raise ValueError(f"mode {mode!r}")
    q_n, d = queries.shape
    x_n, d2 = corpus.shape
    assert d == d2, (d, d2)

    tq = min(tq, max(8, q_n))
    tn = min(tn, max(128, x_n))
    tk = min(tk, d)

    def pad_to(a, rows, cols):
        return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))

    gq = -(-q_n // tq)
    gn = -(-x_n // tn)
    gk = -(-d // tk)
    qp = pad_to(queries, gq * tq, gk * tk)
    xp = pad_to(corpus, gn * tn, gk * tk)

    out = pl.pallas_call(
        functools.partial(_l2_kernel, n_k=gk, mode=mode),
        grid=(gq, gn, gk),
        in_specs=[
            pl.BlockSpec((tq, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gq * tq, gn * tn), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:q_n, :x_n]
