"""PQ asymmetric-distance-computation Pallas kernel (DESIGN.md §2).

The paper's AVX2 "fast-scan" analogue on TPU: x86 PQ scan uses PSHUFB 16-way
LUT shuffles; the TPU has no byte-shuffle unit, but it has an MXU — so the
gather ``out[q,n] = Σ_i lut[q,i,codes[n,i]]`` is re-expressed as a dense
contraction against a one-hot expansion of the codes:

    onehot (TN, m, k) = (codes[:, :, None] == iota(k))
    out (TQ, TN)      = einsum('qmk,nmk->qn', lut_tile, onehot)

One-hot never leaves VMEM; the contraction runs on the MXU at (m·k) effective
depth.  For k ≤ 256 and m ≤ 64 the LUT tile (TQ·m·k·4 ≤ 8·64·256·4 = 512 KiB)
and code tile (TN·m = 512·64 = 32 KiB) fit comfortably; the one-hot expansion
(TN·m·k·4 = 512·64·256·4 = 32 MiB) would NOT — so the kernel loops over the m
sub-spaces in chunks (``m_chunk``), keeping the live one-hot slab at
TN·m_chunk·k·4 ≤ 512·8·256·4 = 4 MiB.

Grid: (Q/TQ, N/TN); codes are streamed through VMEM tile by tile while each
query's LUT stays resident — exactly the paper's "LUT in registers, codes
streamed" SIMD scan, with VMEM playing the register-file role.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 8
DEFAULT_TN = 512
DEFAULT_M_CHUNK = 8


def _adc_kernel(lut_ref, codes_ref, o_ref, *, m_chunk: int):
    lut = lut_ref[...].astype(jnp.float32)        # (TQ, m, k)
    codes = codes_ref[...].astype(jnp.int32)      # (TN, m)
    tq, m, k = lut.shape
    tn = codes.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)

    acc = jnp.zeros((tq, tn), dtype=jnp.float32)
    for m0 in range(0, m, m_chunk):               # static python loop
        mc = min(m_chunk, m - m0)
        onehot = (codes[:, m0:m0 + mc, None] == iota).astype(jnp.float32)
        lut_c = lut[:, m0:m0 + mc, :]
        # MXU contraction over (mc, k): (TQ, mc·k) @ (mc·k, TN)
        acc += jax.lax.dot_general(
            lut_c.reshape(tq, mc * k), onehot.reshape(tn, mc * k),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("tq", "tn", "m_chunk", "interpret"))
def pq_adc_kernel(
    lut: jax.Array,
    codes: jax.Array,
    *,
    tq: int = DEFAULT_TQ,
    tn: int = DEFAULT_TN,
    m_chunk: int = DEFAULT_M_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """ADC scan: lut (Q, m, k) float × codes (N, m) uint8/16 -> (Q, N) float32.

    Padding: queries pad with zero LUTs, codes pad with code 0 — padded rows /
    columns are sliced off before returning, so their values are irrelevant.
    """
    q_n, m, k = lut.shape
    x_n, m2 = codes.shape
    assert m == m2, (m, m2)

    tq = min(tq, max(1, q_n))
    tn = min(tn, max(128, x_n))
    gq = -(-q_n // tq)
    gn = -(-x_n // tn)
    lut_p = jnp.pad(lut.astype(jnp.float32),
                    ((0, gq * tq - q_n), (0, 0), (0, 0)))
    codes_p = jnp.pad(codes, ((0, gn * tn - x_n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_adc_kernel, m_chunk=m_chunk),
        grid=(gq, gn),
        in_specs=[
            pl.BlockSpec((tq, m, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gq * tq, gn * tn), jnp.float32),
        interpret=interpret,
    )(lut_p, codes_p)
    return out[:q_n, :x_n]
