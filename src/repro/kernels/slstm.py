"""Fused sLSTM sequence kernel — weight-resident sequential recurrence.

Why (EXPERIMENTS.md §Perf 4.4): the pure-XLA sLSTM scan re-reads the
recurrent weights every timestep — 16.8 MB × 24,576 steps ≈ 6.6 TB of HBM
traffic per xlstm-1.3b training step, the measured memory floor.  This kernel
is the structural fix: the block-diagonal recurrent weights live in VMEM for
the *whole sequence* (constant index_map ⇒ fetched once), the state (h,c,n,m)
lives in VMEM scratch across sequential grid steps, and only the precomputed
input-side gates stream through.

Grid: (S / chunk,) — TPU grids iterate sequentially, so scratch carries the
recurrence across chunks.  Per-step HBM traffic drops from
(weights + gates + states) to (gates only): 16.8 MB + ~100 KB → ~128 KB,
a ~130x reduction on the dominant term (analytic; validated for correctness
in interpret mode against the pure-jnp oracle).

Stabilised exp-gate cell (matches repro.models.recurrent._slstm_cell):
    pre    = gates_x[t] + [h·R_i, h·R_f, h·R_z, h·R_o] + b
    logf   = log_sigmoid(pre_f);  m' = max(logf + m, pre_i)
    i'     = exp(pre_i − m');     f' = exp(logf + m − m')
    c'     = f'·c + i'·tanh(pre_z);  n' = f'·n + i'
    h'     = sigmoid(pre_o) · c' / max(n', 1e−6)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _slstm_kernel(gates_ref, r_ref, b_ref, o_ref, h_ref, c_ref, n_ref,
                  m_ref, *, n_heads: int, chunk: int):
    """One grid step = `chunk` sequential timesteps.

    gates_ref: (B, chunk, 4d) input-side gates for this chunk (streamed)
    r_ref:     (4, H, blk, blk) recurrent weights (VMEM-resident, constant)
    b_ref:     (1, 4d) bias
    o_ref:     (B, chunk, d) hidden-state output block
    h/c/n/m_ref: (B, d) fp32 VMEM scratch carried across grid steps
    """
    step0 = pl.program_id(0) == 0

    @pl.when(step0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    r = r_ref[...].astype(jnp.float32)          # (4, H, blk, blk)
    bias = b_ref[0].astype(jnp.float32)         # (4d,)
    b_sz, _, d4 = gates_ref.shape
    d = d4 // 4
    blk = d // n_heads

    def step(t, _):
        h = h_ref[...]
        g_t = gates_ref[:, t, :].astype(jnp.float32)          # (B, 4d)
        hh = h.reshape(b_sz, n_heads, blk)
        rec = jnp.einsum("bnk,gnkl->bgnl", hh, r,
                         preferred_element_type=jnp.float32)
        pre = g_t + rec.reshape(b_sz, 4 * d) + bias
        gi, gf, gz, go = (pre[:, :d], pre[:, d:2 * d],
                          pre[:, 2 * d:3 * d], pre[:, 3 * d:])
        logf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(logf + m_ref[...], gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(logf + m_ref[...] - m_new)
        c_new = f_p * c_ref[...] + i_p * jnp.tanh(gz)
        n_new = f_p * n_ref[...] + i_p
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        h_ref[...] = h_new
        c_ref[...] = c_new
        n_ref[...] = n_new
        m_ref[...] = m_new
        o_ref[:, t, :] = h_new.astype(o_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())


@functools.partial(
    jax.jit, static_argnames=("n_heads", "chunk", "interpret"))
def slstm_sequence_kernel(gates_x: jax.Array, r: jax.Array, b: jax.Array,
                          *, n_heads: int, chunk: int = DEFAULT_CHUNK,
                          interpret: bool = False) -> jax.Array:
    """Fused sLSTM over a full sequence.

    Args:
      gates_x: (B, S, 4d) precomputed input-side gates (x @ w_in).
      r: (4, H, blk, blk) block-diagonal recurrent weights.
      b: (4d,) gate biases.
    Returns:
      h: (B, S, d) hidden states.
    """
    b_sz, s, d4 = gates_x.shape
    d = d4 // 4
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (s // chunk,)

    return pl.pallas_call(
        functools.partial(_slstm_kernel, n_heads=n_heads, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_sz, chunk, d4), lambda i: (0, i, 0)),
            pl.BlockSpec(r.shape, lambda i: (0, 0, 0, 0)),   # resident
            pl.BlockSpec((1, d4), lambda i: (0, 0)),         # resident
        ],
        out_specs=pl.BlockSpec((b_sz, chunk, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_sz, s, d), gates_x.dtype),
        scratch_shapes=[pltpu.VMEM((b_sz, d), jnp.float32)
                        for _ in range(4)],    # h, c, n, m carried state
        interpret=interpret,
    )(gates_x, r, b[None, :])
