"""Fused gather + pairwise-distance Pallas kernel for bulk HNSW pruning.

The vectorized Alg-4 diversification prune (core/hnsw_bulk.py) needs, for
every node in a batch, the full (C, C) distance matrix among that node's C
candidate neighbours: candidate j survives iff it is closer to the query
than to every already-selected candidate, so each scan step consults one
row of the pair matrix.

The memory pattern is the same data-dependent row gather as wide-beam
traversal (beam_gather.py) — candidate ids ride as a scalar-prefetch
argument, the corpus stays in HBM (``memory_space=ANY``), and the C rows
are DMA'd into a VMEM scratch tile — but the fused math is a *self*
contraction: one (C, D) × (D, C) MXU matmul producing the full pair
matrix, instead of C separate query-row gathers.

C is small (≲128: m0 + M candidates plus random extras), so rows, the
pair matrix, and the per-row DMA semaphores all fit comfortably in VMEM
in a single grid step; batching over nodes happens outside via ``vmap``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .beam_gather import _gather_rows


def _pair_kernel(ids_ref, corpus_ref, o_ref, rows, sems, *, c: int,
                 mode: str):
    _gather_rows(ids_ref, corpus_ref, rows, sems, c)
    r = rows[...].astype(jnp.float32)             # (C, D)
    g = jax.lax.dot_general(r, r, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (C, C)
    if mode == "l2":
        nn = jnp.sum(r * r, axis=-1)              # (C,)
        o_ref[...] = jnp.maximum(nn[:, None] + nn[None, :] - 2.0 * g, 0.0)
    else:  # dot
        o_ref[...] = -g


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def pair_gather_kernel(ids: jax.Array, corpus: jax.Array, *,
                       mode: str = "l2",
                       interpret: bool = False) -> jax.Array:
    """ids (C,) × corpus (N, D) -> (C, C) float32 pairwise distances."""
    if mode not in ("l2", "dot"):
        raise ValueError(f"mode {mode!r}")
    c = ids.shape[0]
    d = corpus.shape[1]
    cp = -(-c // 8) * 8                            # sublane-align the tile
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, cp - c))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((cp, cp), lambda i, ids: (0, 0)),
        scratch_shapes=[pltpu.VMEM((cp, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((cp,))],
    )
    out = pl.pallas_call(
        functools.partial(_pair_kernel, c=cp, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cp, cp), jnp.float32),
        interpret=interpret,
    )(ids_p, corpus.astype(jnp.float32))
    return out[:c, :c]
