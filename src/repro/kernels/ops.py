"""Public jit'd wrappers for the Pallas kernels.

Platform dispatch: on TPU the compiled kernels run natively; elsewhere (this
CPU container) they execute in ``interpret=True`` mode — same kernel body,
Python-evaluated — so correctness is validated everywhere while the BlockSpec
tiling is real TPU structure.  ``force_ref=True`` (or env QUANTIXAR_REF=1)
routes to the pure-jnp oracle instead, which is what the engine uses for
speed on CPU.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from . import ref
from .beam_gather import (beam_gather_adc_kernel, beam_gather_hamming_kernel,
                          beam_gather_kernel)
from .bulk_prune import pair_gather_kernel
from .hamming import hamming_kernel
from .l2 import l2_distance_kernel
from .pq_adc import pq_adc_kernel
from .slstm import DEFAULT_CHUNK, slstm_sequence_kernel

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_ref(force_ref: Optional[bool]) -> bool:
    if force_ref is not None:
        return force_ref
    if os.environ.get("QUANTIXAR_REF", ""):
        return True
    # On non-TPU backends interpret-mode Pallas is correct but slow; default
    # to the oracle for library use. Tests pass force_ref=False explicitly.
    return _interpret()


def l2_distances(queries: Array, corpus: Array, *,
                 force_ref: Optional[bool] = None, **tiles) -> Array:
    if _use_ref(force_ref):
        return ref.l2_distance_ref(queries, corpus)
    return l2_distance_kernel(queries, corpus, mode="l2",
                              interpret=_interpret(), **tiles)


def dot_distances(queries: Array, corpus: Array, *,
                  force_ref: Optional[bool] = None, **tiles) -> Array:
    if _use_ref(force_ref):
        return ref.dot_distance_ref(queries, corpus)
    return l2_distance_kernel(queries, corpus, mode="dot",
                              interpret=_interpret(), **tiles)


def pq_adc_distances(lut: Array, codes: Array, *,
                     force_ref: Optional[bool] = None, **tiles) -> Array:
    if _use_ref(force_ref):
        return ref.pq_adc_ref(lut, codes)
    return pq_adc_kernel(lut, codes, interpret=_interpret(), **tiles)


def hamming_distances(q_codes: Array, x_codes: Array, *,
                      force_ref: Optional[bool] = None, **tiles) -> Array:
    if _use_ref(force_ref):
        return ref.hamming_ref(q_codes, x_codes)
    return hamming_kernel(q_codes, x_codes, interpret=_interpret(), **tiles)


# ------------------------------------------------- wide-beam gather-distance
# Per-query fused (ids -> row gather -> distance) evaluators for the HNSW
# wide-beam traversal (core/hnsw_search.py).  Called under vmap/while_loop;
# same ref/kernel dispatch contract as the dense kernels above.

def beam_gather_distances(q: Array, ids: Array, corpus: Array, *,
                          mode: str = "l2",
                          force_ref: Optional[bool] = None, **tiles) -> Array:
    """q (D,) × ids (L,) × corpus (N, D) -> (L,) float32 (l2 | dot)."""
    if _use_ref(force_ref):
        if mode == "l2":
            return ref.beam_gather_l2_ref(q, ids, corpus)
        return ref.beam_gather_dot_ref(q, ids, corpus)
    return beam_gather_kernel(q, ids, corpus, mode=mode,
                              interpret=_interpret(), **tiles)


def pair_gather_distances(ids: Array, corpus: Array, *,
                          mode: str = "l2",
                          force_ref: Optional[bool] = None,
                          **tiles) -> Array:
    """ids (C,) × corpus (N, D) -> (C, C) float32 pairwise distances
    among the gathered rows (l2 | dot) — the bulk-prune pair matrix."""
    if _use_ref(force_ref):
        if mode == "l2":
            return ref.pair_gather_l2_ref(ids, corpus)
        return ref.pair_gather_dot_ref(ids, corpus)
    return pair_gather_kernel(ids, corpus, mode=mode,
                              interpret=_interpret(), **tiles)


def beam_gather_adc(lut: Array, ids: Array, codes: Array, *,
                    force_ref: Optional[bool] = None, **tiles) -> Array:
    """lut (m, k) × ids (L,) × codes (N, m) -> (L,) float32 ADC distances."""
    if _use_ref(force_ref):
        return ref.beam_gather_adc_ref(lut, ids, codes)
    return beam_gather_adc_kernel(lut, ids, codes,
                                  interpret=_interpret(), **tiles)


def beam_gather_hamming(q_code: Array, ids: Array, codes: Array, *,
                        force_ref: Optional[bool] = None, **tiles) -> Array:
    """q_code (W,) × ids (L,) × codes (N, W) uint32 -> (L,) int32 Hamming."""
    if _use_ref(force_ref):
        return ref.beam_gather_hamming_ref(q_code, ids, codes)
    return beam_gather_hamming_kernel(q_code, ids, codes,
                                      interpret=_interpret(), **tiles)


# --------------------------------------------------------- sLSTM sequence
# Fused weight-resident sLSTM (models/recurrent.py learned-metric scorer);
# same ref/kernel dispatch contract as the distance kernels above.

def slstm_sequence(gates_x: Array, r: Array, b: Array, *, n_heads: int,
                   chunk: int = DEFAULT_CHUNK,
                   force_ref: Optional[bool] = None) -> Array:
    """gates_x (B, S, 4d) × r (4, H, blk, blk) × b (4d,) -> h (B, S, d)."""
    if _use_ref(force_ref):
        return ref.slstm_sequence_ref(gates_x, r, b, n_heads=n_heads)
    return slstm_sequence_kernel(gates_x, r, b, n_heads=n_heads,
                                 chunk=chunk, interpret=_interpret())
