"""Checkpoint store: manifest + sharded segments (DESIGN.md §2, storage row).

Plays the RocksDB/etcd role of the paper with the same interface split:

  * local mode  — one host, segments under a single directory (RocksDB role:
    fast local persistence).
  * sharded mode — each host writes only its shard's segments + a per-shard
    manifest; a coordinator (host 0) commits the global manifest (etcd role:
    the manifest is the consistent, versioned source of truth).

Fault-tolerance contract:
  * atomic commits — segments are written to a staging dir, fsync'd, then the
    manifest is atomically renamed in; a crash mid-write never corrupts the
    last committed generation.
  * generations — every commit gets a monotonically increasing generation id;
    `latest()` resolves the newest complete one; older generations are kept
    (bounded by `keep`) for rollback.
  * WAL — `wal_append()` persists insert batches between index rebuilds;
    recovery = load last generation + replay WAL segments.
  * elastic reshard — the corpus is row-partitioned, so loading N-shard data
    onto M shards is a deterministic concat+resplit (`load_resharded`).
"""

from __future__ import annotations

import dataclasses
import errno
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

MANIFEST = "MANIFEST.json"


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _save_array(path: str, arr: np.ndarray) -> None:
    with open(path, "wb") as f:
        np.save(f, arr, allow_pickle=arr.dtype == object)
        f.flush()
        os.fsync(f.fileno())


def _load_array(path: str) -> np.ndarray:
    return np.load(path, allow_pickle=True)


@dataclasses.dataclass
class Manifest:
    generation: int
    step: int
    created_unix: float
    num_shards: int
    arrays: Dict[str, Dict[str, Any]]   # key -> {file, shape, dtype, shard}
    wal_segments: List[str]
    extra: Dict[str, Any]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Manifest":
        return cls(**json.loads(text))


class CheckpointStore:
    """Directory layout:

        root/
          gen-000001/MANIFEST.json + *.npy     (committed generations)
          wal/wal-<t>.npz                      (insert log since last commit)
    """

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        os.makedirs(self.wal_dir, exist_ok=True)
        self._lock = threading.Lock()
        # separate lock: _lock is held for a whole commit's IO, and
        # save_async must stay non-blocking while a commit is in flight
        self._threads_lock = threading.Lock()
        self._async_threads: List[threading.Thread] = []  # guarded-by: _threads_lock

    # ------------------------------------------------------------ layout
    @property
    def wal_dir(self) -> str:
        return os.path.join(self.root, "wal")

    def _gen_dir(self, gen: int) -> str:
        return os.path.join(self.root, f"gen-{gen:06d}")

    def generations(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("gen-"):
                mpath = os.path.join(self.root, name, MANIFEST)
                if os.path.exists(mpath):      # complete commits only
                    out.append(int(name[4:]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        gens = self.generations()
        return gens[-1] if gens else None

    # ------------------------------------------------------------ commit
    def save(self, state: Dict[str, np.ndarray], *, step: int = 0,
             shard_id: int = 0, num_shards: int = 1,
             extra: Optional[Dict[str, Any]] = None,
             clear_wal: bool = True) -> int:
        """Commit a new generation atomically. Returns the generation id."""
        with self._lock:
            gen = (self.latest() or 0) + 1
            stage = tempfile.mkdtemp(prefix=f".stage-{gen}-", dir=self.root)
            try:
                arrays = {}
                for key, arr in state.items():
                    arr = np.asarray(arr)
                    fname = key.replace("/", "__") + f".shard{shard_id}.npy"
                    _save_array(os.path.join(stage, fname), arr)
                    arrays[key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "shard": shard_id}
                while True:
                    man = Manifest(generation=gen, step=step,
                                   created_unix=time.time(),
                                   num_shards=num_shards, arrays=arrays,
                                   wal_segments=[], extra=extra or {})
                    # manifest written last => staging dir valid only now
                    with open(os.path.join(stage, MANIFEST), "w") as f:
                        f.write(man.to_json())
                        f.flush()
                        os.fsync(f.fileno())
                    try:
                        os.rename(stage, self._gen_dir(gen))   # atomic publish
                        break
                    except OSError as e:
                        if e.errno not in (errno.ENOTEMPTY, errno.EEXIST):
                            raise          # real IO failure, not a gen race
                        # another store instance over the same root claimed
                        # this generation between latest() and rename — a
                        # committed gen dir is never empty, so the rename
                        # refuses; take the next slot and re-stamp
                        gen += 1
                _fsync_dir(self.root)
            except BaseException:
                shutil.rmtree(stage, ignore_errors=True)
                raise
            if clear_wal:
                self._clear_wal()
            self._gc()
            return gen

    def save_async(self, state: Dict[str, np.ndarray], **kw) -> threading.Thread:
        """Non-blocking commit: snapshot is taken synchronously (cheap — numpy
        copies), IO happens in a background thread (the async-checkpoint
        pattern: training never stalls on storage)."""
        snapshot = {k: np.array(v, copy=True) for k, v in state.items()}
        t = threading.Thread(target=self.save, args=(snapshot,), kwargs=kw,
                             daemon=True)
        t.start()
        with self._threads_lock:
            self._async_threads.append(t)
        return t

    def wait_async(self) -> None:
        # snapshot under the lock, join OUTSIDE it: the background save()
        # acquires the commit lock, and holding any store lock across a
        # join invites an order cycle with it
        with self._threads_lock:
            threads, self._async_threads = self._async_threads, []
        for t in threads:
            t.join()

    def _gc(self) -> None:
        gens = self.generations()
        for g in gens[: max(0, len(gens) - self.keep)]:
            shutil.rmtree(self._gen_dir(g), ignore_errors=True)

    # ------------------------------------------------------------- load
    def load(self, gen: Optional[int] = None) -> Dict[str, np.ndarray]:
        gen = gen if gen is not None else self.latest()
        if gen is None:
            raise FileNotFoundError(f"no committed generation under {self.root}")
        gdir = self._gen_dir(gen)
        with open(os.path.join(gdir, MANIFEST)) as f:
            man = Manifest.from_json(f.read())
        return {key: _load_array(os.path.join(gdir, info["file"]))
                for key, info in man.arrays.items()}

    def manifest(self, gen: Optional[int] = None) -> Manifest:
        gen = gen if gen is not None else self.latest()
        if gen is None:
            raise FileNotFoundError(
                f"no committed generation under {self.root}")
        with open(os.path.join(self._gen_dir(gen), MANIFEST)) as f:
            return Manifest.from_json(f.read())

    # ------------------------------------------------------------- WAL
    def wal_append(self, vectors: np.ndarray,
                   metadata_json: Optional[str] = None) -> str:
        """Persist an insert batch; replayed on recovery until next commit."""
        fname = os.path.join(
            self.wal_dir, f"wal-{time.time_ns():020d}.npz")
        tmp = fname + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, vectors=np.asarray(vectors, dtype=np.float32),
                     metadata=np.array(metadata_json or "null"))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, fname)
        return fname

    def wal_replay(self) -> List[Dict[str, Any]]:
        out = []
        for name in sorted(os.listdir(self.wal_dir)):
            if not name.endswith(".npz"):
                continue
            with np.load(os.path.join(self.wal_dir, name),
                         allow_pickle=True) as z:
                meta = json.loads(str(z["metadata"]))
                out.append({"vectors": z["vectors"], "metadata": meta})
        return out

    def _clear_wal(self) -> None:
        for name in os.listdir(self.wal_dir):
            if name.endswith(".npz"):
                os.remove(os.path.join(self.wal_dir, name))


def replay_wal_into(store: "CheckpointStore", engine) -> int:
    """Replay WAL insert batches into anything with `add(vectors, metadata)`
    (a `QuantixarEngine`, typically restored via `from_state_dict`).

    With the segmented write path the replayed rows land in the engine's
    delta segment: crash recovery = load last generation + replay — no
    quantizer retraining and no sealed-graph rebuild.  Returns rows replayed.
    """
    n = 0
    for seg in store.wal_replay():
        engine.add(seg["vectors"], seg["metadata"])
        n += len(seg["vectors"])
    return n


# ---------------------------------------------------------------------------
# Elastic resharding (row-partitioned corpora)
# ---------------------------------------------------------------------------

def reshard_rows(shards: Sequence[np.ndarray], new_num: int) -> List[np.ndarray]:
    """N-shard row partition -> M-shard row partition (order-preserving)."""
    full = np.concatenate(list(shards), axis=0)
    bounds = np.linspace(0, len(full), new_num + 1).astype(int)
    return [full[bounds[i]: bounds[i + 1]] for i in range(new_num)]


class ShardedCheckpoint:
    """Per-shard stores + coordinator commit (multi-host posture).

    Each shard writes independently (parallel IO); `commit()` on the
    coordinator records the set of shard-generations that constitute one
    consistent global snapshot.
    """

    def __init__(self, root: str, num_shards: int, keep: int = 3):
        self.root = root
        self.num_shards = num_shards
        self.stores = [CheckpointStore(os.path.join(root, f"shard-{i:04d}"),
                                       keep=keep)
                       for i in range(num_shards)]
        os.makedirs(root, exist_ok=True)

    def save_shard(self, shard_id: int, state: Dict[str, np.ndarray],
                   step: int = 0) -> int:
        return self.stores[shard_id].save(
            state, step=step, shard_id=shard_id, num_shards=self.num_shards)

    def commit(self, step: int, shard_gens: Sequence[int]) -> None:
        doc = {"step": step, "unix": time.time(),
               "shard_generations": list(map(int, shard_gens)),
               "num_shards": self.num_shards}
        tmp = os.path.join(self.root, ".global.tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.root, "GLOBAL.json"))

    def load_global(self) -> Dict[str, Any]:
        with open(os.path.join(self.root, "GLOBAL.json")) as f:
            return json.load(f)

    def load_resharded(self, key: str, new_num: int) -> List[np.ndarray]:
        """Load array `key` from all shards and repartition to `new_num`."""
        glob = self.load_global()
        parts = [self.stores[i].load(glob["shard_generations"][i])[key]
                 for i in range(glob["num_shards"])]
        return reshard_rows(parts, new_num)
