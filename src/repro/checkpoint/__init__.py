"""Fault-tolerant checkpoint store (manifest + segments + WAL)."""

from .store import (CheckpointStore, Manifest, ShardedCheckpoint,
                    replay_wal_into, reshard_rows)
