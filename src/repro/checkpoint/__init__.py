"""Fault-tolerant checkpoint store (manifest + segments + WAL)."""

from .store import (CheckpointStore, Manifest, ShardedCheckpoint,
                    reshard_rows)
