"""Quantixar public API: schema-driven vector data management.

Embedded:

    from repro.api import (Database, CollectionSchema, VectorField,
                           KeywordField, NumericField)

    db = Database()
    col = db.create_collection(CollectionSchema(
        name="docs",
        vector=VectorField(dim=128, metric="cosine", index="hnsw"),
        fields=(KeywordField("lang"), NumericField("stars"))))
    col.upsert(["doc-1"], vec[None, :], [{"lang": "en", "stars": 4}])
    hits = col.query(q).filter(lang="en").where("stars", "ge", 3).run()

Over the wire (same surface, against `repro.serving.http`):

    from repro.api import QuantixarClient

    client = QuantixarClient("http://127.0.0.1:6333")
    col = client.collection("docs")
    hits = col.query(q).filter(lang="en").top_k(5).run()

Every search compiles to a declarative, wire-serializable `QueryPlan`
(`repro.api.plan`): single ANN passes, coarse-to-fine
`.stages(coarse_k=...)` plans, `.prefetch(...)`/`.fuse("rrf")` hybrid
queries, and `.explain()` introspection all run through the one staged
executor — embedded or remote.

The engine (`repro.core.engine.QuantixarEngine`) stays the internal
per-collection backend; this layer adds named collections, declarative typed
schemas, stable string ids with upsert/delete/compact semantics, a fluent
filtered query builder routed through the serving batcher, and the versioned
wire protocol (`repro.api.requests`) + HTTP client for the service plane.
"""

from ..cluster.sharded import ShardedCollection, ShardUnavailable
from ..core.metadata import And, Filter, Not, Or, Predicate
from .client import QuantixarClient, RemoteCollection
from .collection import (Collection, CollectionClosed, Entity,
                         QueryRetriesExhausted)
from .database import Database
from .plan import (AnnStage, FusionStage, PlanExplain, PrefetchStage,
                   QueryPlan, RescoreStage, SparseStage, plan_from_dict,
                   plan_to_dict)
from .query import Hit, Query
from .requests import (ApiError, ErrorInfo, RemoteInvalidArgument,
                       RemoteNotFound, RemoteSchemaError, RemoteUnavailable)
from .schema import (BatcherConfig, BoolField, CollectionSchema, KeywordField,
                     MetadataField, NumericField, SchemaError, TextField,
                     VectorField)

__all__ = [
    "And", "Filter", "Not", "Or", "Predicate",
    "Collection", "CollectionClosed", "Entity", "Database", "Hit", "Query",
    "QueryRetriesExhausted", "ShardedCollection", "ShardUnavailable",
    "AnnStage", "FusionStage", "PlanExplain", "PrefetchStage", "QueryPlan",
    "RescoreStage", "SparseStage", "plan_from_dict", "plan_to_dict",
    "QuantixarClient", "RemoteCollection",
    "ApiError", "ErrorInfo", "RemoteInvalidArgument", "RemoteNotFound",
    "RemoteSchemaError", "RemoteUnavailable",
    "BatcherConfig", "BoolField", "CollectionSchema", "KeywordField",
    "MetadataField", "NumericField", "SchemaError", "TextField",
    "VectorField",
]
