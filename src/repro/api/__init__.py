"""Quantixar public API: schema-driven vector data management.

    from repro.api import (Database, CollectionSchema, VectorField,
                           KeywordField, NumericField)

    db = Database()
    col = db.create_collection(CollectionSchema(
        name="docs",
        vector=VectorField(dim=128, metric="cosine", index="hnsw"),
        fields=(KeywordField("lang"), NumericField("stars"))))
    col.upsert(["doc-1"], vec[None, :], [{"lang": "en", "stars": 4}])
    hits = col.query(q).filter(lang="en").where("stars", "ge", 3).run()

The engine (`repro.core.engine.QuantixarEngine`) stays the internal
per-collection backend; this layer adds named collections, declarative typed
schemas, stable string ids with upsert/delete/compact semantics, and a
fluent filtered query builder routed through the serving batcher.
"""

from ..core.metadata import And, Filter, Not, Or, Predicate
from .collection import Collection, Entity
from .database import Database
from .query import Hit, Query
from .schema import (BoolField, CollectionSchema, KeywordField,
                     MetadataField, NumericField, SchemaError, VectorField)

__all__ = [
    "And", "Filter", "Not", "Or", "Predicate",
    "Collection", "Entity", "Database", "Hit", "Query",
    "BoolField", "CollectionSchema", "KeywordField", "MetadataField",
    "NumericField", "SchemaError", "VectorField",
]
