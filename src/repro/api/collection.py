"""`Collection`: schema-driven entity store over a `QuantixarEngine`.

The engine speaks positional row ids over an append-only corpus; the
collection owns the mapping to stable string ids with `upsert`/`get`/
`delete` semantics:

  * upsert of an existing id tombstones the old row and appends a new one
    (HNSW is build-once, so in-place mutation is not possible);
  * deletes are tombstones — dead rows stay in the index but are masked out
    of every search via the engine's row-mask hook;
  * `compact()` rebuilds the engine from live rows only, reclaiming the
    space and graph quality lost to tombstones.

Every read goes through ONE execution path: the fluent `Query` (and the
legacy `search`/`search_ids` array API) compiles to a declarative
`QueryPlan` which `execute_plan` runs — trivial single-vector plans
coalesce through the per-collection `RequestBatcher` into padded engine
batches, everything else (2-D batches, multi-stage coarse-to-fine plans,
prefetch + fusion, `explain`) executes under the collection lock via the
staged `PlanExecutor`.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.engine import QuantixarEngine
from ..core.executor import AnnParams, ExecResult, PlanExecutor
from ..core.metadata import Filter
from ..core.sparse import SparseIndex
from ..serving.batcher import RequestBatcher
from .plan import (AnnStage, PlanExplain, QueryPlan, plan_to_dict,
                   recommend_vector, validate_filter, validate_plan)
from .query import Hit, Query
from .schema import BatcherConfig, CollectionSchema, SchemaError


@dataclasses.dataclass
class Entity:
    """One stored entity: string id, vector, validated payload."""

    id: str
    vector: np.ndarray
    payload: Dict[str, Any]


class CollectionClosed(RuntimeError):
    """Query raced close()/drop: the batcher is gone and must not be
    resurrected.  Typed so the service plane maps it to UNAVAILABLE."""


class QueryRetriesExhausted(RuntimeError):
    """Every retry of a batched query was invalidated by a concurrent
    compact(); the caller saw no stale data, just no answer — retryable."""


def _as_id_list(ids: Union[str, Sequence[str]]) -> List[str]:
    ids = [ids] if isinstance(ids, str) else list(ids)
    for i in ids:
        if not isinstance(i, str) or not i:
            raise SchemaError(f"ids must be non-empty strings, got {i!r}")
    return ids


class Collection:
    def __init__(self, schema: CollectionSchema):
        self.schema = schema
        self._engine = QuantixarEngine(     # guarded-by: _lock
            schema.vector.to_engine_config())
        # one BM25 inverted index per TextField, row-aligned with the engine
        self._sparse = {f.name: SparseIndex(f.tokenizer())  # guarded-by: _lock
                        for f in schema.text_fields()}
        self._ids: List[str] = []        # guarded-by: _lock (row -> id)
        self._live: List[bool] = []      # guarded-by: _lock (row liveness)
        self._row_of: Dict[str, int] = {}   # guarded-by: _lock (live id->row)
        self._batcher: Optional[RequestBatcher] = None  # guarded-by: _batcher_init_lock
        self._batcher_init_lock = threading.Lock()
        # close() holds BOTH locks while flipping this, so a reader under
        # either lock observes the final value
        self._closed = False    # guarded-by: _lock|_batcher_init_lock
        self._mask: Optional[np.ndarray] = None   # guarded-by: _lock
        self._epoch = 0        # guarded-by: _lock (compact renumbers rows)
        # one engine is shared between caller threads (2-D queries, writes)
        # and the batcher worker (1-D queries); its lazy rebuild and chunk
        # concatenation are not thread-safe, so serialize around it
        self._lock = threading.RLock()

    # ------------------------------------------------------------ properties
    @property
    def name(self) -> str:
        return self.schema.name

    def __len__(self) -> int:
        """Number of live entities."""
        with self._lock:
            return len(self._row_of)

    @property
    def tombstones(self) -> int:
        """Dead rows still occupying the index (reclaim via `compact()`)."""
        with self._lock:
            return len(self._ids) - len(self._row_of)

    def __contains__(self, id: str) -> bool:
        with self._lock:
            return id in self._row_of

    @property
    def epoch(self) -> int:
        """Row-numbering generation: bumped by every `compact()` that drops
        tombstones.  Callers that translate engine rows outside the lock
        (the batcher path, shard scatter-gather) snapshot this before the
        search and re-check it before trusting the row numbers."""
        with self._lock:
            return self._epoch

    def ids(self) -> List[str]:
        """Live ids in insertion order."""
        with self._lock:
            return [i for i, alive in zip(self._ids, self._live) if alive]

    # ---------------------------------------------------------------- writes
    def upsert(self, ids: Union[str, Sequence[str]],
               vectors: np.ndarray,
               payloads: Optional[Sequence[Optional[Dict[str, Any]]]] = None,
               ) -> int:
        """Insert or replace entities by string id.  Returns rows written.

        Payloads are validated against the schema (typed fields, required
        fields, unknown-key rejection) before anything is stored.
        """
        ids = _as_id_list(ids)
        if len(set(ids)) != len(ids):
            raise SchemaError("duplicate ids within one upsert batch")
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        if vectors.ndim != 2 or vectors.shape[1] != self.schema.vector.dim:
            raise SchemaError(
                f"expected ({len(ids)}, {self.schema.vector.dim}) vectors, "
                f"got {vectors.shape}")
        if len(vectors) != len(ids):
            raise SchemaError(f"{len(ids)} ids but {len(vectors)} vectors")
        if payloads is None:
            payloads = [None] * len(ids)
        if len(payloads) != len(ids):
            raise SchemaError(f"{len(ids)} ids but {len(payloads)} payloads")
        # validate everything before mutating anything
        validated = [self.schema.validate_payload(p) for p in payloads]

        with self._lock:
            row0 = len(self._ids)
            self._engine.add(vectors, validated)
            for name, index in self._sparse.items():
                # one entry per row (None for rows without the field) keeps
                # sparse row ids aligned with engine rows
                index.add([p.get(name) for p in validated])
            for off, id_ in enumerate(ids):
                old = self._row_of.pop(id_, None)
                if old is not None:
                    self._live[old] = False      # replaced -> tombstone
                self._ids.append(id_)
                self._live.append(True)
                self._row_of[id_] = row0 + off
            self._mask = None
            return len(ids)

    def delete(self, ids: Union[str, Sequence[str]]) -> int:
        """Tombstone entities by id; unknown ids are ignored.  Returns the
        number actually deleted."""
        n = 0
        with self._lock:
            for id_ in _as_id_list(ids):
                row = self._row_of.pop(id_, None)
                if row is not None:
                    self._live[row] = False
                    n += 1
            self._mask = None
        return n

    def seal(self) -> None:
        """Fold the engine's delta segment into the sealed index and seal
        every sparse index — `compact()`'s no-tombstone fast path, exposed
        so shard owners can merge segments without a row renumber."""
        with self._lock:
            self._engine.seal()
            for index in self._sparse.values():
                index.seal()

    def compact(self) -> int:
        """Rebuild the engine over live rows only (drops tombstones, restores
        graph quality).  Returns the number of rows reclaimed.

        With no tombstones to reclaim this still folds the engine's delta
        segment into the sealed index (`QuantixarEngine.seal()`), so
        `compact()` doubles as the explicit merge hook of the segmented
        write path."""
        with self._lock:
            dead = self.tombstones
            if dead == 0:
                self._engine.seal()
                for index in self._sparse.values():
                    index.seal()
                return 0
            live_rows = [r for r, alive in enumerate(self._live) if alive]
            vectors = self._engine.vectors[live_rows]
            payloads = [self._engine.metadata.record(r) for r in live_rows]
            live_ids = [self._ids[r] for r in live_rows]

            self._engine = QuantixarEngine(
                self.schema.vector.to_engine_config())
            # text payloads ride in the metadata records, so re-upserting
            # rebuilds the sparse indexes over live rows automatically
            self._sparse = {f.name: SparseIndex(f.tokenizer())
                            for f in self.schema.text_fields()}
            self._ids, self._live, self._row_of = [], [], {}
            self._mask = None
            self._epoch += 1   # all row numbers just changed
            if live_ids:
                self.upsert(live_ids, vectors, payloads)
            return dead

    # ----------------------------------------------------------------- reads
    def get(self, id: str) -> Optional[Entity]:
        with self._lock:
            row = self._row_of.get(id)
            if row is None:
                return None
            return Entity(id=id, vector=self._engine.vectors[row].copy(),
                          payload=self._engine.metadata.record(row))

    def query(self, vector: Optional[np.ndarray] = None) -> Query:
        """Start a fluent query: `col.query(v).filter(...).top_k(5).run()`.
        With no vector, chain `.text("...")` for a pure keyword (BM25)
        search; with both, the query fuses dense + sparse (hybrid)."""
        return Query(self, vector)

    def recommend(self, positives: Sequence[Any],
                  negatives: Sequence[Any] = ()) -> Query:
        """Start a fluent query whose vector is synthesized from example
        entities (ids or raw vectors): mean(positives) - mean(negatives)."""
        return Query(self, recommend_vector(self, positives, negatives))

    def count(self, flt: Optional[Filter] = None) -> int:
        """Filtered cardinality: live entities matching `flt` (all live
        entities when None) — no hits fetched, no vector work."""
        if flt is not None:
            flt = validate_filter(self.schema, flt)
        with self._lock:
            if self._closed:
                raise CollectionClosed(
                    f"collection {self.name!r} is closed")
            if flt is None or len(self._row_of) == 0:
                # empty collection: nothing matches — don't let the
                # metadata store raise on columns it has never seen
                return len(self._row_of)
            mask = self._engine.metadata.evaluate(flt)
            live = self._live_mask()
            if live is not None:
                mask = mask & live
            return int(np.asarray(mask, dtype=bool).sum())

    def search(self, vectors: np.ndarray, k: int,
               flt: Optional[Filter] = None, ef: Optional[int] = None,
               rescore: Optional[bool] = None,
               expansion_width: Optional[int] = None,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Engine-level batch search with tombstones masked out.  Returns
        (distances, rows) — use `query()` for string-id `Hit` results.

        Compiles to a trivial single-stage plan, so the array API runs the
        same execution path as the fluent/wire queries.  An empty
        collection answers with the engine's padding convention (all-inf
        distances, row -1) instead of raising, so shard fan-outs and the
        serving plane see "no results", not an error."""
        if flt is not None:
            flt = validate_filter(self.schema, flt)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        plan = QueryPlan(k=k, vector=np.asarray(vectors, np.float32),
                         stages=(AnnStage(k=k, ef=ef,
                                          expansion_width=expansion_width,
                                          filter=flt, rescore=rescore),))
        with self._lock:
            res = self._execute_direct(plan)
        return res.distances, res.ids

    def search_ids(self, vectors: np.ndarray, k: int, **kw
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Like `search` but returns string ids (object array; None = empty
        slot) — the shape shard fan-out / cross-collection merges consume."""
        with self._lock:
            d, rows = self.search(vectors, k, **kw)
            ids = np.empty(rows.shape, dtype=object)
            for idx, row in np.ndenumerate(rows):
                # inf distance = padded/masked slot the engine only
                # demoted; its row number must not leak out as a real id
                ids[idx] = (self._ids[int(row)]
                            if row >= 0 and np.isfinite(d[idx]) else None)
            return d, ids

    # ------------------------------------------------------------- internals
    def _live_mask(self) -> Optional[np.ndarray]:  # requires-lock: _lock
        if self.tombstones == 0:
            return None
        if self._mask is None:        # invalidated by every write
            self._mask = np.asarray(self._live, dtype=bool)
        return self._mask

    def _engine_search(self, queries, k, flt=None,
                       params: Optional[AnnParams] = None):
        """One masked first-pass engine search — the ANN primitive both the
        serving batcher and the plan executor call.  Per-query knobs arrive
        as a single `AnnParams` struct instead of parallel keyword lists."""
        with self._lock:
            if len(self._row_of) == 0:
                # empty collection = empty result, not an error: pad with
                # the engine's masked-slot convention (inf distance, row -1)
                if k < 1:
                    raise ValueError(f"k must be >= 1, got {k}")
                n = 1 if queries.ndim == 1 else len(queries)
                return (np.full((n, k), np.inf, dtype=np.float32),
                        np.full((n, k), -1, dtype=np.int64))
            k = min(k, len(self._row_of))
            return self._engine.search(queries, k, flt=flt,
                                       mask=self._live_mask(),
                                       params=params)

    def _sparse_search(self, field: str, text: str, k: int,
                       flt: Optional[Filter] = None, stats=None
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """One masked BM25 pass over a text field's inverted index — the
        sparse twin of `_engine_search`.  Returns (1, k) padded candidate
        arrays whose distances are negated BM25 scores (lower = better).
        `stats` substitutes shard-aggregated corpus statistics so a
        scattered search scores with global IDF/norms, not local ones."""
        with self._lock:
            index = self._sparse.get(field)
            if index is None:       # validate_plan resolves fields first
                raise SchemaError(f"collection {self.name!r} has no text "
                                  f"field {field!r}")
            mask = self._live_mask()
            if flt is not None:
                fmask = self._engine.metadata.evaluate(flt)
                mask = fmask if mask is None else (mask & fmask)
            d, rows = index.search(text, k, mask=mask, stats=stats)
            return d[None, :], rows[None, :]

    def _sparse_term_stats(self, field: str, text: str):
        """Local corpus statistics `(docs_with_text, total_tokens, df)` for
        the query's tokens — the gather leg of distributed BM25
        (`CorpusStats.aggregate` sums these across shards)."""
        with self._lock:
            index = self._sparse.get(field)
            if index is None:
                raise SchemaError(f"collection {self.name!r} has no text "
                                  f"field {field!r}")
            return index.term_stats(index.config.tokenize(text))

    def _rescore_local(self, queries: np.ndarray, rows: np.ndarray, k: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact-rescore a candidate row set against full-precision vectors
        (tombstones masked) — the per-shard leg of a scattered rescore."""
        with self._lock:
            return self._engine.exact_rescore(queries, rows, k,
                                              mask=self._live_mask())

    def _execute_direct(self, plan: QueryPlan,  # requires-lock: _lock
                        deadline: Optional[float] = None) -> ExecResult:
        """Run a plan through the staged executor (caller holds the lock)."""
        if self._closed:
            # parity with the batcher path: a dropped collection must
            # refuse direct-path queries too, not serve its stale engine
            raise CollectionClosed(f"collection {self.name!r} is closed")
        if len(self._row_of) == 0:
            n = len(np.asarray(plan.vector)) if plan.batched else 1
            return ExecResult(
                distances=np.full((n, plan.k), np.inf, dtype=np.float32),
                ids=np.full((n, plan.k), -1, dtype=np.int64),
                stages=[])
        executor = PlanExecutor(self._engine_search, self._engine,
                                mask=self._live_mask(),
                                sparse_fn=(self._sparse_search
                                           if self._sparse else None))
        return executor.execute(plan, deadline=deadline)

    @property
    def batcher(self) -> RequestBatcher:
        """Lazily-started serving batcher (single-vector query path); its
        batch size/deadline come from the schema's `BatcherConfig`.

        Creation is locked — concurrent first queries (e.g. parallel HTTP
        threads) must share one batcher, not leak a second worker whose
        counters and requests vanish — but the hot path stays lock-free so
        submits keep enqueueing while the worker (which takes the collection
        lock to search) is mid-batch."""
        # _batcher only ever goes None -> instance (close() nulls it, but
        # post-close submits fail typed anyway), so a stale fast-path read
        # just falls through to the locked slow path
        batcher = self._batcher  # unguarded-ok: lock-free fast path, re-checked under init lock
        if batcher is None:
            with self._batcher_init_lock:
                if self._closed:     # don't resurrect past close()/drop —
                    raise CollectionClosed(   # that leaks a worker thread
                        f"collection {self.name!r} is closed")
                batcher = self._batcher
                if batcher is None:
                    cfg = self.schema.batcher or BatcherConfig()
                    batcher = RequestBatcher(self._engine_search,
                                             max_batch=cfg.max_batch,
                                             max_wait_ms=cfg.max_wait_ms)
                    self._batcher = batcher
        return batcher

    def _hits_for(self, d: np.ndarray, rows: np.ndarray,
                  include_vector: bool) -> List[Hit]:
        hits = []
        with self._lock:
            for dist, row in zip(d, rows):
                row = int(row)
                if row < 0 or not np.isfinite(dist):
                    continue                    # padded / masked-out slot
                hits.append(Hit(
                    id=self._ids[row], score=float(dist),
                    payload=self._engine.metadata.record(row),
                    vector=(self._engine.vectors[row].copy()
                            if include_vector else None)))
        return hits

    def hits_at(self, d: np.ndarray, rows: np.ndarray,
                include_vector: bool = False, *,
                epoch: Optional[int] = None) -> Optional[List[Optional[Hit]]]:
        """Position-preserving row->Hit translation: one entry per input
        slot, `None` where the slot is padded/masked.  With `epoch` given,
        returns `None` (whole call) if a compact() renumbered rows since the
        caller snapshotted that epoch — the shard scatter-gather path
        retries instead of serving hits for the wrong entities."""
        out: List[Optional[Hit]] = []
        with self._lock:
            if epoch is not None and self._epoch != epoch:
                return None
            for dist, row in zip(d, rows):
                row = int(row)
                if row < 0 or not np.isfinite(dist):
                    out.append(None)
                    continue
                out.append(Hit(
                    id=self._ids[row], score=float(dist),
                    payload=self._engine.metadata.record(row),
                    vector=(self._engine.vectors[row].copy()
                            if include_vector else None)))
        return out

    def execute_plan(self, plan: QueryPlan, *, include_vector: bool = False,
                     timeout: float = 120.0, explain: bool = False
                     ) -> Union[List[Hit], List[List[Hit]], PlanExplain]:
        """THE read path: every query — fluent builder, wire `Search` op,
        legacy array API — arrives here as a declarative plan.

        Trivial single-vector plans (one plain ANN stage) coalesce through
        the serving batcher; batches, multi-stage plans, and `explain`
        execute directly via the staged `PlanExecutor` under the collection
        lock.  `timeout` bounds queue-wait on the batcher path and is
        enforced at stage boundaries on the direct path (an in-flight
        stage itself is not interrupted).  With `explain=True` the result
        is a `PlanExplain` carrying the compiled plan, per-stage candidate
        counts/timings, and hits."""
        plan = validate_plan(self.schema, plan)
        if plan.trivial and not plan.batched and not explain:
            # single query: coalesce through the serving batcher.  The
            # future resolves outside the lock, so a concurrent compact()
            # could renumber rows before translation — detect via the epoch
            # and retry.
            stage = plan.stages[0]
            vec = np.asarray(plan.vector, dtype=np.float32)
            params = AnnParams.or_none(ef=stage.ef,
                                       expansion_width=stage.expansion_width,
                                       rescore=stage.rescore)
            for _ in range(5):
                epoch = self._epoch  # unguarded-ok: optimistic read, re-validated under _lock below
                fut = self.batcher.submit(vec, plan.k, flt=stage.filter,
                                          params=params)
                d, rows = fut.result(timeout=timeout)
                with self._lock:
                    if self._epoch == epoch:
                        return self._hits_for(d, rows, include_vector)
            raise QueryRetriesExhausted(
                f"collection {self.name!r} kept compacting during the query")
        deadline = time.perf_counter() + timeout
        with self._lock:   # rows stay valid until translated to ids
            res = self._execute_direct(plan, deadline=deadline)
            if plan.batched:
                hits: Any = [self._hits_for(res.distances[i], res.ids[i],
                                            include_vector)
                             for i in range(len(res.ids))]
            else:
                hits = self._hits_for(res.distances[0], res.ids[0],
                                      include_vector)
        if explain:
            return PlanExplain(plan=plan_to_dict(plan), stages=res.stages,
                               hits=hits)
        return hits

    def close(self) -> None:
        # lock order: _lock, then _batcher_init_lock (the traced-lock fuzz
        # harness checks this graph stays acyclic; no path acquires them in
        # the reverse order while holding the first).  Holding both means
        # direct-path queries (under _lock) and batcher resurrection (under
        # _batcher_init_lock) each see _closed flip atomically.
        with self._lock:
            with self._batcher_init_lock:
                self._closed = True
                batcher, self._batcher = self._batcher, None
        # join the worker outside both locks: it takes _lock to search
        if batcher is not None:
            batcher.close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out = self._engine.stats()
            out.update({"name": self.name, "live": len(self),
                        "tombstones": self.tombstones})
            sparse_agg = [idx.stats() for idx in self._sparse.values()]
        # serving counters: all-zero until the batcher path first runs.
        # snapshot the attribute — a concurrent close() may null it between
        # the check and the call
        batcher = self._batcher  # unguarded-ok: atomic snapshot; batcher.stats() is safe post-close
        serving = (batcher.stats() if batcher is not None
                   else RequestBatcher.zero_stats())
        out.update({f"serving_{k}": v for k, v in serving.items()})
        if sparse_agg:
            agg = sparse_agg
            out.update({
                "sparse_fields": len(agg),
                "sparse_docs_indexed": sum(s["docs_indexed"] for s in agg),
                "sparse_vocab": sum(s["vocab"] for s in agg),
                "sparse_postings": sum(s["postings"] for s in agg),
                "sparse_sealed_postings": sum(s["sealed_postings"]
                                              for s in agg),
                "sparse_delta_postings": sum(s["delta_postings"]
                                             for s in agg),
                "sparse_seals": sum(s["seals"] for s in agg),
            })
        return out

    def shard_stats(self) -> List[Dict[str, Any]]:
        """Per-shard breakdown; a plain collection is one shard of one
        replica, so the wire `ShardStats` op answers uniformly."""
        with self._lock:
            rows = len(self._ids)
            live = len(self._row_of)
        batcher = self._batcher  # unguarded-ok: atomic snapshot; batcher.stats() is safe post-close
        depth = (batcher.stats()["queue_depth"] if batcher is not None else 0)
        return [{"shard": 0, "replicas": 1, "rows": rows, "live": live,
                 "tombstones": rows - live, "queue_depth": depth,
                 "slots": None}]

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, np.ndarray]:
        with self._lock:
            state = dict(self._engine.state_dict())
            state["__ids__"] = np.asarray(self._ids, dtype=object)
            state["__live__"] = np.asarray(self._live, dtype=bool)
            # "__sparse__" prefix keeps these out of the engine sub-state;
            # the packed form preserves the sealed/delta split, so a
            # loaded index keeps absorbing upserts without a rebuild
            for name, index in self._sparse.items():
                for key, arr in index.state_dict().items():
                    state[f"__sparse__{name}/{key}"] = arr
            return state

    @classmethod
    def from_state_dict(cls, schema: CollectionSchema,
                        state: Dict[str, np.ndarray]) -> "Collection":
        col = cls.__new__(cls)
        col.schema = schema
        engine_state = {k: v for k, v in state.items()
                        if not k.startswith("__")}
        col._engine = QuantixarEngine.from_state_dict(
            schema.vector.to_engine_config(), engine_state)
        sparse_state: Dict[str, Dict[str, np.ndarray]] = {}
        for key, arr in state.items():
            if key.startswith("__sparse__"):
                # index state keys carry no "/", so the last one separates
                # the field name from the array key
                name, sub = key[len("__sparse__"):].rsplit("/", 1)
                sparse_state.setdefault(name, {})[sub] = arr
        col._sparse = {}
        for fld in schema.text_fields():
            if fld.name in sparse_state:
                col._sparse[fld.name] = SparseIndex.from_state_dict(
                    sparse_state[fld.name], fld.tokenizer())
            else:
                # checkpoint predates the field (or was written without the
                # index): rebuild from the metadata records once, here
                index = SparseIndex(fld.tokenizer())
                records = col._engine.metadata
                index.add([records.record(r).get(fld.name)
                           for r in range(len(records))])
                col._sparse[fld.name] = index
        col._ids = [str(i) for i in state["__ids__"]]
        col._live = [bool(b) for b in state["__live__"]]
        col._row_of = {i: r for r, (i, alive)
                       in enumerate(zip(col._ids, col._live)) if alive}
        col._batcher = None
        col._batcher_init_lock = threading.Lock()
        col._closed = False
        col._mask = None
        col._epoch = 0
        col._lock = threading.RLock()
        return col
