"""`QuantixarClient`: the wire-protocol client mirroring `Database`.

The client's surface is deliberately isomorphic to the embedded API —
`create_collection` / `collection` / `drop_collection` / `list_collections`
on the client, `upsert` / `get` / `delete` / `query` / `compact` / `stats`
on `RemoteCollection` — so the same test scenarios run against either.
`RemoteCollection.query()` even reuses the embedded fluent `Query` builder:
validation (dims, filter ops, top_k, plan structure) happens client-side
against the cached schema, and only `execute_plan` differs — the compiled
`QueryPlan` ships as a `Search` request over HTTP instead of running
against a local engine, so multi-stage/fused/explain queries behave
identically on both sides.

Server failures arrive as structured `ErrorInfo` and are raised as
`ApiError` subclasses that keep exception parity with the embedded layer
(`RemoteSchemaError` is a `SchemaError`, `RemoteNotFound` a `KeyError`).
Stdlib-only: one keep-alive `http.client.HTTPConnection` per calling thread
(the server speaks HTTP/1.1), so benchmarks measure the request plane, not
per-request TCP setup.
"""

from __future__ import annotations

import dataclasses
import http.client
import json
import socket
import threading
from typing import Any, Dict, List, Optional, Sequence, Union
from urllib.parse import quote, urlsplit

import numpy as np

from ..core.metadata import Filter
from . import requests as rq
from .collection import Entity
from .plan import (PlanExplain, QueryPlan, plan_to_dict, recommend_vector,
                   validate_filter, validate_plan)
from .query import Hit, Query
from .schema import (BatcherConfig, CollectionSchema, MetadataField,
                     SchemaError, VectorField)


def _hit_from_dict(d: Dict[str, Any]) -> Hit:
    vector = d.get("vector")
    return Hit(id=d["id"], score=float(d["score"]),
               payload=d.get("payload") or {},
               vector=(np.asarray(vector, dtype=np.float32)
                       if vector is not None else None))


class QuantixarClient:
    """Thin HTTP client for a Quantixar server (`repro.serving.http`).

    `timeout` caps every request; `Query.run(timeout=...)` can tighten —
    never widen — it for one search (effective deadline is the minimum of
    the two).
    """

    def __init__(self, base_url: str, timeout: float = 120.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        parts = urlsplit(self.base_url if "://" in self.base_url
                         else f"http://{self.base_url}")
        if parts.scheme != "http" or parts.hostname is None:
            raise ValueError(f"expected an http://host:port URL, "
                             f"got {base_url!r}")
        self._host = parts.hostname
        self._port = parts.port or 80
        self._base_path = parts.path.rstrip("/")
        self._local = threading.local()      # one keep-alive conn per thread

    # ------------------------------------------------------------- transport
    def _conn(self, timeout: float) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self._host, self._port,
                                              timeout=timeout)
            self._local.conn = conn
        else:
            conn.timeout = timeout
            if conn.sock is not None:
                conn.sock.settimeout(timeout)
        return conn

    def _drop_conn(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
        self._local.conn = None

    def _call(self, method: str, path: str,
              body: Optional[Dict[str, Any]] = None,
              timeout: Optional[float] = None) -> Dict[str, Any]:
        effective = (self.timeout if timeout is None
                     else min(timeout, self.timeout))
        data = None if body is None else json.dumps(body).encode("utf-8")
        headers = {"Content-Type": "application/json"}
        # one retry on a fresh connection covers the stale-keep-alive case
        # (e.g. server restarted); our server never closes a connection
        # after accepting a request without sending its response, so the
        # retry cannot double-apply a write
        for attempt in (0, 1):
            conn = self._conn(effective)
            try:
                conn.request(method, self._base_path + path, body=data,
                             headers=headers)
                resp = conn.getresponse()
                status, raw = resp.status, resp.read()
                break
            except socket.timeout:
                self._drop_conn()
                raise rq.error_to_exception(rq.ErrorInfo(
                    rq.UNAVAILABLE,
                    f"request timed out after {effective}s"))
            except (ConnectionError, http.client.HTTPException,
                    OSError) as exc:
                self._drop_conn()
                if attempt:
                    raise rq.error_to_exception(rq.ErrorInfo(
                        rq.UNAVAILABLE, f"server unreachable: {exc}"))
        try:
            envelope = json.loads(raw.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            raise rq.error_to_exception(rq.ErrorInfo(
                rq.INTERNAL, f"HTTP {status}: non-JSON response body"))
        if not envelope.get("ok", False):
            raise rq.error_to_exception(
                rq.ErrorInfo.from_dict(envelope.get("error") or {}))
        return envelope.get("result") or {}

    # ------------------------------------------------------------ management
    def health(self) -> Dict[str, Any]:
        return self._call("GET", "/v1/healthz")

    def create_collection(
            self,
            schema: Optional[CollectionSchema] = None, *,
            name: Optional[str] = None,
            vector: Optional[VectorField] = None,
            fields: Sequence[MetadataField] = (),
            batcher: Optional[BatcherConfig] = None,
            shards: int = 1,
            replicas: int = 1) -> "RemoteCollection":
        if schema is None:
            if name is None or vector is None:
                raise SchemaError(
                    "pass a CollectionSchema or name= and vector=")
            schema = CollectionSchema(
                name=name, vector=vector, fields=tuple(fields),
                batcher=batcher, shards=shards, replicas=replicas)
        else:                          # parity with Database.create_collection
            if batcher is not None:
                schema = dataclasses.replace(schema, batcher=batcher)
            if shards != 1 or replicas != 1:
                schema = dataclasses.replace(schema, shards=shards,
                                             replicas=replicas)
        result = self._call("POST", "/v1/collections",
                            {"schema": schema.to_dict()})
        return RemoteCollection(
            self, CollectionSchema.from_dict(result["schema"]))

    def collection(self, name: str) -> "RemoteCollection":
        result = self._call("GET", f"/v1/collections/{quote(name, safe='')}")
        return RemoteCollection(
            self, CollectionSchema.from_dict(result["schema"]))

    __getitem__ = collection

    def __contains__(self, name: str) -> bool:
        return name in self.list_collections()

    def list_collections(self) -> List[str]:
        return list(self._call("GET", "/v1/collections")["collections"])

    def drop_collection(self, name: str) -> None:
        self._call("DELETE", f"/v1/collections/{quote(name, safe='')}")

    # ----------------------------------------------------------- persistence
    def snapshot(self, path: str, *, step: int = 0) -> int:
        """Server-side `Database.save` of every collection; returns the
        checkpoint generation id."""
        return int(self._call("POST", "/v1/snapshot",
                              {"path": path, "step": step})["generation"])

    def restore(self, path: str, *,
                generation: Optional[int] = None) -> List[str]:
        """Swap the served database for a snapshot generation; returns the
        restored collection names."""
        body: Dict[str, Any] = {"path": path}
        if generation is not None:
            body["generation"] = generation
        return list(self._call("POST", "/v1/restore", body)["collections"])

    def stats(self) -> Dict[str, Any]:
        return self._call("GET", "/v1/stats")["stats"]

    def close(self) -> None:
        """Close this thread's keep-alive connection (other threads'
        connections close with their threads)."""
        self._drop_conn()


class RemoteCollection:
    """Client-side handle mirroring `Collection`'s data-plane surface."""

    def __init__(self, client: QuantixarClient, schema: CollectionSchema):
        self._client = client
        self.schema = schema

    @property
    def name(self) -> str:
        return self.schema.name

    def _path(self, suffix: str = "") -> str:
        return f"/v1/collections/{quote(self.name, safe='')}{suffix}"

    # ---------------------------------------------------------------- writes
    def upsert(self, ids: Union[str, Sequence[str]],
               vectors: np.ndarray,
               payloads: Optional[Sequence[Optional[Dict[str, Any]]]] = None,
               ) -> int:
        ids = [ids] if isinstance(ids, str) else list(ids)
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        body: Dict[str, Any] = {"ids": ids, "vectors": vectors.tolist()}
        if payloads is not None:
            body["payloads"] = list(payloads)
        result = self._client._call("POST", self._path("/points"), body)
        return int(result["upserted"])

    def delete(self, ids: Union[str, Sequence[str]]) -> int:
        ids = [ids] if isinstance(ids, str) else list(ids)
        result = self._client._call("POST", self._path("/points/delete"),
                                    {"ids": ids})
        return int(result["deleted"])

    def compact(self, shard: Optional[int] = None) -> int:
        body: Dict[str, Any] = {} if shard is None else {"shard": shard}
        result = self._client._call("POST", self._path("/compact"), body)
        return int(result["reclaimed"])

    def rebalance(self, shards: Optional[int] = None,
                  replicas: Optional[int] = None) -> Dict[str, Any]:
        """Re-shard / re-replicate a sharded collection server-side
        (snapshot-based move; see `ShardedCollection.rebalance`)."""
        body: Dict[str, Any] = {}
        if shards is not None:
            body["shards"] = shards
        if replicas is not None:
            body["replicas"] = replicas
        return dict(self._client._call("POST", self._path("/rebalance"),
                                       body))

    def shard_stats(self) -> List[Dict[str, Any]]:
        """Per-shard rows/tombstones/queue-depth (single-shard collections
        report one pseudo-shard)."""
        return list(self._client._call("GET",
                                       self._path("/shards"))["shards"])

    # ----------------------------------------------------------------- reads
    def get(self, id: str) -> Optional[Entity]:
        entity = self._client._call(
            "GET", self._path(f"/points/{quote(id, safe='')}"))["entity"]
        if entity is None:
            return None
        return Entity(
            id=entity["id"],
            vector=np.asarray(entity.get("vector", ()), dtype=np.float32),
            payload=entity.get("payload") or {})

    def query(self, vector: Optional[np.ndarray] = None) -> Query:
        """The embedded fluent builder, executed over the wire.  Vectorless
        queries (`.query().text("...")`) compile to sparse keyword plans."""
        return Query(self, vector)

    def recommend(self, positives: Sequence[Any],
                  negatives: Sequence[Any] = ()) -> Query:
        """Fluent query from example entities (ids resolved over the wire,
        raw vectors used as-is): mean(positives) - mean(negatives)."""
        return Query(self, recommend_vector(self, positives, negatives))

    def count(self, flt: Optional[Filter] = None) -> int:
        """Filtered cardinality without fetching hits (wire `Count` op)."""
        body: Dict[str, Any] = {}
        if flt is not None:
            flt = validate_filter(self.schema, flt)
            body["filter"] = rq.filter_to_dict(flt)
        return int(self._client._call("POST", self._path("/count"),
                                      body)["count"])

    def stats(self) -> Dict[str, Any]:
        return self._client._call("GET", self._path("/stats"))["stats"]

    def __len__(self) -> int:
        return int(self.stats()["live"])

    def __contains__(self, id: str) -> bool:
        return self.get(id) is not None

    def close(self) -> None:
        """Parity no-op: server owns the collection's resources."""

    # ------------------------------------------------------------- internals
    def execute_plan(self, plan: QueryPlan, *, include_vector: bool = False,
                     timeout: float = 120.0, explain: bool = False):
        """`Query.run`/`Query.explain` backend: ship the compiled plan as
        one `Search` request (the wire twin of `Collection.execute_plan`)."""
        # client-side validation keeps error parity with the embedded API
        # (bad dims / unknown fields fail before any bytes hit the wire)
        plan = validate_plan(self.schema, plan)
        body: Dict[str, Any] = {"plan": plan_to_dict(plan)}
        if include_vector:
            body["include_vector"] = True
        if explain:
            body["explain"] = True
        # honor Query.run(timeout=...) like the embedded Future.result does
        result = self._client._call("POST", self._path("/search"), body,
                                    timeout=timeout)
        raw = result["hits"]
        if plan.batched:
            hits = [[_hit_from_dict(h) for h in row] for row in raw]
        else:
            hits = [_hit_from_dict(h) for h in raw]
        if explain:
            echo = result.get("explain") or {}
            return PlanExplain(plan=echo.get("plan") or {},
                               stages=list(echo.get("stages") or ()),
                               hits=hits)
        return hits
