"""Fluent query builder + `Hit` result type for the Collection API.

    hits = (col.query(vec)
               .filter(category="news")
               .where("price", "lt", 50)
               .top_k(5)
               .ef(128)
               .include("vector")
               .run())

Filters are validated against the collection schema before execution (unknown
fields and kind-incompatible operators fail fast, instead of silently
matching nothing).  Single-vector queries are routed through the collection's
`RequestBatcher`; matrix queries go straight to the engine as one batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

import numpy as np

from ..core.metadata import And, Filter, Not, Or, Predicate
from .schema import FIELD_OPS, CollectionSchema, SchemaError


@dataclasses.dataclass
class Hit:
    """One search result: stable string id, distance score (lower = closer,
    in the collection metric), and the requested payload/vector."""

    id: str
    score: float
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    vector: Optional[np.ndarray] = None

    def __repr__(self):
        vec = "" if self.vector is None else f", vector[{len(self.vector)}]"
        return f"Hit(id={self.id!r}, score={self.score:.4f}{vec})"


def validate_filter(schema: CollectionSchema, flt: Filter) -> Filter:
    """Check every predicate in the tree against the schema's typed fields."""
    if isinstance(flt, Predicate):
        fld = schema.field(flt.column)          # raises on unknown column
        allowed = FIELD_OPS[fld.kind]
        if flt.op not in allowed:
            raise SchemaError(
                f"op {flt.op!r} not valid for {fld.kind} field "
                f"{flt.column!r}; allowed: {allowed}")
        if flt.op == "in":
            value = [fld.validate(v) for v in flt.value]
            return Predicate(flt.column, "in", tuple(value))
        return Predicate(flt.column, flt.op, fld.validate(flt.value))
    if isinstance(flt, (And, Or)):
        clauses = tuple(validate_filter(schema, c) for c in flt.clauses)
        return type(flt)(clauses)
    if isinstance(flt, Not):
        return Not(validate_filter(schema, flt.clause))
    raise SchemaError(f"not a filter: {flt!r}")


class Query:
    """Immutable-ish builder: every setter returns self for chaining."""

    def __init__(self, collection, vector: np.ndarray):
        self._col = collection
        self._vec = np.asarray(vector, dtype=np.float32)
        if self._vec.ndim not in (1, 2):
            raise SchemaError(
                f"query vector must be 1-D or 2-D, got {self._vec.shape}")
        if self._vec.shape[-1] != collection.schema.vector.dim:
            raise SchemaError(
                f"query dim {self._vec.shape[-1]} != collection dim "
                f"{collection.schema.vector.dim}")
        self._k = 10
        self._flt: Optional[Filter] = None
        self._ef: Optional[int] = None
        self._width: Optional[int] = None
        self._rescore: Optional[bool] = None
        self._include_vector = False

    # --------------------------------------------------------------- setters
    def filter(self, *clauses: Filter, **equals: Any) -> "Query":
        """AND the given filter trees (and `field=value` equality sugar)
        into the query's filter."""
        new: List[Filter] = list(clauses)
        new += [Predicate(col, "eq", val) for col, val in equals.items()]
        for clause in new:
            clause = validate_filter(self._col.schema, clause)
            self._flt = clause if self._flt is None else And(
                (self._flt, clause))
        return self

    def where(self, column: str, op: str, value: Any) -> "Query":
        """Sugar for `.filter(Predicate(column, op, value))`."""
        return self.filter(Predicate(column, op, value))

    def top_k(self, k: int) -> "Query":
        if k <= 0:
            raise SchemaError(f"top_k must be positive, got {k}")
        self._k = int(k)
        return self

    def ef(self, ef: int) -> "Query":
        """HNSW beam width for this query (recall/latency knob)."""
        self._ef = int(ef)
        return self

    def expansion_width(self, width: int) -> "Query":
        """Wide-beam HNSW expansion width for this query: candidates popped
        (and adjacency rows fused) per traversal iteration.  1 = classic
        single-pop; higher widths cut sequential loop trips ~width×."""
        if width < 1:
            raise SchemaError(
                f"expansion_width must be >= 1, got {width}")
        self._width = int(width)
        return self

    def rescore(self, on: bool = True) -> "Query":
        """Override the schema's exact-rescore setting for this query."""
        self._rescore = bool(on)
        return self

    def include(self, *what: str) -> "Query":
        """Opt into returning heavier attributes; currently `"vector"`."""
        for name in what:
            if name == "vector":
                self._include_vector = True
            elif name != "payload":           # payload always included
                raise SchemaError(f"cannot include {name!r}; "
                                  f"options: 'payload', 'vector'")
        return self

    # ------------------------------------------------------------- execution
    def run(self, timeout: float = 120.0
            ) -> Union[List[Hit], List[List[Hit]]]:
        """Execute.  1-D input -> List[Hit]; 2-D input -> List[List[Hit]]."""
        return self._col._run_query(
            self._vec, self._k, flt=self._flt, ef=self._ef,
            rescore=self._rescore, expansion_width=self._width,
            include_vector=self._include_vector, timeout=timeout)
