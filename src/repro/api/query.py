"""Fluent query builder + `Hit` result type for the Collection API.

    hits = (col.query(vec)
               .filter(category="news")
               .where("price", "lt", 50)
               .top_k(5)
               .ef(128)
               .include("vector")
               .run())

Every setter is **copy-on-write**: it returns a new `Query`, so a base
query can be reused between variants (or threads) without silently
accumulating filters.

`run()` no longer calls the engine directly — the builder *compiles* to a
declarative `QueryPlan` (see `repro.api.plan`) and hands it to the
collection's `execute_plan`, the single execution path shared by embedded
collections, the serving batcher, and the wire protocol.  Beyond the
classic single pass:

  * `.stages(coarse_k=...)` — coarse-to-fine: a raw code-domain first pass
    fetching `coarse_k` (default `oversample * k`) candidates, then an
    exact float rescore down to `k` (the explicit form of the engine's old
    `rescore=True` oversampling);
  * `.prefetch(vector=..., k=..., filter=...)` — add an independent
    sub-query; combine several with `.fuse("rrf")` or `.fuse("linear")`;
  * `.text("...")` — BM25 keyword search over a schema `TextField`.
    Alone (`col.query().text("...")`) it compiles to a pure sparse plan;
    with a query vector it becomes a hybrid plan — dense and sparse
    prefetch legs merged by RRF (or whatever `.fuse()` picked);
    `.prefetch(text=...)` adds further keyword legs explicitly;
  * `.explain()` — execute and return the compiled plan with per-stage
    candidate counts and timings (`PlanExplain`).

Filters are validated against the collection schema before execution
(unknown fields and kind-incompatible operators fail fast, instead of
silently matching nothing).
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.metadata import And, Filter, Predicate
from .plan import (AnnStage, FusionStage, PlanExplain, PrefetchStage,
                   QueryPlan, RescoreStage, SparseStage, validate_filter)
from .schema import SchemaError

__all__ = ["Hit", "Query", "validate_filter"]


@dataclasses.dataclass
class Hit:
    """One search result: stable string id, score, and the requested
    payload/vector.

    `score` is always "lower = closer", but its scale depends on the final
    plan stage: a distance in the collection metric for plain and rescored
    queries, a *negated RRF sum* for `.fuse("rrf")` results, and a min-max
    normalized weighted sum in [0, 1] for `.fuse("linear")` — fused scores
    rank hits but are NOT metric distances, so don't apply metric-space
    thresholds to them (add `.stages()` for exact final distances)."""

    id: str
    score: float
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    vector: Optional[np.ndarray] = None

    def __repr__(self):
        vec = "" if self.vector is None else f", vector[{len(self.vector)}]"
        return f"Hit(id={self.id!r}, score={self.score:.4f}{vec})"


@dataclasses.dataclass(frozen=True)
class _PrefetchSpec:
    """One `.prefetch()` call, compiled to a sub-plan at run time.  A spec
    is either dense (vector / ef / width knobs) or sparse (`text` set)."""

    vector: Optional[np.ndarray]      # None: reuse the root query vector
    k: Optional[int]                  # None: fusion stage k
    ef: Optional[int]
    expansion_width: Optional[int]
    filter: Optional[Filter]
    coarse_k: Optional[int]           # per-sub-plan coarse-to-fine
    text: Optional[str] = None        # set: this leg is a BM25 keyword pass
    text_field: Optional[str] = None  # None: the schema's single text field


class Query:
    """Immutable builder: every setter returns a new `Query` (copy-on-write),
    so base queries can be shared and specialized freely."""

    def __init__(self, collection, vector: Optional[np.ndarray] = None):
        self._col = collection
        self._vec: Optional[np.ndarray] = None
        if vector is not None:
            self._vec = np.asarray(vector, dtype=np.float32)
            if self._vec.ndim not in (1, 2):
                raise SchemaError(
                    f"query vector must be 1-D or 2-D, got {self._vec.shape}")
            if self._vec.shape[-1] != collection.schema.vector.dim:
                raise SchemaError(
                    f"query dim {self._vec.shape[-1]} != collection dim "
                    f"{collection.schema.vector.dim}")
        self._text: Optional[str] = None
        self._text_field: Optional[str] = None
        self._k = 10
        self._flt: Optional[Filter] = None
        self._ef: Optional[int] = None
        self._width: Optional[int] = None
        self._rescore: Optional[bool] = None
        self._include_vector = False
        self._coarse_k: Optional[int] = None
        self._oversample: Optional[int] = None
        self._prefetch: Tuple[_PrefetchSpec, ...] = ()
        self._fusion: Optional[FusionStage] = None

    def _clone(self) -> "Query":
        # all builder state is immutable (scalars, Filter trees, tuples),
        # so a shallow copy is a safe fork point
        return copy.copy(self)

    # --------------------------------------------------------------- setters
    def filter(self, *clauses: Filter, **equals: Any) -> "Query":
        """AND the given filter trees (and `field=value` equality sugar)
        into the query's filter."""
        q = self._clone()
        new: List[Filter] = list(clauses)
        new += [Predicate(col, "eq", val) for col, val in equals.items()]
        for clause in new:
            clause = validate_filter(self._col.schema, clause)
            q._flt = clause if q._flt is None else And((q._flt, clause))
        return q

    def where(self, column: str, op: str, value: Any) -> "Query":
        """Sugar for `.filter(Predicate(column, op, value))`."""
        return self.filter(Predicate(column, op, value))

    def text(self, text: str, field: Optional[str] = None) -> "Query":
        """BM25 keyword search over a schema `TextField`.  On a vectorless
        query (`col.query().text("...")`) this is the whole search; with a
        query vector it adds a sparse leg next to the dense one and the two
        are rank-fused (RRF unless `.fuse()` chose otherwise).  `field`
        defaults to the collection's single text field."""
        if not isinstance(text, str) or not text.strip():
            raise SchemaError(
                f"text() needs a non-empty string, got {text!r}")
        if field is not None and not isinstance(field, str):
            raise SchemaError(f"text field must be a string, got {field!r}")
        q = self._clone()
        q._text = text
        q._text_field = field
        return q

    def top_k(self, k: int) -> "Query":
        if k <= 0:
            raise SchemaError(f"top_k must be positive, got {k}")
        q = self._clone()
        q._k = int(k)
        return q

    def ef(self, ef: int) -> "Query":
        """HNSW beam width for this query (recall/latency knob)."""
        q = self._clone()
        q._ef = int(ef)
        return q

    def expansion_width(self, width: int) -> "Query":
        """Wide-beam HNSW expansion width for this query: candidates popped
        (and adjacency rows fused) per traversal iteration.  1 = classic
        single-pop; higher widths cut sequential loop trips ~width×."""
        if width < 1:
            raise SchemaError(
                f"expansion_width must be >= 1, got {width}")
        q = self._clone()
        q._width = int(width)
        return q

    def rescore(self, on: bool = True) -> "Query":
        """Override the schema's engine-internal rescore setting for this
        query.  Prefer `.stages()`, which makes the oversample explicit and
        shows up in `.explain()` as its own stage."""
        q = self._clone()
        q._rescore = bool(on)
        return q

    def stages(self, coarse_k: Optional[int] = None, *,
               oversample: Optional[int] = None) -> "Query":
        """Compile to an explicit coarse-to-fine plan: a raw (code-domain
        for quantized collections) first pass fetching `coarse_k`
        candidates, then an exact float rescore down to `top_k`.

        `coarse_k` defaults to `oversample * top_k` (oversample defaults
        to the schema's `rescore_multiplier`), resolved at run time."""
        if coarse_k is not None and coarse_k < 1:
            raise SchemaError(f"coarse_k must be >= 1, got {coarse_k}")
        if oversample is not None and oversample < 1:
            raise SchemaError(f"oversample must be >= 1, got {oversample}")
        q = self._clone()
        q._coarse_k = None if coarse_k is None else int(coarse_k)
        q._oversample = None if oversample is None else int(oversample)
        if q._coarse_k is None and q._oversample is None:
            q._oversample = int(self._col.schema.vector.rescore_multiplier)
        return q

    def prefetch(self, vector: Optional[np.ndarray] = None, *,
                 k: Optional[int] = None, ef: Optional[int] = None,
                 expansion_width: Optional[int] = None,
                 filter: Optional[Filter] = None,
                 coarse_k: Optional[int] = None,
                 text: Optional[str] = None,
                 text_field: Optional[str] = None,
                 **equals: Any) -> "Query":
        """Add one independent sub-query — dense (its own vector / filter /
        ef / width, optional per-sub-plan coarse-to-fine) or sparse
        (`text=...`, a BM25 pass over `text_field`).  Call repeatedly for
        several sub-queries and pick a merge with `.fuse(...)` (RRF is the
        default when prefetches are present)."""
        if text is not None:
            if not isinstance(text, str) or not text.strip():
                raise SchemaError(
                    f"prefetch text must be a non-empty string, got {text!r}")
            if vector is not None or ef is not None \
                    or expansion_width is not None or coarse_k is not None:
                raise SchemaError(
                    "a prefetch leg is dense or sparse, not both: 'text' "
                    "cannot combine with vector/ef/expansion_width/coarse_k")
        elif text_field is not None:
            raise SchemaError("prefetch 'text_field' needs 'text'")
        vec = None
        if vector is not None:
            vec = np.asarray(vector, dtype=np.float32)
            if vec.ndim != 1 or vec.shape[0] != self._col.schema.vector.dim:
                raise SchemaError(
                    f"prefetch vector must be 1-D of dim "
                    f"{self._col.schema.vector.dim}, got {vec.shape}")
        flt = filter
        for col_name, val in equals.items():
            pred = Predicate(col_name, "eq", val)
            flt = pred if flt is None else And((flt, pred))
        if flt is not None:
            flt = validate_filter(self._col.schema, flt)
        if k is not None and k < 1:
            raise SchemaError(f"prefetch k must be >= 1, got {k}")
        if coarse_k is not None and coarse_k < 1:
            raise SchemaError(f"prefetch coarse_k must be >= 1, "
                              f"got {coarse_k}")
        q = self._clone()
        q._prefetch = self._prefetch + (_PrefetchSpec(
            vector=vec, k=k, ef=ef, expansion_width=expansion_width,
            filter=flt, coarse_k=coarse_k, text=text,
            text_field=text_field),)
        return q

    def fuse(self, method: str = "rrf", *,
             weights: Optional[Sequence[float]] = None,
             rrf_k: int = 60) -> "Query":
        """Choose how prefetch sub-query results merge: `"rrf"`
        (reciprocal-rank fusion) or `"linear"` (min-max score-normalized
        weighted sum)."""
        q = self._clone()
        q._fusion = FusionStage(
            k=1, method=method,           # k is resolved at compile time
            weights=tuple(weights) if weights is not None else None,
            rrf_k=int(rrf_k))
        return q

    def include(self, *what: str) -> "Query":
        """Opt into returning heavier attributes; currently `"vector"`."""
        q = self._clone()
        for name in what:
            if name == "vector":
                q._include_vector = True
            elif name != "payload":           # payload always included
                raise SchemaError(f"cannot include {name!r}; "
                                  f"options: 'payload', 'vector'")
        return q

    # ----------------------------------------------------------- compilation
    def _coarse(self, k: int) -> Optional[int]:
        if self._coarse_k is not None:
            return max(self._coarse_k, k)
        if self._oversample is not None:
            return k * self._oversample
        return None

    def _compile(self) -> QueryPlan:
        """Builder state -> declarative `QueryPlan` tree."""
        k = self._k
        prefetch = self._prefetch
        if self._text is not None:
            if self._vec is None and not prefetch:
                # pure keyword search: one sparse stage is the whole plan
                if self._coarse_k is not None or self._oversample is not None:
                    raise SchemaError(
                        "stages() needs a query vector: rescoring keyword "
                        "hits is a vector-space operation")
                if self._rescore:
                    raise SchemaError(
                        "rescore() needs a query vector; keyword-only "
                        "queries have nothing to rescore against")
                if self._fusion is not None:
                    raise SchemaError(
                        "fuse() needs at least two search legs; a "
                        "keyword-only query has one")
                return QueryPlan(k=k, stages=(SparseStage(
                    text=self._text, k=k, field=self._text_field,
                    filter=self._flt),), vector=None)
            # hybrid: the root text becomes a sparse prefetch leg; without
            # explicit prefetches the dense leg is implicit — it inherits
            # the root vector (vector=None on the wire) and knobs
            sparse_spec = _PrefetchSpec(
                vector=None, k=None, ef=None, expansion_width=None,
                filter=None, coarse_k=None, text=self._text,
                text_field=self._text_field)
            if not prefetch:
                prefetch = (_PrefetchSpec(
                    vector=None, k=None, ef=None, expansion_width=None,
                    filter=None, coarse_k=None), sparse_spec)
            else:
                prefetch = prefetch + (sparse_spec,)
        if self._fusion is not None and not prefetch:
            raise SchemaError("fuse() needs at least one prefetch() "
                              "(or a hybrid .text() query)")
        if not prefetch:
            if self._vec is None:
                raise SchemaError(
                    "query needs a vector or text: pass a vector to "
                    "query(...) or add .text('...')")
            coarse = self._coarse(k)
            if coarse is None:                      # classic single pass
                stages: Tuple[Any, ...] = (AnnStage(
                    k=k, ef=self._ef, expansion_width=self._width,
                    filter=self._flt, rescore=self._rescore),)
            else:                                   # explicit coarse-to-fine
                stages = (AnnStage(k=coarse, ef=self._ef,
                                   expansion_width=self._width,
                                   filter=self._flt, rescore=False),
                          RescoreStage(k=k))
            return QueryPlan(k=k, stages=stages, vector=self._vec)

        if self._vec is not None and self._vec.ndim != 1:
            raise SchemaError("prefetch queries take a 1-D root vector")
        plans = []
        coarse = self._coarse(k)
        for spec in prefetch:
            # with .stages() on a fused query, the coarse pool must come
            # from the sub-queries: each fetches coarse-many raw candidates
            # (no engine-internal rescore) and the trailing RescoreStage
            # does the one exact pass after fusion
            sub_k = spec.k if spec.k is not None else (coarse or k)
            # the root filter is an invariant, not a default: a sub-query's
            # own filter narrows it further rather than replacing it
            if spec.filter is None:
                sub_flt = self._flt
            elif self._flt is None:
                sub_flt = spec.filter
            else:
                sub_flt = And((self._flt, spec.filter))
            if spec.text is not None:
                # sparse leg: the whole sub-plan is one BM25 pass fetching
                # the same oversampled pool size as its dense siblings
                plans.append(QueryPlan(k=sub_k, stages=(SparseStage(
                    text=spec.text, k=sub_k, field=spec.text_field,
                    filter=sub_flt),), vector=None))
                continue
            sub_ef = spec.ef if spec.ef is not None else self._ef
            sub_w = (spec.expansion_width if spec.expansion_width is not None
                     else self._width)
            if spec.coarse_k is not None:
                sub_stages: Tuple[Any, ...] = (
                    AnnStage(k=max(spec.coarse_k, sub_k), ef=sub_ef,
                             expansion_width=sub_w, filter=sub_flt,
                             rescore=False),
                    RescoreStage(k=sub_k))
            else:
                sub_rescore = False if coarse is not None else self._rescore
                sub_stages = (AnnStage(k=sub_k, ef=sub_ef,
                                       expansion_width=sub_w,
                                       filter=sub_flt,
                                       rescore=sub_rescore),)
            # sub-plans without their own vector inherit the root's at
            # execution time (vector=None on the wire), so an N-way
            # prefetch ships one vector copy, not N+1
            plans.append(QueryPlan(k=sub_k, stages=sub_stages,
                                   vector=spec.vector))
        fusion = self._fusion or FusionStage(k=k)
        stages = (PrefetchStage(plans=tuple(plans)),
                  dataclasses.replace(fusion, k=coarse or k))
        if coarse is not None:       # fused coarse set -> exact final rank
            stages = stages + (RescoreStage(k=k),)
        return QueryPlan(k=k, stages=stages, vector=self._vec)

    # ------------------------------------------------------------- execution
    def run(self, timeout: float = 120.0
            ) -> Union[List[Hit], List[List[Hit]]]:
        """Execute.  1-D input -> List[Hit]; 2-D input -> List[List[Hit]]."""
        return self._col.execute_plan(
            self._compile(), include_vector=self._include_vector,
            timeout=timeout)

    def explain(self, timeout: float = 120.0) -> PlanExplain:
        """Execute and return the compiled plan plus the executor's
        per-stage candidate counts and timings (embedded and over the wire
        report the same structure)."""
        return self._col.execute_plan(
            self._compile(), include_vector=self._include_vector,
            timeout=timeout, explain=True)
