"""Declarative collection schemas for the public API layer.

A `CollectionSchema` is the single source of truth for a collection: one
vector field (dim / metric / index / quantization and their tuning knobs)
plus typed metadata fields (keyword / numeric / bool) that are validated at
upsert time.  The schema compiles down to the engine's `EngineConfig` and
round-trips through plain dicts so `Database.save()` can persist it inside
the checkpoint manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from ..core.bq import BQConfig
from ..core.distances import available_metrics
from ..core.engine import EngineConfig
from ..core.hnsw_build import HNSWConfig
from ..core.ivf import IVFConfig
from ..core.pq import PQConfig
from ..core.sparse import TokenizerConfig

INDEXES = ("hnsw", "flat", "ivf")
QUANTIZATIONS = ("none", "pq", "bq")
BUILDERS = ("incremental", "bulk", "bulk_ref")

# column names the Collection layer reserves for itself
RESERVED_NAMES = ("id", "score", "vector")


class SchemaError(ValueError):
    """Invalid schema definition or payload that violates the schema."""


# --------------------------------------------------------------------- fields
@dataclasses.dataclass(frozen=True)
class MetadataField:
    """Base typed metadata field; subclasses define `kind` + type checking."""

    name: str
    required: bool = False
    kind = "abstract"

    def __post_init__(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise SchemaError(f"field name must be a non-empty str, "
                              f"got {self.name!r}")
        if self.name in RESERVED_NAMES:
            raise SchemaError(f"field name {self.name!r} is reserved")

    def validate(self, value: Any) -> Any:
        raise NotImplementedError

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name,
                "required": self.required}


@dataclasses.dataclass(frozen=True)
class KeywordField(MetadataField):
    """Exact-match string attribute (eq/ne/in filters)."""

    kind = "keyword"

    def validate(self, value: Any) -> str:
        if not isinstance(value, str):
            raise SchemaError(
                f"field {self.name!r} expects str, got {type(value).__name__}")
        return value


@dataclasses.dataclass(frozen=True)
class NumericField(MetadataField):
    """int/float attribute (full comparison-operator set)."""

    kind = "numeric"

    def validate(self, value: Any) -> float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise SchemaError(
                f"field {self.name!r} expects a number, "
                f"got {type(value).__name__}")
        return float(value)


@dataclasses.dataclass(frozen=True)
class BoolField(MetadataField):
    """Boolean attribute (eq/ne filters)."""

    kind = "bool"

    def validate(self, value: Any) -> bool:
        if not isinstance(value, bool):
            raise SchemaError(
                f"field {self.name!r} expects bool, "
                f"got {type(value).__name__}")
        return value


@dataclasses.dataclass(frozen=True)
class TextField(MetadataField):
    """Full-text attribute: tokenized at upsert time into the collection's
    BM25 `SparseIndex`, queried via `Query.text(...)` / `SparseStage`.

    The tokenization rules are part of the schema (serialized and
    round-tripped through the checkpoint manifest) so documents and
    queries always tokenize identically.  `stopwords=None` selects the
    default English list; an empty tuple disables stopword removal.
    Text fields are retrieval-only: they accept no filter predicates.
    """

    lowercase: bool = True
    min_token_len: int = 2
    stopwords: Optional[Tuple[str, ...]] = None
    kind = "text"

    def __post_init__(self) -> None:
        super().__post_init__()
        if not isinstance(self.min_token_len, int) or self.min_token_len < 1:
            raise SchemaError(f"field {self.name!r}: min_token_len must be "
                              f"a positive int, got {self.min_token_len!r}")
        if self.stopwords is not None:
            words = tuple(self.stopwords)
            if not all(isinstance(w, str) for w in words):
                raise SchemaError(
                    f"field {self.name!r}: stopwords must be strings")
            object.__setattr__(self, "stopwords", words)

    def validate(self, value: Any) -> str:
        if not isinstance(value, str):
            raise SchemaError(
                f"field {self.name!r} expects str, got {type(value).__name__}")
        return value

    def tokenizer(self) -> TokenizerConfig:
        return TokenizerConfig(lowercase=self.lowercase,
                               min_token_len=self.min_token_len,
                               stopwords=self.stopwords)

    def to_dict(self) -> Dict[str, Any]:
        out = super().to_dict()
        out.update({"lowercase": self.lowercase,
                    "min_token_len": self.min_token_len,
                    "stopwords": (list(self.stopwords)
                                  if self.stopwords is not None else None)})
        return out


_FIELD_KINDS = {"keyword": KeywordField, "numeric": NumericField,
                "bool": BoolField, "text": TextField}

# ops a filter may apply per field kind ("text" is retrieval-only: it has
# no predicate ops, so filters on it fail fast with a clear message)
FIELD_OPS = {
    "keyword": ("eq", "ne", "in"),
    "numeric": ("eq", "ne", "lt", "le", "gt", "ge", "in"),
    "bool": ("eq", "ne"),
    "text": (),
}


def field_from_dict(d: Dict[str, Any]) -> MetadataField:
    kind = d.get("kind")
    if kind not in _FIELD_KINDS:
        raise SchemaError(f"unknown field kind {kind!r}")
    kw = {k: v for k, v in d.items() if k != "kind"}
    if kind == "text" and kw.get("stopwords") is not None:
        kw["stopwords"] = tuple(kw["stopwords"])
    kw["required"] = bool(kw.get("required", False))
    try:
        return _FIELD_KINDS[kind](**kw)
    except TypeError as exc:
        raise SchemaError(f"bad {kind!r} field definition: {exc}")


# --------------------------------------------------------------- vector field
@dataclasses.dataclass(frozen=True)
class VectorField:
    """The collection's single vector attribute + index/quantization choice."""

    dim: int
    metric: str = "cosine"
    index: str = "hnsw"
    quantization: str = "none"
    hnsw: HNSWConfig = dataclasses.field(default_factory=HNSWConfig)
    pq: PQConfig = dataclasses.field(default_factory=PQConfig)
    bq: BQConfig = dataclasses.field(default_factory=BQConfig)
    ivf: IVFConfig = dataclasses.field(default_factory=IVFConfig)
    ef_search: int = 64
    rescore: bool = True
    rescore_multiplier: int = 4
    # API default: the device-parallel bulk HNSW constructor; "incremental"
    # is the paper-faithful serial builder, "bulk_ref" the numpy reference
    builder: str = "bulk"

    def __post_init__(self) -> None:
        if not isinstance(self.dim, int) or self.dim <= 0:
            raise SchemaError(f"dim must be a positive int, got {self.dim!r}")
        if self.builder not in BUILDERS:
            raise SchemaError(f"builder {self.builder!r}; have {BUILDERS}")
        if self.metric not in available_metrics():
            raise SchemaError(f"metric {self.metric!r}; "
                              f"have {sorted(available_metrics())}")
        if self.index not in INDEXES:
            raise SchemaError(f"index {self.index!r}; have {INDEXES}")
        if self.quantization not in QUANTIZATIONS:
            raise SchemaError(f"quantization {self.quantization!r}; "
                              f"have {QUANTIZATIONS}")
        if self.quantization == "pq" and self.dim % self.pq.m != 0:
            raise SchemaError(
                f"dim={self.dim} not divisible by pq.m={self.pq.m}")

    def to_engine_config(self) -> EngineConfig:
        return EngineConfig(
            dim=self.dim, metric=self.metric, index=self.index,
            quantization=self.quantization, pq=self.pq, bq=self.bq,
            hnsw=self.hnsw, ivf=self.ivf, builder=self.builder,
            ef_search=self.ef_search, rescore=self.rescore,
            rescore_multiplier=self.rescore_multiplier)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "VectorField":
        d = dict(d)
        for key, sub in (("hnsw", HNSWConfig), ("pq", PQConfig),
                         ("bq", BQConfig), ("ivf", IVFConfig)):
            if isinstance(d.get(key), dict):
                d[key] = sub(**d[key])
        return cls(**d)


# ------------------------------------------------------------ batcher config
@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    """Serving-batcher knobs for a collection's single-vector query path.

    `max_batch` caps how many coalesced queries form one padded engine batch;
    `max_wait_ms` bounds how long the first request waits for company (the
    tail-latency cap at low QPS).  Declared on the schema so the service
    plane can tune them per collection instead of the old hardcoded values.
    """

    max_batch: int = 32
    max_wait_ms: float = 2.0

    def __post_init__(self) -> None:
        if not isinstance(self.max_batch, int) or self.max_batch < 1:
            raise SchemaError(
                f"batcher max_batch must be a positive int, "
                f"got {self.max_batch!r}")
        if not isinstance(self.max_wait_ms, (int, float)) \
                or self.max_wait_ms < 0:
            raise SchemaError(
                f"batcher max_wait_ms must be >= 0, got {self.max_wait_ms!r}")

    def to_dict(self) -> Dict[str, Any]:
        return {"max_batch": self.max_batch,
                "max_wait_ms": float(self.max_wait_ms)}


# --------------------------------------------------------------------- schema
@dataclasses.dataclass(frozen=True)
class CollectionSchema:
    """Named collection layout: one vector field + typed metadata fields."""

    name: str
    vector: VectorField
    fields: Tuple[MetadataField, ...] = ()
    # None = unspecified: the collection falls back to BatcherConfig()
    # defaults, and the service plane may substitute its own defaults —
    # an explicit BatcherConfig always wins over both
    batcher: Optional[BatcherConfig] = None
    # horizontal layout: rows hash-partition across `shards` engine shards,
    # each mirrored `replicas` times for read fan-out.  1/1 = the plain
    # single-engine Collection; anything else materializes a
    # `repro.cluster.ShardedCollection` behind the same API
    shards: int = 1
    replicas: int = 1

    # shards is bounded by the router's hash-slot count (rebalance moves
    # whole slots, so more shards than slots would leave some empty)
    MAX_SHARDS = 64
    MAX_REPLICAS = 8

    def __post_init__(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise SchemaError("collection name must be a non-empty str")
        if "/" in self.name:
            raise SchemaError("collection name must not contain '/' "
                              "(used as a checkpoint key separator)")
        for attr, cap in (("shards", self.MAX_SHARDS),
                          ("replicas", self.MAX_REPLICAS)):
            value = getattr(self, attr)
            if isinstance(value, bool) or not isinstance(value, int) \
                    or not 1 <= value <= cap:
                raise SchemaError(
                    f"{attr} must be an int in [1, {cap}], got {value!r}")
        object.__setattr__(self, "fields", tuple(self.fields))
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise SchemaError(f"duplicate field names in {names}")

    def field(self, name: str) -> MetadataField:
        for f in self.fields:
            if f.name == name:
                return f
        raise SchemaError(f"collection {self.name!r} has no field {name!r}; "
                          f"have {[f.name for f in self.fields]}")

    def field_names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    def text_fields(self) -> Tuple["TextField", ...]:
        return tuple(f for f in self.fields if f.kind == "text")

    def resolve_text_field(self, name: Optional[str]) -> "TextField":
        """The text field a sparse query targets; `None` picks the
        collection's single text field (ambiguity is an error)."""
        text = self.text_fields()
        if name is None:
            if len(text) == 1:
                return text[0]
            if not text:
                raise SchemaError(
                    f"collection {self.name!r} has no text fields; add a "
                    f"TextField to the schema to use sparse/text search")
            raise SchemaError(
                f"collection {self.name!r} has {len(text)} text fields "
                f"({[f.name for f in text]}); specify field=")
        fld = self.field(name)          # raises on unknown column
        if fld.kind != "text":
            raise SchemaError(f"field {name!r} is {fld.kind!r}, not a "
                              f"text field")
        return fld

    def validate_payload(self,
                         payload: Optional[Dict[str, Any]]) -> Dict[str, Any]:
        """Type-check a payload against the schema; returns the normalized
        payload (numerics coerced to float).  Unknown keys are rejected."""
        payload = payload or {}
        if not isinstance(payload, dict):
            raise SchemaError(f"payload must be a dict, "
                              f"got {type(payload).__name__}")
        known = {f.name: f for f in self.fields}
        unknown = sorted(set(payload) - set(known))
        if unknown:
            raise SchemaError(f"unknown payload keys {unknown}; "
                              f"schema fields are {sorted(known)}")
        out: Dict[str, Any] = {}
        for name, fld in known.items():
            if name in payload:
                out[name] = fld.validate(payload[name])
            elif fld.required:
                raise SchemaError(f"missing required field {name!r}")
        return out

    def to_dict(self) -> Dict[str, Any]:
        out = {"name": self.name, "vector": self.vector.to_dict(),
               "fields": [f.to_dict() for f in self.fields]}
        if self.batcher is not None:
            out["batcher"] = self.batcher.to_dict()
        # serialized only when non-default, so pre-cluster snapshots and
        # wire payloads stay byte-identical
        if self.shards != 1:
            out["shards"] = self.shards
        if self.replicas != 1:
            out["replicas"] = self.replicas
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CollectionSchema":
        batcher = d.get("batcher")
        if batcher is not None and not isinstance(batcher, dict):
            raise SchemaError(     # don't silently drop an operator's tuning
                f"batcher must be an object like "
                f"{{'max_batch': 32, 'max_wait_ms': 2.0}}, got {batcher!r}")
        return cls(name=d["name"],
                   vector=VectorField.from_dict(d["vector"]),
                   fields=tuple(field_from_dict(f)
                                for f in d.get("fields", ())),
                   batcher=(BatcherConfig(**batcher) if batcher is not None
                            else None),
                   shards=d.get("shards", 1),
                   replicas=d.get("replicas", 1))
