"""Declarative query plans: the wire-serializable form of every search.

The fluent `Query` builder *compiles* to a `QueryPlan` — a tree of stage
dataclasses — instead of calling the engine directly, and the same plan
executes embedded (`Collection.execute_plan` -> `PlanExecutor`) or over the
wire (the `Search` op carries the plan dict).  Stage types:

  * `AnnStage`      — one index pass (HNSW/flat/IVF) with its own
                      k / ef / expansion_width / filter; ``rescore=None``
                      defers to the engine config (the legacy single-stage
                      behaviour), ``False`` forces a raw code-domain pass
                      (the coarse stage of a coarse-to-fine plan);
  * `SparseStage`   — a BM25 keyword pass over a schema `TextField`'s
                      inverted index (standalone keyword search, filtered
                      via its own / the root filter, or fused with dense
                      ANN inside a prefetch sub-plan);
  * `RescoreStage`  — exact float re-rank of the previous stage's
                      (oversampled) candidates down to ``k``;
  * `PrefetchStage` — N independent sub-plans, each with its own vector,
                      filter, and tuning knobs;
  * `FusionStage`   — RRF or score-normalized linear fusion of the
                      prefetch lists into one candidate set.

The codec (`plan_to_dict` / `plan_from_dict`) is versioned with
`PLAN_VERSION`; malformed plans raise `SchemaError`, which every transport
maps to a structured `ErrorInfo`.  `validate_plan` checks stage ordering
and vector dimensions against a collection schema before execution, and
`Query.explain()` returns a `PlanExplain`: the compiled plan dict plus the
executor's per-stage candidate counts and timings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.metadata import And, Filter, Not, Or, Predicate
from .schema import FIELD_OPS, CollectionSchema, SchemaError

PLAN_VERSION = 1

FUSION_METHODS = ("rrf", "linear")


# ------------------------------------------------------------------- filters
def validate_filter(schema: CollectionSchema, flt: Filter) -> Filter:
    """Check every predicate in the tree against the schema's typed fields."""
    if isinstance(flt, Predicate):
        fld = schema.field(flt.column)          # raises on unknown column
        allowed = FIELD_OPS[fld.kind]
        if flt.op not in allowed:
            raise SchemaError(
                f"op {flt.op!r} not valid for {fld.kind} field "
                f"{flt.column!r}; allowed: {allowed}")
        if flt.op == "in":
            value = [fld.validate(v) for v in flt.value]
            return Predicate(flt.column, "in", tuple(value))
        return Predicate(flt.column, flt.op, fld.validate(flt.value))
    if isinstance(flt, (And, Or)):
        clauses = tuple(validate_filter(schema, c) for c in flt.clauses)
        return type(flt)(clauses)
    if isinstance(flt, Not):
        return Not(validate_filter(schema, flt.clause))
    raise SchemaError(f"not a filter: {flt!r}")


# -------------------------------------------------------------------- stages
@dataclasses.dataclass(frozen=True)
class AnnStage:
    """First-pass index search; must open a (sub-)plan's stage pipeline."""

    k: int
    ef: Optional[int] = None
    expansion_width: Optional[int] = None
    filter: Optional[Filter] = None
    # None: engine-config default (quantized engines oversample + rescore
    # internally — the legacy single-stage behaviour).  False: raw
    # code-domain candidates for an explicit downstream rescore stage.
    rescore: Optional[bool] = None
    op = "ann"


@dataclasses.dataclass(frozen=True)
class RescoreStage:
    """Exact float re-rank of the previous stage's candidates to top-k."""

    k: int
    op = "rescore"


@dataclasses.dataclass(frozen=True)
class SparseStage:
    """BM25 keyword pass over a schema `TextField`'s inverted index; like
    `AnnStage` it must open a (sub-)plan's pipeline.  `field=None` targets
    the collection's single text field; candidate scores come back negated
    (lower = better) so they merge with the engine-wide ordering."""

    text: str
    k: int
    field: Optional[str] = None
    filter: Optional[Filter] = None
    op = "sparse"

    def __post_init__(self) -> None:
        if not isinstance(self.text, str) or not self.text.strip():
            raise SchemaError(
                f"sparse stage: 'text' must be a non-empty string, "
                f"got {self.text!r}")
        if isinstance(self.k, bool) or not isinstance(self.k, int) \
                or self.k < 1:
            raise SchemaError(
                f"sparse stage: 'k' must be a positive int, got {self.k!r}")
        if self.field is not None and not isinstance(self.field, str):
            raise SchemaError(
                f"sparse stage: 'field' must be a string, got {self.field!r}")


@dataclasses.dataclass(frozen=True)
class PrefetchStage:
    """N independent sub-plans whose result lists feed a fusion stage."""

    plans: Tuple["QueryPlan", ...]
    op = "prefetch"


@dataclasses.dataclass(frozen=True)
class FusionStage:
    """Merge prefetch lists: reciprocal-rank ("rrf") or min-max-normalized
    weighted ("linear") fusion."""

    k: int
    method: str = "rrf"
    weights: Optional[Tuple[float, ...]] = None
    rrf_k: int = 60
    op = "fusion"

    def __post_init__(self) -> None:
        if self.method not in FUSION_METHODS:
            raise SchemaError(f"fusion method {self.method!r}; "
                              f"have {FUSION_METHODS}")


Stage = Union[AnnStage, SparseStage, RescoreStage, PrefetchStage,
              FusionStage]


@dataclasses.dataclass(frozen=True, eq=False)
class QueryPlan:
    """Root of a compiled query: final k, root query vector(s), and the
    stage pipeline.  ``vector`` may be None only when every stage that
    needs one (ann/rescore) lives inside prefetch sub-plans that carry
    their own vectors."""

    k: int
    stages: Tuple[Stage, ...]
    vector: Optional[np.ndarray] = None

    @property
    def batched(self) -> bool:
        return self.vector is not None and np.asarray(self.vector).ndim == 2

    @property
    def trivial(self) -> bool:
        """Single plain ANN pass — eligible for the serving batcher."""
        return (len(self.stages) == 1
                and isinstance(self.stages[0], AnnStage)
                and self.stages[0].k == self.k)

    def to_dict(self) -> Dict[str, Any]:
        return plan_to_dict(self)


# --------------------------------------------------------------------- codec
def _filter_to_dict(flt: Optional[Filter]) -> Optional[Dict[str, Any]]:
    if flt is None:
        return None
    from .requests import filter_to_dict
    return filter_to_dict(flt)


def _filter_from_dict(d: Optional[Dict[str, Any]]) -> Optional[Filter]:
    if d is None:
        return None
    from .requests import filter_from_dict
    return filter_from_dict(d)


def _stage_to_dict(stage: Stage) -> Dict[str, Any]:
    if isinstance(stage, AnnStage):
        out: Dict[str, Any] = {"op": "ann", "k": stage.k}
        if stage.ef is not None:
            out["ef"] = stage.ef
        if stage.expansion_width is not None:
            out["expansion_width"] = stage.expansion_width
        if stage.filter is not None:
            out["filter"] = _filter_to_dict(stage.filter)
        if stage.rescore is not None:
            out["rescore"] = stage.rescore
        return out
    if isinstance(stage, SparseStage):
        out = {"op": "sparse", "k": stage.k, "text": stage.text}
        if stage.field is not None:
            out["field"] = stage.field
        if stage.filter is not None:
            out["filter"] = _filter_to_dict(stage.filter)
        return out
    if isinstance(stage, RescoreStage):
        return {"op": "rescore", "k": stage.k}
    if isinstance(stage, PrefetchStage):
        return {"op": "prefetch",
                "plans": [plan_to_dict(p) for p in stage.plans]}
    if isinstance(stage, FusionStage):
        out = {"op": "fusion", "k": stage.k, "method": stage.method}
        if stage.weights is not None:
            out["weights"] = list(stage.weights)
        if stage.rrf_k != 60:
            out["rrf_k"] = stage.rrf_k
        return out
    raise SchemaError(f"not a plan stage: {stage!r}")


def plan_to_dict(plan: QueryPlan) -> Dict[str, Any]:
    """Plan tree -> plain-JSON dict (versioned)."""
    out: Dict[str, Any] = {
        "v": PLAN_VERSION,
        "k": plan.k,
        "stages": [_stage_to_dict(s) for s in plan.stages],
    }
    if plan.vector is not None:
        out["vector"] = np.asarray(plan.vector, dtype=np.float32).tolist()
    return out


def _require_pos_int(d: Dict[str, Any], key: str, ctx: str) -> int:
    value = d.get(key)
    if isinstance(value, bool) or not isinstance(value, int) or value < 1:
        raise SchemaError(f"{ctx}: {key!r} must be a positive int, "
                          f"got {value!r}")
    return value


def _opt_int(d: Dict[str, Any], key: str, ctx: str,
             minimum: int = 0) -> Optional[int]:
    value = d.get(key)
    if value is None:
        return None
    if isinstance(value, bool) or not isinstance(value, int) \
            or value < minimum:
        raise SchemaError(f"{ctx}: {key!r} must be an int >= {minimum}, "
                          f"got {value!r}")
    return value


def _stage_from_dict(d: Any) -> Stage:
    if not isinstance(d, dict):
        raise SchemaError(f"plan stage must be an object, got {d!r}")
    op = d.get("op")
    if op == "ann":
        rescore = d.get("rescore")
        if rescore is not None and not isinstance(rescore, bool):
            raise SchemaError(
                f"ann stage: 'rescore' must be a bool, got {rescore!r}")
        return AnnStage(
            k=_require_pos_int(d, "k", "ann stage"),
            ef=_opt_int(d, "ef", "ann stage"),
            expansion_width=_opt_int(d, "expansion_width", "ann stage", 1),
            filter=_filter_from_dict(d.get("filter")),
            rescore=rescore)
    if op == "sparse":
        field = d.get("field")
        if field is not None and not isinstance(field, str):
            raise SchemaError(
                f"sparse stage: 'field' must be a string, got {field!r}")
        # SparseStage.__post_init__ rejects empty/non-string text
        return SparseStage(
            text=d.get("text"),
            k=_require_pos_int(d, "k", "sparse stage"),
            field=field,
            filter=_filter_from_dict(d.get("filter")))
    if op == "rescore":
        return RescoreStage(k=_require_pos_int(d, "k", "rescore stage"))
    if op == "prefetch":
        plans = d.get("plans")
        if not isinstance(plans, list) or not plans:
            raise SchemaError("prefetch stage needs a non-empty 'plans' list")
        return PrefetchStage(plans=tuple(plan_from_dict(p) for p in plans))
    if op == "fusion":
        weights = d.get("weights")
        if weights is not None:
            if not isinstance(weights, (list, tuple)) or not all(
                    isinstance(w, (int, float)) and not isinstance(w, bool)
                    for w in weights):
                raise SchemaError(
                    f"fusion weights must be a list of numbers, "
                    f"got {weights!r}")
            weights = tuple(float(w) for w in weights)
        rrf_k = d.get("rrf_k", 60)
        if isinstance(rrf_k, bool) or not isinstance(rrf_k, int) \
                or rrf_k < 1:
            raise SchemaError(
                f"fusion rrf_k must be a positive int, got {rrf_k!r}")
        return FusionStage(
            k=_require_pos_int(d, "k", "fusion stage"),
            method=d.get("method", "rrf"),
            weights=weights, rrf_k=rrf_k)
    raise SchemaError(f"unknown plan stage op {op!r}; have "
                      f"('ann', 'sparse', 'rescore', 'prefetch', 'fusion')")


def plan_from_dict(d: Any) -> QueryPlan:
    """Plain dict -> plan tree; malformed input raises `SchemaError` (every
    transport maps it onto the structured error taxonomy)."""
    if not isinstance(d, dict):
        raise SchemaError(f"plan must be an object, got {type(d).__name__}")
    version = d.get("v", PLAN_VERSION)
    if version != PLAN_VERSION:
        raise SchemaError(f"unsupported plan version {version!r}; "
                          f"this build speaks v{PLAN_VERSION}")
    stages = d.get("stages")
    if not isinstance(stages, list) or not stages:
        raise SchemaError("plan needs a non-empty 'stages' list")
    vector = d.get("vector")
    if vector is not None:
        try:
            vector = np.asarray(vector, dtype=np.float32)
        except (TypeError, ValueError) as exc:   # ragged / non-numeric
            raise SchemaError(f"malformed plan vector: {exc}")
    return QueryPlan(
        k=_require_pos_int(d, "k", "plan"),
        stages=tuple(_stage_from_dict(s) for s in stages),
        vector=vector)


# ---------------------------------------------------------------- validation
def validate_plan(schema: CollectionSchema, plan: QueryPlan,
                  _nested: bool = False,
                  _inherits_vector: bool = False) -> QueryPlan:
    """Structural + schema validation; returns the plan with every filter
    tree validated (and value-normalized) against the collection schema.

    Prefetch sub-plans may omit their vector when the parent has one
    (execution inherits it), so an N-way prefetch query ships the root
    vector once instead of N+1 times."""
    if not plan.stages:
        raise SchemaError("plan has no stages")
    vector = plan.vector
    if vector is not None:
        vector = np.asarray(vector, dtype=np.float32)
        if vector.ndim not in (1, 2) or vector.shape[-1] != schema.vector.dim:
            raise SchemaError(
                f"plan vector shape {vector.shape} does not match "
                f"collection dim {schema.vector.dim}")
        if _nested and vector.ndim != 1:
            raise SchemaError("prefetch sub-plan vectors must be 1-D")
    has_vector = vector is not None or (_nested and _inherits_vector)
    stages: List[Stage] = []
    for pos, stage in enumerate(plan.stages):
        if isinstance(stage, AnnStage):
            if pos != 0:
                raise SchemaError("ann stage must open the plan "
                                  f"(found at position {pos})")
            if not has_vector:
                raise SchemaError("ann stage needs a plan vector")
            if stage.expansion_width is not None and stage.expansion_width < 1:
                raise SchemaError(f"expansion_width must be >= 1, "
                                  f"got {stage.expansion_width}")
            flt = (validate_filter(schema, stage.filter)
                   if stage.filter is not None else None)
            stages.append(dataclasses.replace(stage, filter=flt))
        elif isinstance(stage, SparseStage):
            if pos != 0:
                raise SchemaError("sparse stage must open the plan "
                                  f"(found at position {pos})")
            if vector is not None and vector.ndim != 1:
                # sparse scoring is per-query; a batched root vector has
                # no per-row text to pair with
                raise SchemaError(
                    "sparse stages take single queries; got a batched "
                    f"root vector of shape {vector.shape}")
            field = schema.resolve_text_field(stage.field)
            flt = (validate_filter(schema, stage.filter)
                   if stage.filter is not None else None)
            stages.append(dataclasses.replace(stage, field=field.name,
                                              filter=flt))
        elif isinstance(stage, PrefetchStage):
            if pos != 0:
                raise SchemaError("prefetch stage must open the plan "
                                  f"(found at position {pos})")
            if vector is not None and vector.ndim != 1:
                # each sub-plan is a single query; a batched root has no
                # meaning here and the fused result would silently cover
                # one row (or crash a trailing rescore stage)
                raise SchemaError(
                    "prefetch plans take a 1-D root vector, got shape "
                    f"{vector.shape}")
            nxt = plan.stages[pos + 1] if pos + 1 < len(plan.stages) else None
            if not isinstance(nxt, FusionStage):
                raise SchemaError(
                    "prefetch stage must be followed by a fusion stage")
            stages.append(PrefetchStage(plans=tuple(
                validate_plan(schema, sub, _nested=True,
                              _inherits_vector=has_vector)
                for sub in stage.plans)))
        elif isinstance(stage, FusionStage):
            if pos == 0 or not isinstance(plan.stages[pos - 1],
                                          PrefetchStage):
                raise SchemaError(
                    "fusion stage must follow a prefetch stage")
            prev = plan.stages[pos - 1]
            if stage.weights is not None \
                    and len(stage.weights) != len(prev.plans):
                raise SchemaError(
                    f"fusion has {len(stage.weights)} weights for "
                    f"{len(prev.plans)} prefetch sub-plans")
            stages.append(stage)
        elif isinstance(stage, RescoreStage):
            if pos == 0:
                raise SchemaError(
                    "rescore stage needs a preceding candidate stage")
            if not has_vector:
                raise SchemaError("rescore stage needs a plan vector")
            stages.append(stage)
        else:
            raise SchemaError(f"not a plan stage: {stage!r}")
    final = plan.stages[-1]
    if isinstance(final, PrefetchStage):
        raise SchemaError("plan cannot end on a prefetch stage")
    if getattr(final, "k", plan.k) < plan.k:
        raise SchemaError(
            f"final stage delivers k={final.k} < plan k={plan.k}")
    return QueryPlan(k=plan.k, stages=tuple(stages), vector=vector)


# ------------------------------------------------------------------- explain
@dataclasses.dataclass
class PlanExplain:
    """`Query.explain()` result: the compiled plan (codec form), the
    executor's per-stage report (candidate counts in/out, seconds, nested
    prefetch children), and the hits the plan produced.  The same object
    comes back embedded and over the wire."""

    plan: Dict[str, Any]
    stages: List[Dict[str, Any]]
    hits: List[Any] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {"plan": self.plan, "stages": self.stages}

    def __repr__(self) -> str:
        parts = ", ".join(
            f"{s['stage']}(k={s['k']}, out={s['candidates_out']}, "
            f"{s['seconds'] * 1e3:.2f}ms)" for s in self.stages)
        return f"PlanExplain({parts})"


# ----------------------------------------------------------------- recommend
def recommend_vector(collection: Any, positives: Sequence[Any],
                     negatives: Sequence[Any] = ()) -> np.ndarray:
    """Synthesize a query vector from example entities: mean(positives)
    minus mean(negatives).  Examples may be stored entity ids (looked up
    via ``collection.get``) or raw vectors; works against embedded and
    remote collections alike."""
    def resolve(example) -> np.ndarray:
        if isinstance(example, str):
            entity = collection.get(example)
            if entity is None or len(entity.vector) == 0:
                raise SchemaError(f"recommend: no entity {example!r} in "
                                  f"collection {collection.name!r}")
            return np.asarray(entity.vector, dtype=np.float32)
        vec = np.asarray(example, dtype=np.float32)
        if vec.ndim != 1 or vec.shape[0] != collection.schema.vector.dim:
            raise SchemaError(f"recommend: example vector shape {vec.shape} "
                              f"!= dim {collection.schema.vector.dim}")
        return vec

    if not positives:
        raise SchemaError("recommend needs at least one positive example")
    pos = np.stack([resolve(p) for p in positives]).mean(axis=0)
    if not negatives:
        return pos
    neg = np.stack([resolve(n) for n in negatives]).mean(axis=0)
    return pos - neg
