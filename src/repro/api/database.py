"""`Database`: named collections + save/load through the checkpoint store.

One `Database` manages many named `Collection`s and persists them as a
single atomic checkpoint generation: every collection's engine state and
id/tombstone maps become namespaced arrays, and the declarative schemas ride
in the manifest's `extra` JSON — so `Database.load(path)` reconstructs the
full typed API surface (schemas included) from disk alone.

`Database` is the embedded twin of `QuantixarClient`: both hand out
collections whose reads (fluent `Query`, `count`, `recommend`, explicit
`QueryPlan`s) run the same declarative plan pipeline — the client ships the
compiled plan over the wire, a `Database` collection executes it in
process — so scenarios move between the two backends without rewrites.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Union

from ..checkpoint.store import CheckpointStore
from ..cluster.sharded import ShardedCollection
from .collection import Collection
from .schema import (BatcherConfig, CollectionSchema, MetadataField,
                     SchemaError, VectorField)

_SEP = "/"          # namespaces collection arrays inside one checkpoint

# a sharded collection quacks like a Collection everywhere the database
# (and the serving plane above it) touches one
AnyCollection = Union[Collection, ShardedCollection]


def _build_collection(schema: CollectionSchema) -> AnyCollection:
    """Topology dispatch: `shards`/`replicas` in the schema pick the
    engine shape; everything above sees one `Collection`-shaped object."""
    if schema.shards > 1 or schema.replicas > 1:
        return ShardedCollection(schema)
    return Collection(schema)


class Database:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._collections: Dict[str, AnyCollection] = {}
        self._store = CheckpointStore(path) if path else None

    # ------------------------------------------------------------ management
    def create_collection(
            self,
            schema: Optional[CollectionSchema] = None, *,
            name: Optional[str] = None,
            vector: Optional[VectorField] = None,
            fields: Sequence[MetadataField] = (),
            batcher: Optional[BatcherConfig] = None,
            shards: int = 1, replicas: int = 1) -> AnyCollection:
        """Create from a full `CollectionSchema`, or from name/vector/fields
        keyword parts; `batcher=` tunes the serving-batcher knobs
        (`BatcherConfig(max_batch=..., max_wait_ms=...)`).  `shards`/
        `replicas` > 1 build a hash-partitioned `ShardedCollection` behind
        the same API."""
        if schema is None:
            if name is None or vector is None:
                raise SchemaError(
                    "pass a CollectionSchema or name= and vector=")
            schema = CollectionSchema(name=name, vector=vector,
                                      fields=tuple(fields), batcher=batcher,
                                      shards=shards, replicas=replicas)
        else:
            if batcher is not None:
                schema = dataclasses.replace(schema, batcher=batcher)
            if shards != 1 or replicas != 1:
                schema = dataclasses.replace(schema, shards=shards,
                                             replicas=replicas)
        if schema.name in self._collections:
            raise SchemaError(f"collection {schema.name!r} already exists")
        col = _build_collection(schema)
        self._collections[schema.name] = col
        return col

    def collection(self, name: str) -> AnyCollection:
        if name not in self._collections:
            raise KeyError(f"no collection {name!r}; "
                           f"have {self.list_collections()}")
        return self._collections[name]

    __getitem__ = collection

    def __contains__(self, name: str) -> bool:
        return name in self._collections

    def list_collections(self) -> List[str]:
        return sorted(self._collections)

    def drop_collection(self, name: str) -> None:
        col = self._collections.pop(name, None)
        if col is None:
            raise KeyError(f"no collection {name!r}")
        col.close()

    def close(self) -> None:
        for col in self._collections.values():
            col.close()

    # ----------------------------------------------------------- persistence
    def _resolve_store(self, path: Optional[str]) -> CheckpointStore:
        if path is not None:
            return CheckpointStore(path)
        if self._store is None:
            raise SchemaError(
                "no path: pass save(path=...) or Database(path=...)")
        return self._store

    def save(self, path: Optional[str] = None, *, step: int = 0) -> int:
        """Commit every collection atomically as one checkpoint generation.
        Returns the generation id."""
        store = self._resolve_store(path)
        state: Dict[str, Any] = {}
        schemas: Dict[str, Dict[str, Any]] = {}
        for name, col in self._collections.items():
            for key, arr in col.state_dict().items():
                state[f"{name}{_SEP}{key}"] = arr
            schemas[name] = col.schema.to_dict()
        return store.save(state, step=step,
                          extra={"quantixar_collections": schemas})

    @classmethod
    def load(cls, path: str, *, generation: Optional[int] = None
             ) -> "Database":
        """Reconstruct a full database (schemas, engines, id maps) from the
        newest — or a specific — committed generation."""
        db = cls(path)
        store = db._store
        man = store.manifest(generation)
        schemas = man.extra.get("quantixar_collections")
        if schemas is None:
            raise SchemaError(
                f"checkpoint under {path!r} was not written by Database.save")
        state = store.load(generation)
        for name, schema_dict in schemas.items():
            schema = CollectionSchema.from_dict(schema_dict)
            prefix = f"{name}{_SEP}"
            sub = {k[len(prefix):]: v for k, v in state.items()
                   if k.startswith(prefix)}
            if schema.shards > 1 or schema.replicas > 1:
                db._collections[name] = ShardedCollection.from_state_dict(
                    schema, sub)
            else:
                db._collections[name] = Collection.from_state_dict(schema,
                                                                   sub)
        return db

    def stats(self) -> Dict[str, Any]:
        return {name: col.stats() for name, col in self._collections.items()}
