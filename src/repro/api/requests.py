"""Versioned wire protocol for the Quantixar request plane.

Every operation a client can perform — collection DDL, point CRUD, filtered
search, compaction, stats, snapshot/restore — is a dataclass here with a
plain-dict JSON codec, so any transport (the stdlib HTTP server in
`repro.serving.http`, a test harness calling `QuantixarService` directly)
speaks the same typed language.  Failures travel the same way: a structured
`ErrorInfo` (code + message + details) instead of a traceback, with a fixed
taxonomy every transport maps onto its own status space.

The protocol is versioned (`PROTOCOL_VERSION`); request envelopes carry the
version and an `op` tag, and `decode_request` rejects unknown versions/ops
with `INVALID_ARGUMENT` rather than guessing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Type, Union

from ..core.metadata import And, Filter, Not, Or, Predicate
from .schema import SchemaError

PROTOCOL_VERSION = 1

# ------------------------------------------------------------ error taxonomy
SCHEMA_ERROR = "SCHEMA_ERROR"          # request violates a collection schema
NOT_FOUND = "NOT_FOUND"                # unknown collection / id / route
INVALID_ARGUMENT = "INVALID_ARGUMENT"  # malformed request (bad JSON, op, ...)
UNAVAILABLE = "UNAVAILABLE"            # transient: shutting down, timeout
INTERNAL = "INTERNAL"                  # unexpected server-side failure

ERROR_CODES = (SCHEMA_ERROR, NOT_FOUND, INVALID_ARGUMENT, UNAVAILABLE,
               INTERNAL)


@dataclasses.dataclass
class ErrorInfo:
    """A failure as data: taxonomy code, human message, optional details."""

    code: str
    message: str
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.code not in ERROR_CODES:
            self.code = INTERNAL

    def to_dict(self) -> Dict[str, Any]:
        out = {"code": self.code, "message": self.message}
        if self.details:
            out["details"] = self.details
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ErrorInfo":
        return cls(code=str(d.get("code", INTERNAL)),
                   message=str(d.get("message", "")),
                   details=dict(d.get("details") or {}))


class ApiError(Exception):
    """Carrier for an `ErrorInfo` across the client/service boundary."""

    def __init__(self, info: ErrorInfo) -> None:
        super().__init__(f"[{info.code}] {info.message}")
        self.info = info

    # without this, RemoteNotFound would pick up KeyError.__str__ and
    # render its message repr-quoted
    __str__ = Exception.__str__

    @property
    def code(self) -> str:
        return self.info.code


# Client-side mirrors that keep exception parity with the embedded API:
# a remote SCHEMA_ERROR is catchable as `SchemaError`, a remote NOT_FOUND
# as `KeyError`, so the same test scenarios run embedded or over the wire.
class RemoteSchemaError(ApiError, SchemaError):
    pass


class RemoteNotFound(ApiError, KeyError):
    pass


class RemoteInvalidArgument(ApiError, ValueError):
    pass


class RemoteUnavailable(ApiError):
    pass


_ERROR_EXCEPTIONS: Dict[str, Type[ApiError]] = {
    SCHEMA_ERROR: RemoteSchemaError,
    NOT_FOUND: RemoteNotFound,
    INVALID_ARGUMENT: RemoteInvalidArgument,
    UNAVAILABLE: RemoteUnavailable,
    INTERNAL: ApiError,
}


def error_to_exception(info: ErrorInfo) -> ApiError:
    """The `ApiError` subclass whose extra bases match the embedded API's
    exception for this failure class."""
    return _ERROR_EXCEPTIONS.get(info.code, ApiError)(info)


# ------------------------------------------------------------- filter codec
def filter_to_dict(flt: Optional[Filter]) -> Optional[Dict[str, Any]]:
    """Serialize a full filter tree (Predicate/And/Or/Not) to plain JSON."""
    if flt is None:
        return None
    if isinstance(flt, Predicate):
        value = list(flt.value) if isinstance(flt.value, (tuple, list, set)) \
            else flt.value
        return {"pred": {"column": flt.column, "op": flt.op, "value": value}}
    if isinstance(flt, And):
        return {"and": [filter_to_dict(c) for c in flt.clauses]}
    if isinstance(flt, Or):
        return {"or": [filter_to_dict(c) for c in flt.clauses]}
    if isinstance(flt, Not):
        return {"not": filter_to_dict(flt.clause)}
    raise SchemaError(f"not a filter: {flt!r}")


def filter_from_dict(d: Optional[Dict[str, Any]]) -> Optional[Filter]:
    if d is None:
        return None
    if not isinstance(d, dict) or len(d) != 1:
        raise SchemaError(f"malformed filter node: {d!r}")
    kind, body = next(iter(d.items()))
    if kind == "pred":
        value = body["value"]
        if isinstance(value, list):          # JSON lists -> hashable tuples
            value = tuple(value)
        return Predicate(body["column"], body["op"], value)
    if kind == "and":
        return And(tuple(filter_from_dict(c) for c in body))
    if kind == "or":
        return Or(tuple(filter_from_dict(c) for c in body))
    if kind == "not":
        return Not(filter_from_dict(body))
    raise SchemaError(f"unknown filter node kind {kind!r}")


# ----------------------------------------------------------------- requests
_REQUEST_TYPES: Dict[str, Type["Request"]] = {}


@dataclasses.dataclass
class Request:
    """Base request: `op` identifies the operation on the wire."""

    op = "abstract"

    def __init_subclass__(cls, **kw: Any) -> None:
        super().__init_subclass__(**kw)
        if cls.op != "abstract":
            _REQUEST_TYPES[cls.op] = cls

    def to_dict(self) -> Dict[str, Any]:
        return {"v": PROTOCOL_VERSION, "op": self.op,
                "body": dataclasses.asdict(self)}


@dataclasses.dataclass
class CreateCollection(Request):
    """DDL: create a collection from a `CollectionSchema.to_dict()` payload."""

    schema: Dict[str, Any]
    op = "create_collection"


@dataclasses.dataclass
class DropCollection(Request):
    collection: str
    op = "drop_collection"


@dataclasses.dataclass
class ListCollections(Request):
    op = "list_collections"


@dataclasses.dataclass
class DescribeCollection(Request):
    collection: str
    op = "describe_collection"


@dataclasses.dataclass
class Upsert(Request):
    collection: str
    ids: List[str]
    vectors: List[List[float]]
    payloads: Optional[List[Optional[Dict[str, Any]]]] = None
    op = "upsert"


@dataclasses.dataclass
class Delete(Request):
    collection: str
    ids: List[str]
    op = "delete"


@dataclasses.dataclass
class Get(Request):
    collection: str
    id: str
    include_vector: bool = True
    op = "get"


@dataclasses.dataclass
class Search(Request):
    """Single (1-D `vector`) or batch (2-D `vector`) filtered search.

    Two forms:

      * legacy fields — `vector`/`k`/`filter` plus the per-request knobs
        (`ef`/`rescore`/`expansion_width`), which the server compiles to a
        trivial single-stage plan.  `text` (optionally `text_field`)
        instead of / alongside `vector` asks for BM25 keyword search —
        alone it compiles to a sparse plan, with a vector to a hybrid
        RRF-fused plan, exactly like the fluent `Query.text()`;
      * `plan` — a full `plan_to_dict` tree (coarse-to-fine stages,
        prefetch sub-plans incl. sparse legs, fusion), the wire form of
        the fluent `Query`.  When `plan` is set it is the whole query; the
        legacy fields are ignored and the root vector rides inside the
        plan.

    `explain=True` asks the server to echo the compiled plan and per-stage
    candidate counts/timings alongside the hits.
    """

    collection: str
    vector: Optional[List[Any]] = None
    k: int = 10
    filter: Optional[Dict[str, Any]] = None
    ef: Optional[int] = None
    rescore: Optional[bool] = None
    expansion_width: Optional[int] = None
    include_vector: bool = False
    plan: Optional[Dict[str, Any]] = None
    explain: bool = False
    text: Optional[str] = None
    text_field: Optional[str] = None
    op = "search"

    @property
    def batched(self) -> bool:
        """Legacy-form (vector-field) batched-ness; plan-form requests get
        it from the parsed `QueryPlan.batched` instead."""
        return bool(self.vector) and isinstance(self.vector[0], (list, tuple))


@dataclasses.dataclass
class Count(Request):
    """Filtered cardinality: how many live entities match `filter`
    (all of them when None) — no hits fetched, no vector work."""

    collection: str
    filter: Optional[Dict[str, Any]] = None
    op = "count"


@dataclasses.dataclass
class Compact(Request):
    """`shard` (sharded collections only) compacts one shard instead of
    the whole collection."""

    collection: str
    shard: Optional[int] = None
    op = "compact"


@dataclasses.dataclass
class Rebalance(Request):
    """Re-partition a sharded collection onto `shards` shards x `replicas`
    replicas (None = keep current) via snapshot + re-upsert."""

    collection: str
    shards: Optional[int] = None
    replicas: Optional[int] = None
    op = "rebalance"


@dataclasses.dataclass
class ShardStats(Request):
    """Per-shard breakdown: rows/tombstones/queue depth, owned hash slots,
    replica health.  A plain collection answers as one shard."""

    collection: str
    op = "shard_stats"


@dataclasses.dataclass
class Stats(Request):
    collection: Optional[str] = None      # None: whole-database stats
    op = "stats"


@dataclasses.dataclass
class Snapshot(Request):
    """Persist every collection as one atomic checkpoint generation."""

    path: str
    step: int = 0
    op = "snapshot"


@dataclasses.dataclass
class Restore(Request):
    """Replace the served database with a snapshot generation."""

    path: str
    generation: Optional[int] = None
    op = "restore"


@dataclasses.dataclass
class Health(Request):
    op = "health"


AnyRequest = Union[CreateCollection, DropCollection, ListCollections,
                   DescribeCollection, Upsert, Delete, Get, Search, Count,
                   Compact, Rebalance, ShardStats, Stats, Snapshot, Restore,
                   Health]


def decode_request(d: Dict[str, Any]) -> Request:
    """Envelope dict -> typed request; malformed input raises `ApiError`
    with `INVALID_ARGUMENT` (never a bare KeyError/TypeError)."""
    if not isinstance(d, dict):
        raise error_to_exception(ErrorInfo(
            INVALID_ARGUMENT, f"request must be an object, got "
            f"{type(d).__name__}"))
    version = d.get("v", PROTOCOL_VERSION)
    if version != PROTOCOL_VERSION:
        raise error_to_exception(ErrorInfo(
            INVALID_ARGUMENT, f"unsupported protocol version {version!r}; "
            f"this server speaks v{PROTOCOL_VERSION}"))
    op = d.get("op")
    cls = _REQUEST_TYPES.get(op)
    if cls is None:
        raise error_to_exception(ErrorInfo(
            INVALID_ARGUMENT, f"unknown op {op!r}",
            {"known_ops": sorted(_REQUEST_TYPES)}))
    body = d.get("body") or {}
    try:
        return cls(**body)
    except TypeError as exc:
        raise error_to_exception(ErrorInfo(
            INVALID_ARGUMENT, f"bad body for op {op!r}: {exc}"))


# ---------------------------------------------------------------- responses
@dataclasses.dataclass
class Response:
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Response":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class Ack(Response):
    ok: bool = True


@dataclasses.dataclass
class CollectionInfo(Response):
    name: str
    schema: Dict[str, Any]


@dataclasses.dataclass
class CollectionList(Response):
    collections: List[str]


@dataclasses.dataclass
class UpsertResult(Response):
    upserted: int


@dataclasses.dataclass
class DeleteResult(Response):
    deleted: int


@dataclasses.dataclass
class GetResult(Response):
    entity: Optional[Dict[str, Any]]      # {id, payload, vector?} or None


@dataclasses.dataclass
class SearchResult(Response):
    """`hits` is a list of hit dicts for single queries, a list of lists for
    batch queries (`batched` disambiguates the empty case).  When the
    request asked for `explain`, `explain` carries the compiled plan echo
    plus the executor's per-stage report."""

    hits: List[Any]
    batched: bool = False
    explain: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class CountResult(Response):
    count: int = 0


@dataclasses.dataclass
class CompactResult(Response):
    reclaimed: int


@dataclasses.dataclass
class RebalanceResult(Response):
    shards: int
    replicas: int
    rows: int
    seconds: float


@dataclasses.dataclass
class ShardStatsResult(Response):
    shards: List[Dict[str, Any]]


@dataclasses.dataclass
class StatsResult(Response):
    stats: Dict[str, Any]


@dataclasses.dataclass
class SnapshotResult(Response):
    generation: int


@dataclasses.dataclass
class RestoreResult(Response):
    collections: List[str]


@dataclasses.dataclass
class HealthResult(Response):
    status: str = "ok"
    version: int = PROTOCOL_VERSION
