"""Clustered serving: hash-slot routing + sharded, replicated collections.

`ShardedCollection` partitions one logical collection across N in-process
engine shards (x R replicas) behind the exact `Collection` API; `Router`
owns the id -> hash slot -> shard mapping that makes rebalancing a routing
-table edit instead of a full rehash.
"""

from .router import HASH_SLOTS, Router, slot_of
from .sharded import ShardedCollection, ShardUnavailable

__all__ = ["HASH_SLOTS", "Router", "ShardedCollection", "ShardUnavailable",
           "slot_of"]
