"""`ShardedCollection`: one logical collection over N in-process shards.

Rows are hash-slot partitioned by string id (`repro.cluster.router`); each
shard is a full single-engine `Collection` replicated `replicas` times.
The class speaks the exact `Collection` surface — `upsert`/`delete`/`get`/
`query()`/`execute_plan`/`stats`/`state_dict` — so the serving plane,
`Database` persistence, and the wire protocol treat both interchangeably.

Exactness is the design center: a sharded collection must return the SAME
hits as one engine over the same rows.

  * Global ids.  Every appended row gets a monotonically increasing global
    sequence number (seq) assigned in upsert-batch order — the same order a
    single engine numbers its rows — so every cross-shard tie-break
    (distance ties, BM25 score ties, RRF rank ties) resolves exactly as the
    single-engine row tie-break does.  Per shard, `gmap` (local row -> seq,
    append-only between compactions) and `rdict` (seq -> local row) carry
    the translation.
  * Exact top-k merge.  Plans scatter only at the leaf `ann`/`sparse`
    stages: each shard returns its local top-k, the union is re-sorted by
    (distance, seq) — top-k of a union of per-shard top-k is exactly the
    global top-k.  Fusion (RRF/linear) and rescore run GLOBALLY over seq
    ids, never per shard.
  * Exact distributed BM25.  Per-shard document frequencies would skew
    IDF, so sparse stages run two-phase: gather integer term statistics
    from every shard, `CorpusStats.aggregate` them, then score each shard
    with the GLOBAL stats — bit-identical to one index (integer sums
    commute; the float math then runs on identical inputs).

Concurrency mirrors `Collection`: non-trivial plans and all writes/topology
changes serialize under one collection-level lock; trivial single-vector
queries coalesce lock-free through ONE collection-level `RequestBatcher`
whose every flushed batch scatters to all shards as a single aligned wave
(the QPS-scaling path — per-shard batchers would fragment concurrent
callers into staggered partial flushes), then validate per-shard epochs and
the topology generation after the fact, retrying when a compaction or
rebalance raced them.

Rebalancing (`rebalance`/`split`/`move_slot`) is snapshot-based: every
source shard is committed through a `CheckpointStore` (the same artifact a
cross-node shard move would ship), restored, and re-upserted in global seq
order under the new routing table — queries in flight keep answering
against the old shard set and retry onto the new one after the swap.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from itertools import count
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..api.collection import (Collection, CollectionClosed, Entity,
                              QueryRetriesExhausted, _as_id_list)
from ..api.plan import (AnnStage, PlanExplain, QueryPlan, plan_to_dict,
                        recommend_vector, validate_filter, validate_plan)
from ..api.query import Hit, Query
from ..api.schema import BatcherConfig, CollectionSchema, SchemaError
from ..checkpoint.store import CheckpointStore
from ..core.executor import AnnParams, ExecResult, PlanExecutor
from ..core.metadata import Filter
from ..core.sparse import CorpusStats
from ..serving.batcher import BatcherClosed, RequestBatcher
from .router import HASH_SLOTS, Router


class ShardUnavailable(RuntimeError):
    """Every replica of some shard refused the request (unhealthy or
    failed) — the query cannot be answered exactly, so it is not answered
    at all.  The service plane maps this to UNAVAILABLE (retryable)."""


class _ViewChanged(RuntimeError):
    """Internal: a compact()/rebalance() raced a batcher-path query; the
    rows it returned belong to a dead numbering.  Caught and retried."""


class _ShardView:
    """Immutable-by-convention snapshot of one shard's serving state.

    `replicas`/`epochs` never mutate after publication; `gmap`/`rdict` are
    the LIVE translation maps — append-only/insert-only between
    compactions (safe to read concurrently under the GIL), replaced
    wholesale (with a new view) whenever a compaction renumbers rows.
    `health` is a shared mutable list (reads tolerate stale values).
    """

    __slots__ = ("replicas", "health", "gmap", "rdict", "epochs", "rr")

    def __init__(self, replicas: Tuple[Collection, ...], health: List[bool],
                 gmap: List[int], rdict: Dict[int, int],
                 epochs: Tuple[int, ...], rr=None):
        self.replicas = replicas
        self.health = health
        self.gmap = gmap
        self.rdict = rdict
        self.epochs = epochs
        self.rr = rr if rr is not None else count()


class ShardedCollection:
    """Hash-partitioned, replicated collection behind the `Collection` API."""

    def __init__(self, schema: CollectionSchema):
        if schema.shards < 1 or schema.replicas < 1:
            raise SchemaError("shards and replicas must be >= 1")
        self.schema = schema
        self._router = Router.even(schema.shards)   # guarded-by: _lock
        self._views: List[_ShardView] = [           # guarded-by: _lock
            self._make_shard(s, schema.replicas)
            for s in range(schema.shards)]
        self._seq_of: Dict[str, int] = {}      # guarded-by: _lock (live id->seq)
        self._id_of_seq: Dict[int, str] = {}   # guarded-by: _lock (live seq->id)
        self._next_seq = 0                     # guarded-by: _lock
        self._closed = False                   # guarded-by: _lock
        self._scatter_log: List[Dict[str, Any]] = []   # guarded-by: _lock
        # leaf-stage fan-out pool; per-shard work items never scatter again,
        # so the pool cannot deadlock on itself
        self._pool = ThreadPoolExecutor(max_workers=16,
                                        thread_name_prefix="qx-shard")
        self._lock = threading.RLock()
        # collection-level serving batcher (lazily started); bumps whenever
        # seq numbering may change (compact/rebalance) so lock-free readers
        # can tell a renumbering raced their round trip
        self._batcher: Optional[RequestBatcher] = None  # guarded-by: _batcher_init_lock
        self._batcher_init_lock = threading.Lock()
        self._topology_gen = 0                 # guarded-by: _lock

    # -------------------------------------------------------------- topology
    def _shard_schema(self, shard: int, replica: int) -> CollectionSchema:
        return dataclasses.replace(
            self.schema, name=f"{self.schema.name}.s{shard}r{replica}",
            shards=1, replicas=1)

    def _make_shard(self, shard: int, replicas: int) -> _ShardView:
        cols = tuple(Collection(self._shard_schema(shard, r))
                     for r in range(replicas))
        return _ShardView(cols, [True] * replicas, [], {},
                          tuple(c.epoch for c in cols))

    # ------------------------------------------------------------ properties
    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def num_shards(self) -> int:
        return len(self._views)  # unguarded-ok: atomic read of a published list

    def __len__(self) -> int:
        with self._lock:
            return len(self._seq_of)

    @property
    def tombstones(self) -> int:
        with self._lock:
            return sum(v.replicas[0].tombstones for v in self._views)

    def __contains__(self, id: str) -> bool:
        with self._lock:
            return id in self._seq_of

    def ids(self) -> List[str]:
        """Live ids in global insertion (seq) order — the same order a
        single engine would report."""
        with self._lock:
            return [self._id_of_seq[seq] for seq in sorted(self._id_of_seq)]

    # ---------------------------------------------------------------- writes
    def upsert(self, ids: Union[str, Sequence[str]],
               vectors: np.ndarray,
               payloads: Optional[Sequence[Optional[Dict[str, Any]]]] = None,
               ) -> int:
        """Partition the batch by hash slot and fan each piece out to every
        replica of its shard.  Seqs are assigned by position in the ORIGINAL
        batch (before partitioning), so global row numbering matches what a
        single engine receiving the same batch would produce."""
        ids = _as_id_list(ids)
        if len(set(ids)) != len(ids):
            raise SchemaError("duplicate ids within one upsert batch")
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        if vectors.ndim != 2 or vectors.shape[1] != self.schema.vector.dim:
            raise SchemaError(
                f"expected ({len(ids)}, {self.schema.vector.dim}) vectors, "
                f"got {vectors.shape}")
        if len(vectors) != len(ids):
            raise SchemaError(f"{len(ids)} ids but {len(vectors)} vectors")
        if payloads is None:
            payloads = [None] * len(ids)
        if len(payloads) != len(ids):
            raise SchemaError(f"{len(ids)} ids but {len(payloads)} payloads")
        # validate the WHOLE batch before any shard commits anything
        validated = [self.schema.validate_payload(p) for p in payloads]

        with self._lock:
            self._check_open()
            seq0 = self._next_seq
            for shard, idxs in sorted(self._router.partition(ids).items()):
                view = self._views[shard]
                sub_ids = [ids[i] for i in idxs]
                sub_vecs = vectors[idxs]
                sub_pl = [validated[i] for i in idxs]
                for col in view.replicas:     # writes go to ALL replicas
                    col.upsert(sub_ids, sub_vecs, sub_pl)
                for i in idxs:
                    seq = seq0 + i
                    old = self._seq_of.get(ids[i])
                    if old is not None:       # replaced: old seq retires
                        del self._id_of_seq[old]
                        view.rdict.pop(old, None)
                    self._seq_of[ids[i]] = seq
                    self._id_of_seq[seq] = ids[i]
                    view.gmap.append(seq)     # row-aligned with the engine
                    view.rdict[seq] = len(view.gmap) - 1
            self._next_seq = seq0 + len(ids)
            return len(ids)

    def delete(self, ids: Union[str, Sequence[str]]) -> int:
        n = 0
        with self._lock:
            self._check_open()
            for id_ in _as_id_list(ids):
                seq = self._seq_of.pop(id_, None)
                if seq is None:
                    continue
                del self._id_of_seq[seq]
                view = self._views[self._router.shard_of(id_)]
                view.rdict.pop(seq, None)
                for col in view.replicas:
                    col.delete(id_)
                n += 1
        return n

    def seal(self, shard: Optional[int] = None) -> None:
        """Fold delta segments into the sealed index on one shard (or all)
        without renumbering rows."""
        with self._lock:
            self._check_open()
            for s in self._shard_range(shard):
                for col in self._views[s].replicas:
                    col.seal()

    def compact(self, shard: Optional[int] = None) -> int:
        """Rebuild one shard (or all) over live rows only.  Local rows are
        renumbered but seqs are STABLE: the new `gmap` re-derives each
        surviving row's original seq, so global ids, tie-breaks, and
        already-issued `search` results keep meaning the same entities."""
        reclaimed = 0
        with self._lock:
            self._check_open()
            for s in self._shard_range(shard):
                view = self._views[s]
                dead = 0
                for col in view.replicas:   # lockstep: epochs stay aligned
                    dead = col.compact()
                reclaimed += dead
                live_ids = view.replicas[0].ids()
                gmap = [self._seq_of[i] for i in live_ids]
                rdict = {seq: row for row, seq in enumerate(gmap)}
                self._views[s] = _ShardView(
                    view.replicas, view.health, gmap, rdict,
                    tuple(c.epoch for c in view.replicas), view.rr)
            self._topology_gen += 1
        return reclaimed

    def _shard_range(self, shard: Optional[int]) -> List[int]:  # requires-lock: _lock
        if shard is None:
            return list(range(len(self._views)))
        if not 0 <= shard < len(self._views):
            raise ValueError(f"shard must be in [0, {len(self._views)}), "
                             f"got {shard}")
        return [shard]

    def _check_open(self) -> None:      # requires-lock: _lock
        if self._closed:
            raise CollectionClosed(f"collection {self.name!r} is closed")

    # ------------------------------------------------------------ replication
    def set_replica_health(self, shard: int, replica: int, up: bool) -> None:
        """Mark one replica (un)servable.  Reads route around down
        replicas; writes still apply everywhere (a down replica is slow or
        briefly unreachable, not forgotten)."""
        with self._lock:
            view = self._views[self._shard_range(shard)[0]]
            if not 0 <= replica < len(view.replicas):
                raise ValueError(f"replica must be in "
                                 f"[0, {len(view.replicas)}), got {replica}")
            view.health[replica] = bool(up)

    def _replica_order(self, view: _ShardView, shard: int) -> List[int]:
        """Healthy replica indices, round-robin rotated so concurrent reads
        spread across replicas."""
        n = len(view.replicas)
        start = next(view.rr) % n
        order = [(start + i) % n for i in range(n)]
        healthy = [ri for ri in order if view.health[ri]]
        if not healthy:
            raise ShardUnavailable(
                f"all {n} replica(s) of shard {shard} are marked down")
        return healthy

    def _on_replica(self, view: _ShardView, shard: int, call):
        """Run `call(col)` on the first healthy replica that answers,
        failing over past replicas that raise.  Schema errors are
        deterministic — every replica would refuse identically — so they
        propagate instead of burning the failover budget."""
        last: Optional[BaseException] = None
        for ri in self._replica_order(view, shard):
            try:
                return ri, call(view.replicas[ri])
            except SchemaError:
                raise
            except Exception as e:          # failover to the next replica
                last = e
        raise ShardUnavailable(
            f"all replicas of shard {shard} failed the request") from last

    # ------------------------------------------------------- scatter plumbing
    def _scatter(self, views: List[_ShardView], fn) -> List[Any]:
        if len(views) == 1:
            return [fn(0, views[0])]
        futs = [self._pool.submit(fn, s, v) for s, v in enumerate(views)]
        return [f.result() for f in futs]

    @staticmethod
    def _merge_legs(legs, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-shard (Q, k_s) candidates -> exact global (Q, k) top-k in
        seq space.  Sort key (distance, seq) reproduces the single-engine
        tie-break (ascending row) because seq order IS row order."""
        n_q = legs[0][3].shape[0]
        out_d = np.full((n_q, k), np.inf, dtype=np.float32)
        out_i = np.full((n_q, k), -1, dtype=np.int64)
        for q in range(n_q):
            pairs: List[Tuple[float, int]] = []
            for _s, _ri, view, d, rows, _sec in legs:
                gmap = view.gmap
                for dist, row in zip(d[q], rows[q]):
                    if row < 0 or not np.isfinite(dist):
                        continue
                    pairs.append((float(dist), gmap[int(row)]))
            pairs.sort()
            for slot, (dist, seq) in enumerate(pairs[:k]):
                out_d[q, slot] = dist
                out_i[q, slot] = seq
        return out_d, out_i

    def _make_search_fn(self, views: List[_ShardView], log):
        def search_fn(queries, k, flt=None, params=None):
            def leg(s, view):
                t0 = time.perf_counter()
                ri, (d, rows) = self._on_replica(
                    view, s, lambda col: col._engine_search(
                        queries, k, flt=flt, params=params))
                return s, ri, view, np.atleast_2d(d), np.atleast_2d(rows), \
                    time.perf_counter() - t0
            legs = self._scatter(views, leg)
            log.append({"op": "ann", "shards": [
                {"shard": s, "replica": ri, "seconds": sec}
                for s, ri, _v, _d, _r, sec in legs]})
            return self._merge_legs(legs, k)
        return search_fn

    def _make_sparse_fn(self, views: List[_ShardView], log):
        def sparse_fn(field, text, k, flt=None):
            # phase 1: integer corpus statistics from every shard, summed
            # BEFORE any float division -> global IDF/norms, bit-identical
            # to a single index over the union corpus
            parts = self._scatter(views, lambda s, view: self._on_replica(
                view, s,
                lambda col: col._sparse_term_stats(field, text))[1])
            stats = CorpusStats.aggregate(parts)

            def leg(s, view):
                t0 = time.perf_counter()
                ri, (d, rows) = self._on_replica(
                    view, s, lambda col: col._sparse_search(
                        field, text, k, flt=flt, stats=stats))
                return s, ri, view, d, rows, time.perf_counter() - t0
            legs = self._scatter(views, leg)
            log.append({"op": "sparse", "shards": [
                {"shard": s, "replica": ri, "seconds": sec}
                for s, ri, _v, _d, _r, sec in legs]})
            return self._merge_legs(legs, k)
        return sparse_fn

    class _ScatterEngine:
        """Engine facade for `PlanExecutor`'s rescore stage: candidates
        arrive as seq ids, are routed to their owning shards, exact-rescored
        against full-precision local vectors, and merged exactly."""

        def __init__(self, owner: "ShardedCollection",
                     views: List[_ShardView], log):
            self._owner = owner
            self._views = views
            self._log = log

        def exact_rescore(self, queries, cand_ids, k, mask=None):
            cand_ids = np.asarray(cand_ids, dtype=np.int64)
            n_q, n_c = cand_ids.shape

            def leg(s, view):
                t0 = time.perf_counter()
                local = np.full((n_q, n_c), -1, dtype=np.int64)
                rdict = view.rdict
                for q in range(n_q):
                    for c in range(n_c):
                        seq = int(cand_ids[q, c])
                        if seq >= 0:
                            local[q, c] = rdict.get(seq, -1)
                ri, (d, rows) = self._owner._on_replica(
                    view, s, lambda col: col._rescore_local(
                        queries, local, min(k, n_c)))
                return s, ri, view, d, rows, time.perf_counter() - t0
            legs = self._owner._scatter(self._views, leg)
            self._log.append({"op": "rescore", "shards": [
                {"shard": s, "replica": ri, "seconds": sec}
                for s, ri, _v, _d, _r, sec in legs]})
            return self._owner._merge_legs(legs, k)

    # ----------------------------------------------------------------- reads
    def get(self, id: str) -> Optional[Entity]:
        with self._lock:
            self._check_open()
            if id not in self._seq_of:
                return None
            shard = self._router.shard_of(id)
            view = self._views[shard]
        _ri, ent = self._on_replica(view, shard, lambda col: col.get(id))
        return ent

    def count(self, flt: Optional[Filter] = None) -> int:
        if flt is not None:
            flt = validate_filter(self.schema, flt)
        with self._lock:
            self._check_open()
            views = list(self._views)
        return sum(self._on_replica(v, s, lambda col: col.count(flt))[1]
                   for s, v in enumerate(views))

    def query(self, vector: Optional[np.ndarray] = None) -> Query:
        return Query(self, vector)

    def recommend(self, positives: Sequence[Any],
                  negatives: Sequence[Any] = ()) -> Query:
        return Query(self, recommend_vector(self, positives, negatives))

    def search(self, vectors: np.ndarray, k: int,
               flt: Optional[Filter] = None, ef: Optional[int] = None,
               rescore: Optional[bool] = None,
               expansion_width: Optional[int] = None,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Array API over the scatter-gather path.  Returned ids are GLOBAL
        seq numbers (use `search_ids` for string ids) — exactly the row
        numbers a single engine fed the same upsert stream would return."""
        if flt is not None:
            flt = validate_filter(self.schema, flt)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        plan = QueryPlan(k=k, vector=np.asarray(vectors, np.float32),
                         stages=(AnnStage(k=k, ef=ef,
                                          expansion_width=expansion_width,
                                          filter=flt, rescore=rescore),))
        with self._lock:
            res = self._execute_direct(plan)
        return res.distances, res.ids

    def search_ids(self, vectors: np.ndarray, k: int, **kw
                   ) -> Tuple[np.ndarray, np.ndarray]:
        with self._lock:
            d, seqs = self.search(vectors, k, **kw)
            ids = np.empty(seqs.shape, dtype=object)
            for idx, seq in np.ndenumerate(seqs):
                ids[idx] = (self._id_of_seq.get(int(seq))
                            if seq >= 0 and np.isfinite(d[idx]) else None)
            return d, ids

    # -------------------------------------------------------- plan execution
    def _execute_direct(self, plan: QueryPlan,     # requires-lock: _lock
                        deadline: Optional[float] = None) -> ExecResult:
        self._check_open()
        if not self._seq_of:
            n = len(np.asarray(plan.vector)) if plan.batched else 1
            return ExecResult(
                distances=np.full((n, plan.k), np.inf, dtype=np.float32),
                ids=np.full((n, plan.k), -1, dtype=np.int64),
                stages=[])
        views = list(self._views)
        log: List[Dict[str, Any]] = []
        has_text = bool(self.schema.text_fields())
        executor = PlanExecutor(
            self._make_search_fn(views, log),
            self._ScatterEngine(self, views, log),
            mask=None,     # per-shard legs apply their own liveness masks
            sparse_fn=self._make_sparse_fn(views, log) if has_text else None)
        res = executor.execute(plan, deadline=deadline)
        self._attach_shard_timings(res.stages, log)
        return res

    @staticmethod
    def _attach_shard_timings(reports: List[Dict[str, Any]],
                              log: List[Dict[str, Any]]) -> None:
        """Zip the chronological scatter log onto the executor's stage tree
        (depth-first, prefetch children before later siblings — the order
        stages actually executed)."""
        it = iter(log)

        def walk(stage_list):
            for rep in stage_list:
                for child in rep.get("children") or []:
                    walk(child)
                if rep["stage"] in ("ann", "sparse", "rescore"):
                    entry = next(it, None)
                    if entry is not None and entry["op"] == rep["stage"]:
                        rep["shards"] = entry["shards"]
        walk(reports)

    def _locate_seq(self, seq: int, views: List[_ShardView]
                    ) -> Optional[Tuple[int, _ShardView, int]]:
        for s, view in enumerate(views):
            row = view.rdict.get(seq)
            if row is not None:
                return s, view, row
        return None

    def execute_plan(self, plan: QueryPlan, *, include_vector: bool = False,
                     timeout: float = 120.0, explain: bool = False
                     ) -> Union[List[Hit], List[List[Hit]], PlanExplain]:
        """THE read path, mirroring `Collection.execute_plan`: trivial
        single-vector plans coalesce in the collection-level batcher and
        scatter as aligned waves (lock-free, epoch-validated, retried on
        topology races); everything else scatter-gathers under the
        collection lock."""
        plan = validate_plan(self.schema, plan)
        if plan.trivial and not plan.batched and not explain:
            for _ in range(5):
                try:
                    return self._trivial_query(plan, include_vector, timeout)
                except _ViewChanged:
                    continue
            raise QueryRetriesExhausted(
                f"collection {self.name!r} kept changing topology during "
                f"the query")
        deadline = time.perf_counter() + timeout
        with self._lock:
            res = self._execute_direct(plan, deadline=deadline)
            views = list(self._views)
            if plan.batched:
                hits: Any = [self._hits_row(res.distances[i], res.ids[i],
                                            views, include_vector)
                             for i in range(len(res.ids))]
            else:
                hits = self._hits_row(res.distances[0], res.ids[0],
                                      views, include_vector)
        if explain:
            return PlanExplain(plan=plan_to_dict(plan), stages=res.stages,
                               hits=hits)
        return hits

    def _hits_row(self, d: np.ndarray, seqs: np.ndarray,
                  views: List[_ShardView], include_vector: bool,
                  guard_epochs: bool = False) -> List[Hit]:
        """One query row of merged (distance, seq) candidates -> Hits.
        Direct-path callers hold `_lock` (topology cannot move under them);
        the lock-free trivial path passes `guard_epochs=True` so a compact
        racing the payload fetch surfaces as `_ViewChanged`, never as a
        payload for the wrong row."""
        buckets: Dict[Tuple[int, int], List[Tuple[int, float, int]]] = {}
        slot_of: List[Tuple[int, Tuple[int, int], int]] = []
        for slot, (dist, seq) in enumerate(zip(d, seqs)):
            seq = int(seq)
            if seq < 0 or not np.isfinite(dist):
                continue
            loc = self._locate_seq(seq, views)
            if loc is None:               # deleted mid-plan: drop the slot
                continue
            s, view, row = loc
            ri = self._replica_order(view, s)[0]
            bucket = buckets.setdefault((s, ri), [])
            slot_of.append((slot, (s, ri), len(bucket)))
            bucket.append((slot, float(dist), row))
        fetched = {}
        for (s, ri), bucket in buckets.items():
            view = views[s]
            hits = view.replicas[ri].hits_at(
                np.asarray([b[1] for b in bucket], dtype=np.float32),
                np.asarray([b[2] for b in bucket], dtype=np.int64),
                include_vector,
                epoch=view.epochs[ri] if guard_epochs else None)
            if hits is None:
                if guard_epochs:
                    raise _ViewChanged()    # compact raced the fetch
                hits = [None] * len(bucket)
            fetched[(s, ri)] = hits
        out: List[Hit] = []
        for _slot, key, pos in sorted(slot_of):
            hit = fetched[key][pos]
            if hit is not None:
                out.append(hit)
        return out

    @property
    def batcher(self) -> RequestBatcher:
        """Lazily-started collection-LEVEL serving batcher.

        One coalescing point for the whole sharded collection: every
        flushed batch scatters to all shards in a single aligned wave
        (`_batched_scatter`).  Per-shard batchers would be wrong here —
        a request needs ALL shards to answer, so N independent flush
        cycles make each caller wait for the max over N staggered
        deadlines and fragment concurrent waves into partial batches.
        Creation is locked (parallel first queries must share one worker);
        the hot path stays lock-free."""
        # _batcher only ever goes None -> instance (close() nulls it, but
        # post-close submits fail typed anyway), so a stale fast-path read
        # just falls through to the locked slow path
        batcher = self._batcher  # unguarded-ok: lock-free fast path, re-checked under init lock
        if batcher is None:
            with self._batcher_init_lock:
                if self._closed:  # unguarded-ok: close() flips it holding _batcher_init_lock too
                    raise CollectionClosed(   # don't resurrect past close()
                        f"collection {self.name!r} is closed")
                batcher = self._batcher
                if batcher is None:
                    cfg = self.schema.batcher or BatcherConfig()
                    batcher = RequestBatcher(self._batched_scatter,
                                             max_batch=cfg.max_batch,
                                             max_wait_ms=cfg.max_wait_ms)
                    self._batcher = batcher
        return batcher

    def _batched_scatter(self, queries: np.ndarray, k: int,
                         flt: Optional[Filter] = None,
                         params: Optional[AnnParams] = None,
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """search_fn behind `batcher`: one coalesced batch -> one aligned
        scatter across every shard -> exact (distance, seq) merge.  Runs on
        the batcher worker WITHOUT the collection lock; correctness against
        concurrent compact/rebalance comes from the per-replica epoch check
        after each leg — stale rows raise `_ViewChanged`, which fails the
        whole batch and every coalesced caller retries."""
        views = list(self._views)  # unguarded-ok: snapshot; epochs validated per leg below

        def leg(s, view):
            last: Optional[BaseException] = None
            closed = 0
            order = self._replica_order(view, s)
            for ri in order:
                col = view.replicas[ri]
                try:
                    d, rows = col._engine_search(queries, k, flt=flt,
                                                 params=params)
                except SchemaError:
                    raise               # deterministic: no replica differs
                except CollectionClosed as e:
                    closed += 1         # rebalance swapped this replica out
                    last = e
                    continue
                except Exception as e:  # failover to the next replica
                    last = e
                    continue
                if col.epoch != view.epochs[ri]:
                    raise _ViewChanged()    # compact raced: rows are stale
                return (s, ri, view, np.atleast_2d(d), np.atleast_2d(rows),
                        0.0)
            if closed == len(order):    # whole view is dead, not just down
                raise _ViewChanged() from last
            raise ShardUnavailable(
                f"all replicas of shard {s} failed the search") from last

        legs = self._scatter(views, leg)
        return self._merge_legs(legs, k)

    def _trivial_query(self, plan: QueryPlan, include_vector: bool,
                       timeout: float) -> List[Hit]:
        """Fast path: one plain ANN stage, one query vector, no collection
        lock.  Requests coalesce in the collection-level `batcher`; each
        flushed batch scatters to all shards as ONE aligned wave, so
        concurrent callers share the scatter overhead.  Results come back
        in (distance, seq) space and are re-validated — epoch checks
        inside the scatter, epoch-guarded payload fetch, and a topology-
        generation check bracketing the whole round trip (a rebalance
        renumbers seqs, so even epoch-fresh views could misread stale
        seqs) — a racing compact()/rebalance() surfaces as `_ViewChanged`
        (retried by `execute_plan`), never as wrong ids."""
        if self._closed:  # unguarded-ok: racing close() re-detected via BatcherClosed below
            raise CollectionClosed(f"collection {self.name!r} is closed")
        stage = plan.stages[0]
        params = AnnParams.or_none(ef=stage.ef,
                                   expansion_width=stage.expansion_width,
                                   rescore=stage.rescore)
        gen = self._topology_gen  # unguarded-ok: snapshot; re-checked after the fetch
        try:
            fut = self.batcher.submit(np.asarray(plan.vector, np.float32),
                                      plan.k, flt=stage.filter,
                                      params=params)
            d, seqs = fut.result(timeout=timeout)
        except BatcherClosed as e:
            raise CollectionClosed(
                f"collection {self.name!r} is closed") from e
        views = list(self._views)  # unguarded-ok: snapshot; gen re-checked below
        hits = self._hits_row(d, seqs, views, include_vector,
                              guard_epochs=True)
        if self._topology_gen != gen:  # unguarded-ok: single int read
            raise _ViewChanged()       # seq numbering may have been rebuilt
        return hits

    # ------------------------------------------------------------- rebalance
    def rebalance(self, shards: Optional[int] = None,
                  replicas: Optional[int] = None,
                  snapshot_dir: Optional[str] = None) -> Dict[str, Any]:
        """Re-partition onto `shards` shards x `replicas` replicas (either
        None = keep current).  Snapshot-based: sources are checkpointed,
        restored, and re-upserted under the new even slot map."""
        with self._lock:
            self._check_open()
            new_shards = len(self._views) if shards is None else int(shards)
            router = (self._router if new_shards == len(self._views)
                      else Router.even(new_shards))
            return self._rebuild(router, replicas, snapshot_dir)

    def split(self, shard: int,
              snapshot_dir: Optional[str] = None) -> Dict[str, Any]:
        """Scale-out primitive: half of `shard`'s hash slots (and their
        rows) move to a new shard appended at the end."""
        with self._lock:
            self._check_open()
            self._shard_range(shard)
            return self._rebuild(self._router.split(shard), None,
                                 snapshot_dir)

    def move_slot(self, slot: int, to_shard: int,
                  snapshot_dir: Optional[str] = None) -> Dict[str, Any]:
        """Move one hash slot to another shard (the unit step every larger
        rebalance decomposes into)."""
        with self._lock:
            self._check_open()
            return self._rebuild(self._router.moved(slot, to_shard),
                                 None, snapshot_dir)

    def _rebuild(self, router: Router,            # requires-lock: _lock
                 replicas: Optional[int],
                 snapshot_dir: Optional[str]) -> Dict[str, Any]:
        t0 = time.perf_counter()
        n_replicas = (len(self._views[0].replicas) if replicas is None
                      else int(replicas))
        if not 1 <= n_replicas <= CollectionSchema.MAX_REPLICAS:
            raise ValueError(f"replicas must be in "
                             f"[1, {CollectionSchema.MAX_REPLICAS}], "
                             f"got {n_replicas}")
        tmp = None
        if snapshot_dir is None:
            tmp = tempfile.mkdtemp(prefix="quantixar-rebalance-")
            snapshot_dir = tmp
        old_views = self._views
        try:
            # 1. snapshot every source shard through its OWN store (the
            #    ShardedCheckpoint layout — one store per shard keeps the
            #    per-store generation GC from eating sibling snapshots);
            #    this is the artifact a cross-node move would ship, the
            #    gmap riding in the manifest
            stores = [CheckpointStore(os.path.join(snapshot_dir,
                                                   f"shard-{s:04d}"))
                      for s in range(len(old_views))]
            gens = []
            for s, view in enumerate(old_views):
                gens.append(stores[s].save(
                    view.replicas[0].state_dict(), shard_id=s,
                    num_shards=len(old_views),
                    extra={"collection": self.schema.name, "shard": s,
                           "gmap": [int(x) for x in view.gmap]}))
            # 2. restore from the snapshots (NOT the live shards) and
            #    order every live row by its global seq
            entries: List[Tuple[int, str, np.ndarray, Dict[str, Any]]] = []
            for s, gen in enumerate(gens):
                state = stores[s].load(gen)
                gmap = stores[s].manifest(gen).extra["gmap"]
                restored = Collection.from_state_dict(
                    self._shard_schema(s, 0), state)
                try:
                    for row, (id_, alive) in enumerate(
                            zip(state["__ids__"], state["__live__"])):
                        if not alive:
                            continue
                        ent = restored.get(str(id_))
                        entries.append((int(gmap[row]), str(id_),
                                        ent.vector, ent.payload))
                finally:
                    restored.close()
            entries.sort(key=lambda e: e[0])
            # 3. build the new shard set; fresh compact seqs 0..n-1 in the
            #    old global order keep tie-breaks identical to a
            #    single-engine compact()
            self.schema = dataclasses.replace(
                self.schema, shards=router.num_shards, replicas=n_replicas)
            new_views = [self._make_shard(s, n_replicas)
                         for s in range(router.num_shards)]
            seq_of: Dict[str, int] = {}
            id_of_seq: Dict[int, str] = {}
            per_shard: Dict[int, List[int]] = {}
            for seq, (_old_seq, id_, _v, _p) in enumerate(entries):
                seq_of[id_] = seq
                id_of_seq[seq] = id_
                per_shard.setdefault(router.shard_of(id_), []).append(seq)
            for s, seqs in sorted(per_shard.items()):
                view = new_views[s]
                ids = [entries[q][1] for q in seqs]
                vecs = np.stack([entries[q][2] for q in seqs])
                pls = [entries[q][3] for q in seqs]
                for col in view.replicas:
                    col.upsert(ids, vecs, pls)
                view.gmap.extend(seqs)
                for row, seq in enumerate(seqs):
                    view.rdict[seq] = row
            # 4. swap; in-flight batcher-path queries hit CollectionClosed
            #    on the old replicas and retry against the new views
            self._router = router
            self._views = new_views
            self._seq_of = seq_of
            self._id_of_seq = id_of_seq
            self._next_seq = len(entries)
            self._topology_gen += 1
        finally:
            if tmp is not None:
                shutil.rmtree(tmp, ignore_errors=True)
        for view in old_views:
            for col in view.replicas:
                col.close()
        return {"shards": router.num_shards, "replicas": n_replicas,
                "rows": len(self._seq_of),
                "seconds": time.perf_counter() - t0}

    # --------------------------------------------------------------- service
    def close(self) -> None:
        # lock order: _lock, then _batcher_init_lock — mirrors `Collection`
        # so the traced-lock graph stays acyclic; holding both means the
        # batcher property and direct-path queries each see _closed flip
        # atomically
        with self._lock:
            with self._batcher_init_lock:
                if self._closed:
                    return
                self._closed = True
                batcher, self._batcher = self._batcher, None
            views = self._views
        self._pool.shutdown(wait=False)
        # join the batcher worker outside the sharded lock (it takes only
        # the per-shard collections' locks)
        if batcher is not None:
            batcher.close()
        for view in views:
            for col in view.replicas:
                col.close()

    def stats(self) -> Dict[str, Any]:
        per = self.shard_stats()
        agg: Dict[str, Any] = {
            "name": self.name,
            "shards": len(per),
            "replicas": self.schema.replicas,
            "hash_slots": HASH_SLOTS,
            "n": sum(p["rows"] for p in per),
            "live": sum(p["live"] for p in per),
            "tombstones": sum(p["tombstones"] for p in per),
            "per_shard": per,
        }
        # serving counters come from the collection-level batcher (the
        # trivial-query coalescing point); snapshot the attribute — a
        # concurrent close() may null it between the check and the call
        batcher = self._batcher  # unguarded-ok: atomic snapshot; batcher.stats() is safe post-close
        serving = (batcher.stats() if batcher is not None
                   else RequestBatcher.zero_stats())
        agg.update({f"serving_{k}": v for k, v in serving.items()})
        agg["serving_queue_depth"] += sum(p["queue_depth"] for p in per)
        return agg

    def shard_stats(self) -> List[Dict[str, Any]]:
        """Per-shard rows/tombstones/queue depth + routing and health —
        the payload behind the wire `ShardStats` op."""
        with self._lock:
            self._check_open()
            views = list(self._views)
            router = self._router
        out = []
        for s, view in enumerate(views):
            reps = [col.shard_stats()[0] for col in view.replicas]
            out.append({
                "shard": s,
                "replicas": len(view.replicas),
                "rows": reps[0]["rows"],
                "live": reps[0]["live"],
                "tombstones": reps[0]["tombstones"],
                "queue_depth": sum(r["queue_depth"] for r in reps),
                "slots": router.slots_of_shard(s),
                "health": [bool(h) for h in view.health],
            })
        return out

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Flat array state: routing table + per-shard sub-states (replica
        0 only — replicas are bit-identical and re-fan-out on load)."""
        with self._lock:
            state: Dict[str, np.ndarray] = {
                "__cluster__slot_map": np.asarray(self._router.slot_map,
                                                  dtype=np.int64),
                "__cluster__next_seq": np.asarray([self._next_seq],
                                                  dtype=np.int64),
            }
            for s, view in enumerate(self._views):
                state[f"__cluster__gmap{s}"] = np.asarray(view.gmap,
                                                          dtype=np.int64)
                for key, arr in view.replicas[0].state_dict().items():
                    state[f"__cluster__shard{s}__{key}"] = arr
            return state

    @classmethod
    def from_state_dict(cls, schema: CollectionSchema,
                        state: Dict[str, np.ndarray]) -> "ShardedCollection":
        obj = cls.__new__(cls)
        obj.schema = schema
        obj._router = Router(
            [int(x) for x in state["__cluster__slot_map"]])
        obj._next_seq = int(state["__cluster__next_seq"][0])
        obj._seq_of = {}
        obj._id_of_seq = {}
        obj._views = []
        obj._closed = False
        obj._scatter_log = []
        obj._pool = ThreadPoolExecutor(max_workers=16,
                                       thread_name_prefix="qx-shard")
        obj._lock = threading.RLock()
        obj._batcher = None
        obj._batcher_init_lock = threading.Lock()
        obj._topology_gen = 0
        for s in range(obj._router.num_shards):
            prefix = f"__cluster__shard{s}__"
            sub = {k[len(prefix):]: v for k, v in state.items()
                   if k.startswith(prefix)}
            gmap = [int(x) for x in state[f"__cluster__gmap{s}"]]
            replicas = []
            for r in range(schema.replicas):
                # replicas must not alias each other's arrays: each engine
                # mutates its own copies as writes land post-load
                rsub = (sub if r == 0 else
                        {k: np.array(v, copy=True) for k, v in sub.items()})
                replicas.append(Collection.from_state_dict(
                    obj._shard_schema(s, r), rsub))
            rdict: Dict[int, int] = {}
            for row, (id_, alive) in enumerate(
                    zip(sub["__ids__"], sub["__live__"])):
                if not alive:
                    continue
                seq = gmap[row]
                obj._seq_of[str(id_)] = seq
                obj._id_of_seq[seq] = str(id_)
                rdict[seq] = row
            obj._views.append(_ShardView(
                tuple(replicas), [True] * schema.replicas, gmap, rdict,
                tuple(c.epoch for c in replicas)))
        return obj
