"""Hash-slot routing: string id -> slot -> shard.

Entities are partitioned by a fixed-size hash-slot space (Redis-cluster
style) rather than `hash(id) % num_shards`: the id -> slot mapping is
immutable, so rebalancing moves *slots* between shards (a small routing
table update plus the rows in the moved slots) instead of rehashing the
whole corpus.  `blake2b` keys the slot so routing is deterministic across
processes and Python runs (`hash()` is salted per process).
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence

HASH_SLOTS = 64


def slot_of(id: str) -> int:
    """Deterministic id -> slot in [0, HASH_SLOTS)."""
    digest = hashlib.blake2b(id.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(digest, "big") % HASH_SLOTS


class Router:
    """Immutable slot -> shard routing table."""

    def __init__(self, slot_map: Sequence[int]):
        slot_map = [int(s) for s in slot_map]
        if len(slot_map) != HASH_SLOTS:
            raise ValueError(f"slot_map must cover all {HASH_SLOTS} slots, "
                             f"got {len(slot_map)}")
        self.num_shards = max(slot_map) + 1
        if min(slot_map) < 0:
            raise ValueError("slot_map entries must be >= 0")
        if set(slot_map) != set(range(self.num_shards)):
            raise ValueError("every shard in [0, max] must own >= 1 slot")
        self.slot_map = tuple(slot_map)

    @classmethod
    def even(cls, num_shards: int) -> "Router":
        """Round-robin slot assignment (the create-time default)."""
        if not 1 <= num_shards <= HASH_SLOTS:
            raise ValueError(f"num_shards must be in [1, {HASH_SLOTS}], "
                             f"got {num_shards}")
        return cls([s % num_shards for s in range(HASH_SLOTS)])

    def shard_of(self, id: str) -> int:
        return self.slot_map[slot_of(id)]

    def partition(self, ids: Sequence[str]) -> Dict[int, List[int]]:
        """Batch indices grouped by owning shard (batch order preserved
        within each group — seq assignment depends on this)."""
        parts: Dict[int, List[int]] = {}
        for idx, id_ in enumerate(ids):
            parts.setdefault(self.shard_of(id_), []).append(idx)
        return parts

    def slots_of_shard(self, shard: int) -> List[int]:
        return [slot for slot, s in enumerate(self.slot_map) if s == shard]

    # ------------------------------------------------------------ rebalance
    def moved(self, slot: int, to_shard: int) -> "Router":
        """Routing table with one slot reassigned (shard move primitive)."""
        if not 0 <= slot < HASH_SLOTS:
            raise ValueError(f"slot must be in [0, {HASH_SLOTS}), got {slot}")
        new = list(self.slot_map)
        new[slot] = to_shard
        return Router(new)

    def split(self, shard: int) -> "Router":
        """Give the second half of `shard`'s slots to a new shard appended
        at index `num_shards` (scale-out primitive)."""
        slots = self.slots_of_shard(shard)
        if len(slots) < 2:
            raise ValueError(f"shard {shard} owns {len(slots)} slot(s); "
                             f"need >= 2 to split")
        new = list(self.slot_map)
        for slot in slots[len(slots) // 2:]:
            new[slot] = self.num_shards
        return Router(new)
