"""Quantixar-JAX: distributed vector data management on TPU (paper repro)."""

__version__ = "1.0.0"
