"""Optimizers."""

from .adamw import AdamWConfig, AdamWState, apply_updates, global_norm, init, make_schedule
from .compression import (compress_decompress, compression_ratio,
                          init_error_feedback)
