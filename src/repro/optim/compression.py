"""Gradient compression with error feedback — distributed-optimization trick
for DCN-limited multi-pod training (DESIGN.md §6).

The cross-pod gradient all-reduce is the only DCN traffic in the
(pod, data, model) layout; int8 quantization cuts it 4x.  Deterministic
per-leaf symmetric quantization (scale = max|g|/127) is biased, so an
error-feedback accumulator carries the residual into the next step (EF-SGD:
Seide et al. / Karimireddy et al.) — convergence matches uncompressed SGD on
convex probes (tests/test_infra.py::TestGradCompression).

Usage (launch/train.py --grad-compress):
    ef = init_error_feedback(params)
    grads_c, ef = compress_decompress(grads, ef)   # wire format boundary
    ... apply_updates(params, grads_c, ...)
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8: returns (codes int8, scale f32 scalar)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_leaf(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compress_decompress(grads: PyTree, ef: PyTree) -> Tuple[PyTree, PyTree]:
    """int8 round-trip with error feedback.

    Returns (dequantized grads — what the receiving side applies,
             new error-feedback state = what the wire dropped).
    In a real deployment the int8 codes are what crosses DCN; jit'd
    end-to-end the quant/dequant pair IS the wire boundary.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        codes, scale = quantize_leaf(target)
        deq = dequantize_leaf(codes, scale)
        return deq, target - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_ratio(grads: PyTree) -> float:
    """fp32 bytes / (int8 codes + scales) — the DCN saving."""
    f32 = sum(l.size * 4 for l in jax.tree_util.tree_leaves(grads))
    i8 = sum(l.size * 1 + 4 for l in jax.tree_util.tree_leaves(grads))
    return f32 / i8
