"""AdamW + schedules — self-contained (no optax in the container).

State layout mirrors the param pytree (m, v per leaf) so the distributed
sharding policy applies transparently: optimizer state inherits each param's
PartitionSpec, which is what keeps the 3×fp32 memory footprint sharded on the
FSDP axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"       # "cosine" | "linear" | "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: PyTree
    v: PyTree


def make_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1.0 - frac)
        else:
            decay = jnp.array(1.0)
        return cfg.lr * warm * decay

    return sched


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params: PyTree, grads: PyTree, state: AdamWState,
                  cfg: AdamWConfig) -> Tuple[PyTree, AdamWState, jax.Array]:
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    sched = make_schedule(cfg)
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    step = state.step + 1
    lr = sched(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
