"""Train / serve step factories — the functions the launcher jits and shards.

train_step: CE loss (fp32 logsumexp) + MoE aux + AdamW.  serve_step: one
decode step over a KV/recurrent-state cache.  Both are pure functions of
(state, batch) so pjit in/out shardings apply directly.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..optim import adamw
from .config import ModelConfig
from .model import DecodeState, decode_step, forward

Array = jax.Array

MOE_AUX_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def cross_entropy(logits: Array, targets: Array, mask: Array) -> Array:
    """Mean CE over mask; logits fp32 (B, S, V)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch) -> Tuple[Array, Dict[str, Array]]:
        logits, aux = forward(params, batch, cfg)
        mask = batch.get("segment_ids",
                         jnp.ones_like(batch["targets"])).astype(jnp.float32)
        ce = cross_entropy(logits, batch["targets"], mask)
        loss = ce + MOE_AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    loss_fn = make_loss_fn(cfg)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        params, opt, gnorm = adamw.apply_updates(
            state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=opt.step.astype(jnp.float32))
        return TrainState(params=params, opt=opt), metrics

    return train_step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True,
                    temperature: float = 1.0):
    def serve_step(params, state: DecodeState, tokens: Array
                   ) -> Tuple[Array, DecodeState]:
        """tokens (B, 1) current token -> (next_token (B, 1), new state)."""
        logits, new_state = decode_step(params, state, tokens, cfg)
        if greedy:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        else:
            key = jax.random.fold_in(jax.random.PRNGKey(0), state.pos[0])
            nxt = jax.random.categorical(key, logits[:, -1, :] / temperature)
        return nxt[:, None].astype(jnp.int32), new_state

    return serve_step


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    from .model import init_params
    params = init_params(key, cfg)
    return TrainState(params=params, opt=adamw.init(params))


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg), jax.random.PRNGKey(0))
