"""Block-level init/apply dispatch for every block type, train + decode paths.

A "unit" is one period of cfg.block_pattern; the model scans over stacked
units (model.py).  Each block is pre-norm residual; mlstm/slstm are
self-contained (their FFN/gating is internal, following xLSTM).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import recurrent as R

Array = jax.Array
Params = Dict[str, Any]


def block_window(cfg: ModelConfig, block_type: str) -> int:
    if block_type.startswith("swa"):
        return cfg.window
    if block_type == "local_attn":
        return cfg.local_window
    return 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, block_type: str,
               with_cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {}
    if block_type in ("attn", "swa", "local_attn", "attn_moe", "swa_moe"):
        p["norm1"] = L.init_norm(cfg)
        p["attn"] = L.init_attention(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg)
        if block_type.endswith("moe"):
            p["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    elif block_type == "rglru":
        p["norm1"] = L.init_norm(cfg)
        p["rglru"] = R.init_rglru(ks[0], cfg)
        p["norm2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif block_type == "mlstm":
        p["norm1"] = L.init_norm(cfg)
        p["mlstm"] = R.init_mlstm(ks[0], cfg)
    elif block_type == "slstm":
        p["norm1"] = L.init_norm(cfg)
        p["slstm"] = R.init_slstm(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(block_type)
    if with_cross:
        p["cross_norm"] = L.init_norm(cfg)
        p["cross"] = L.init_attention(ks[5], cfg, cross=True)
    return p


# ---------------------------------------------------------------------------
# train (full sequence)
# ---------------------------------------------------------------------------

def apply_block_train(p: Params, x: Array, cfg: ModelConfig, block_type: str,
                      positions: Array, *, causal: bool = True,
                      enc_out: Optional[Array] = None,
                      enc_pos: Optional[Array] = None) -> Tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if block_type in ("attn", "swa", "local_attn", "attn_moe", "swa_moe"):
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + L.attention_full(p["attn"], h, cfg, positions, causal=causal,
                                 window=block_window(cfg, block_type))
        if "cross" in p and enc_out is not None:
            h = L.apply_norm(p["cross_norm"], x, cfg)
            k, v, kp = _cross_kv(p["cross"], enc_out, cfg, enc_pos)
            x = x + L.attention_full(p["cross"], h, cfg, positions,
                                     causal=False, window=0,
                                     kv_override=(k, v, kp))
        h = L.apply_norm(p["norm2"], x, cfg)
        if block_type.endswith("moe"):
            delta, aux = L.apply_moe(p["moe"], h, cfg)
            x = x + delta
        else:
            x = x + L.apply_mlp(p["mlp"], h, cfg)
    elif block_type == "rglru":
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + R.apply_rglru(p["rglru"], h, cfg)
        h = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], h, cfg)
    elif block_type == "mlstm":
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + R.apply_mlstm(p["mlstm"], h, cfg)
    elif block_type == "slstm":
        h = L.apply_norm(p["norm1"], x, cfg)
        x = x + R.apply_slstm(p["slstm"], h, cfg)
    return x, aux


def _cross_kv(p_attn: Params, enc_out: Array, cfg: ModelConfig,
              enc_pos: Optional[Array]):
    """K/V projections of encoder output for cross-attention (no RoPE)."""
    b, t, _ = enc_out.shape
    dt = enc_out.dtype
    nkv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p_attn["wk"].astype(dt)).reshape(b, t, nkv, dh)
    v = (enc_out @ p_attn["wv"].astype(dt)).reshape(b, t, nkv, dh)
    if enc_pos is None:
        enc_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    return k, v, enc_pos


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array     # (B, S_cache, nkv, dh)
    v: Array


def block_state_init(cfg: ModelConfig, block_type: str, batch: int,
                     cache_len: int, dtype) -> Any:
    nkv, dh = cfg.n_kv_heads, cfg.head_dim
    w = block_window(cfg, block_type)
    if block_type in ("attn", "swa", "local_attn", "attn_moe", "swa_moe"):
        s = min(cache_len, w) if w > 0 else cache_len
        z = jnp.zeros((batch, s, nkv, dh), dtype)
        return KVCache(k=z, v=z)
    if block_type == "rglru":
        return R.rglru_init_state(cfg, batch, dtype)
    if block_type == "mlstm":
        return R.mlstm_init_state(cfg, batch, dtype)
    if block_type == "slstm":
        return R.slstm_init_state(cfg, batch, dtype)
    raise ValueError(block_type)  # pragma: no cover


def apply_block_decode(p: Params, x_t: Array, state: Any, pos: Array,
                       cfg: ModelConfig, block_type: str,
                       cross_kv: Optional[Tuple[Array, Array]] = None
                       ) -> Tuple[Array, Any]:
    """x_t (B, 1, d); pos (B,). Returns (x_t, new_state)."""
    w = block_window(cfg, block_type)
    if block_type in ("attn", "swa", "local_attn", "attn_moe", "swa_moe"):
        ring = w > 0 and state.k.shape[1] <= w
        h = L.apply_norm(p["norm1"], x_t, cfg)
        attn, ck, cv = L.attention_decode(p["attn"], h, state.k, state.v,
                                          pos, cfg, window=w, ring=ring)
        x_t = x_t + attn
        state = KVCache(k=ck, v=cv)
        if "cross" in p and cross_kv is not None:
            h = L.apply_norm(p["cross_norm"], x_t, cfg)
            x_t = x_t + _cross_decode(p["cross"], h, cross_kv, cfg)
        h = L.apply_norm(p["norm2"], x_t, cfg)
        if block_type.endswith("moe"):
            delta, _ = L.apply_moe(p["moe"], h, cfg)
            x_t = x_t + delta
        else:
            x_t = x_t + L.apply_mlp(p["mlp"], h, cfg)
        return x_t, state
    if block_type == "rglru":
        h = L.apply_norm(p["norm1"], x_t, cfg)
        delta, new_r = R.apply_rglru_decode(p["rglru"], h[:, 0], state, cfg)
        x_t = x_t + delta[:, None, :]
        h = L.apply_norm(p["norm2"], x_t, cfg)
        return x_t + L.apply_mlp(p["mlp"], h, cfg), new_r
    if block_type == "mlstm":
        h = L.apply_norm(p["norm1"], x_t, cfg)
        delta, new_s = R.apply_mlstm_decode(p["mlstm"], h[:, 0], state, cfg)
        return x_t + delta[:, None, :], new_s
    if block_type == "slstm":
        h = L.apply_norm(p["norm1"], x_t, cfg)
        delta, new_s = R.apply_slstm_decode(p["slstm"], h[:, 0], state, cfg)
        return x_t + delta[:, None, :], new_s
    raise ValueError(block_type)  # pragma: no cover


def _cross_decode(p_cross: Params, x_t: Array,
                  cross_kv: Tuple[Array, Array], cfg: ModelConfig) -> Array:
    """Single-step cross-attention against precomputed encoder K/V."""
    b, _, d = x_t.shape
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = nq // nkv
    dt = x_t.dtype
    k, v = cross_kv
    q = (x_t @ p_cross["wq"].astype(dt)).reshape(b, 1, nkv, g, dh)
    if "q_norm" in p_cross:
        q = L._qk_norm(q, p_cross["q_norm"])
    sc = jnp.einsum("bsngh,btnh->bngst", q, k.astype(dt),
                    preferred_element_type=jnp.float32) / (dh ** 0.5)
    wts = jax.nn.softmax(sc, axis=-1).astype(dt)
    out = jnp.einsum("bngst,btnh->bsngh", wts, v.astype(dt))
    return out.reshape(b, 1, nq * dh) @ p_cross["wo"].astype(dt)
