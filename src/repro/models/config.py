"""Unified model configuration covering all 10 assigned architectures.

One dataclass + a block-pattern string list expresses dense / MoE / hybrid
(RG-LRU) / SSM (xLSTM) / VLM / audio enc-dec families.  Block types:

  "attn"        full (GQA) attention + MLP
  "swa"         sliding-window attention + MLP           (mixtral)
  "local_attn"  local window attention + MLP             (recurrentgemma)
  "attn_moe"    attention + MoE FFN                      (mixtral, granite)
  "swa_moe"     sliding-window attention + MoE FFN       (mixtral)
  "rglru"       RG-LRU recurrent block + MLP             (recurrentgemma)
  "mlstm"       xLSTM matrix-memory block (self-contained)
  "slstm"       xLSTM scalar-memory block (self-contained)

The pattern is cycled over ``n_layers``; the layer stack scans over whole
pattern units (HLO stays small, compile stays fast — DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

VALID_BLOCKS = ("attn", "swa", "local_attn", "attn_moe", "swa_moe",
                "rglru", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int                    # decoder layers for enc-dec
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp_type: str = "swiglu"         # swiglu|geglu|gelu
    norm_type: str = "rmsnorm"       # rmsnorm|layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # fraction of head dim rotated (stablelm .25)
    window: int = 4096               # swa window
    local_window: int = 2048         # local_attn window
    attn_chunk: int = 512            # online-softmax block (bounds VMEM/HBM
    #                                  transients: B·H·c² scores per block)
    dense_attn_threshold: int = 1024  # dense softmax below this seq len
    attn_schedule: str = "masked"    # "masked": every (q,kv) chunk pair is
    #                                  computed then masked (simple scan²,
    #                                  2x causal waste); "extent": static
    #                                  per-q-chunk kv ranges skip fully
    #                                  masked chunks (§Perf; falls back to
    #                                  masked above 16 q-chunks to bound HLO)
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_group_size: int = 1024       # GShard-style routing wave (tokens)
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"     # "einsum": GShard one-hot matmuls
    #                                  (baseline); "gather": index-based
    #                                  dispatch/combine — O(E·C·d) data
    #                                  movement instead of O(g·E·C·d) matmul
    #                                  flops (§Perf MoE iteration)
    # enc-dec (audio)
    encoder_layers: int = 0          # >0 -> encoder-decoder model
    # recurrent widths
    rnn_width: int = 0               # RG-LRU width (0 -> d_model)
    mlstm_proj_factor: float = 2.0   # xLSTM mLSTM up-projection
    slstm_proj_factor: float = 1.375  # xLSTM sLSTM FFN factor (qkv conv omitted)
    mlstm_chunk: int = 256           # chunkwise-parallel block; §Perf tunes
    #                                  toward dk (state-vs-intra balance)
    mlstm_state_dtype: str = "float32"  # carried C/N dtype (§Perf: bfloat16)
    decode_pos_mode: str = "ragged"  # "ragged": per-seq positions (scatter
    #                                  cache update); "uniform": one shared
    #                                  position (dynamic-update-slice — fully
    #                                  shardable, §Perf decode iteration)
    # frontends (assignment: modality frontends are stubs)
    frontend: str = "none"           # none|vq_tokens|audio_frames
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # mesh axes the activation batch dim is pinned to (with_sharding_constraint
    # at block boundaries — keeps GSPMD from replicating the token dim);
    # empty = no constraints (single-device tests)
    batch_axes: Tuple[str, ...] = ()
    # cast unit params to the activation dtype at the scan boundary so the
    # FSDP all-gather moves bf16, not f32 (§Perf: halves gather traffic;
    # master weights stay f32 in the optimizer)
    bf16_weight_gather: bool = False
    # Megatron-style sequence parallelism: residual stream pinned
    # (batch, S/model, d) at block boundaries — norm/residual cotangents stay
    # sharded instead of f32 full-activation gathers in backward (§Perf 5)
    sequence_parallel: bool = False
    # which shape cells this arch runs (assignment skip rules)
    supports_long_context: bool = False

    def __post_init__(self):
        for b in self.block_pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block type {b!r}")
        if any(b.endswith("moe") for b in self.block_pattern):
            if self.moe_experts <= 0 or self.moe_top_k <= 0:
                raise ValueError(f"{self.name}: moe blocks need moe_experts/top_k")

    # ------------------------------------------------------------------ dims
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        """Full pattern repetitions (scanned); remaining layers form `tail`."""
        return self.n_layers // len(self.block_pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        """Leftover blocks when n_layers isn't a pattern multiple (e.g.
        recurrentgemma's 38 = 12×(R,R,A) + (R,R)); applied after the scan."""
        return self.block_pattern[: self.n_layers % len(self.block_pattern)]

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def lru_width(self) -> int:
        return self.rnn_width or self.d_model

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        counts = {
            "embed": self.vocab_size * d,
            "head": 0 if self.tie_embeddings else self.vocab_size * d,
            "final_norm": d,
        }
        attn = d * nq * dh + 2 * d * nkv * dh + nq * dh * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * dh
        mlp_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        mlp = mlp_mats * d * self.d_ff
        moe = self.moe_experts * (mlp_mats * d * self.d_ff) + d * self.moe_experts
        lru = self.lru_width
        rglru = (2 * d * lru            # in/gate projections (x, y branches)
                 + lru * d              # out projection
                 + 3 * lru              # Λ, input-gate, rec-gate params (diag)
                 + 2 * lru * lru // 4)  # block-diag gate weights (4 blocks)
        dm = int(d * self.mlstm_proj_factor)
        mh = max(self.n_heads, 1)
        mlstm = (2 * d * dm                 # up (x2 branches)
                 + 3 * dm * dm // mh        # q,k,v block-diag per head
                 + 2 * dm * mh + 2 * mh     # i/f gate projections + biases
                 + dm * d)                  # down
        ds = int(d * self.slstm_proj_factor)
        slstm = (4 * d * d                  # i,f,z,o input weights
                 + 4 * d * d // mh          # block-diag recurrent weights
                 + 4 * d                    # biases
                 + 2 * d * ds)              # ffn
        per_block = {
            "attn": attn + mlp + 2 * d,
            "swa": attn + mlp + 2 * d,
            "local_attn": attn + mlp + 2 * d,
            "attn_moe": attn + moe + 2 * d,
            "swa_moe": attn + moe + 2 * d,
            "rglru": rglru + mlp + 2 * d,
            "mlstm": mlstm + d,
            "slstm": slstm + 2 * d,
        }
        total = counts["embed"] + counts["head"] + counts["final_norm"]
        for i in range(self.n_layers):
            total += per_block[self.block_pattern[i % len(self.block_pattern)]]
        if self.is_enc_dec:
            # encoder blocks (full attn, no extra embed) + cross-attn in decoder
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)      # cross-attention + norm
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of experts)."""
        if self.moe_experts == 0:
            return self.param_count()
        mlp_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        unused = (self.moe_experts - self.moe_top_k) * mlp_mats * \
            self.d_model * self.d_ff
        n_moe_blocks = sum(1 for i in range(self.n_layers)
                           if self.block_pattern[i % len(self.block_pattern)]
                           .endswith("moe"))
        return int(self.param_count() - n_moe_blocks * unused)
