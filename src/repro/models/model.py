"""Model assembly: embeddings, scan-over-units layer stack, enc-dec, decode.

The layer stack scans over pattern units with stacked params (leading dim =
n_units) and `jax.checkpoint` on the unit body — compile-friendly HLO (one
scan, not n_layers inlined bodies) and remat-bounded activation memory.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks as B
from . import layers as L
from .config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_unit(key, cfg: ModelConfig, with_cross: bool = False) -> Params:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {str(i): B.init_block(ks[i], cfg, bt, with_cross=with_cross)
            for i, bt in enumerate(cfg.block_pattern)}


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": 0.02 * jax.random.normal(
            ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32),
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = L._init(ks[1], (cfg.d_model, cfg.vocab_size))
    unit_keys = jax.random.split(ks[2], cfg.n_units)
    p["units"] = jax.vmap(
        lambda k: _init_unit(k, cfg, with_cross=cfg.is_enc_dec))(unit_keys)
    if cfg.tail_pattern:
        tks = jax.random.split(ks[4], len(cfg.tail_pattern))
        p["tail"] = {str(i): B.init_block(tks[i], cfg, bt,
                                          with_cross=cfg.is_enc_dec)
                     for i, bt in enumerate(cfg.tail_pattern)}
    if cfg.is_enc_dec:
        enc_cfg = cfg.with_overrides(block_pattern=("attn",),
                                     n_layers=cfg.encoder_layers,
                                     encoder_layers=0)
        enc_keys = jax.random.split(ks[3], cfg.encoder_layers)
        p["enc_units"] = jax.vmap(lambda k: _init_unit(k, enc_cfg))(enc_keys)
        p["enc_final_norm"] = L.init_norm(cfg)
    return p


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _scan_units(units: Params, x: Array, cfg: ModelConfig, positions: Array,
                *, causal: bool, enc_out: Optional[Array] = None,
                enc_pos: Optional[Array] = None,
                pattern: Optional[Tuple[str, ...]] = None,
                remat: bool = True) -> Tuple[Array, Array]:
    pattern = pattern or cfg.block_pattern

    if cfg.bf16_weight_gather:
        # cast the stacked params BEFORE the scan so the per-unit FSDP
        # all-gather (at the scan's xs slice) moves bf16, not f32 — master
        # f32 weights stay in the optimizer state; backward re-accumulates
        # f32 through the cast. (Casting inside the body is too late: the
        # gather sits at the slice — measured, see EXPERIMENTS.md §Perf 5.)
        units = jax.tree_util.tree_map(
            lambda p: p.astype(cfg.activation_dtype)
            if p.dtype == jnp.float32 and p.ndim >= 3 else p, units)

    def unit_fn(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        x = L.constrain_batch(x, cfg)
        for i, bt in enumerate(pattern):
            x, a = B.apply_block_train(unit_params[str(i)], x, cfg, bt,
                                       positions, causal=causal,
                                       enc_out=enc_out, enc_pos=enc_pos)
            x = L.constrain_batch(x, cfg)
            aux = aux + a
        return x, aux

    body = jax.checkpoint(unit_fn) if remat else unit_fn

    def scan_body(carry, unit_params):
        x, aux = carry
        x, a = body(x, unit_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               units)
    return x, aux


def embed_tokens(params: Params, tokens: Array, cfg: ModelConfig) -> Array:
    return L.constrain_batch(
        params["embed"].astype(cfg.activation_dtype)[tokens], cfg)


def logits_from_hidden(params: Params, x: Array, cfg: ModelConfig) -> Array:
    x = L.apply_norm(params["final_norm"], x, cfg)
    x = L.constrain_batch(x, cfg)
    if cfg.tie_embeddings:
        w = params["embed"].T.astype(x.dtype)
    else:
        w = params["head"].astype(x.dtype)
    # vocab sharding propagates from the (divisibility-guarded) head weight
    return (x @ w).astype(jnp.float32)


def encode(params: Params, frames: Array, cfg: ModelConfig) -> Array:
    """Encoder stack over precomputed frontend embeddings (B, S_enc, d)."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _scan_units(params["enc_units"], frames.astype(
        cfg.activation_dtype), cfg, pos, causal=False, pattern=("attn",))
    return L.apply_norm(params["enc_final_norm"], x, cfg)


def forward(params: Params, batch: Dict[str, Array],
            cfg: ModelConfig) -> Tuple[Array, Array]:
    """Training/prefill forward. batch: tokens (B,S) [+ frames for enc-dec].

    Returns (logits (B,S,V) fp32, aux loss).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(params, tokens, cfg)
    enc_out = enc_pos = None
    if cfg.is_enc_dec:
        enc_out = encode(params, batch["frames"], cfg)
        t = enc_out.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                   (b, t))
    x, aux = _scan_units(params["units"], x, cfg, positions, causal=True,
                         enc_out=enc_out, enc_pos=enc_pos)
    for i, bt in enumerate(cfg.tail_pattern):
        x, a = B.apply_block_train(params["tail"][str(i)], x, cfg, bt,
                                   positions, causal=True,
                                   enc_out=enc_out, enc_pos=enc_pos)
        aux = aux + a
    return logits_from_hidden(params, x, cfg), aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    block_states: Any        # pytree stacked over units
    pos: Array               # (B,) int32 next position to write
    cross_kv: Any            # optional (n_units, ...) cross K/V


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None, enc_out: Optional[Array] = None,
                      params: Optional[Params] = None) -> DecodeState:
    dtype = dtype or cfg.activation_dtype

    def one_unit(_):
        return {str(i): B.block_state_init(cfg, bt, batch, cache_len, dtype)
                for i, bt in enumerate(cfg.block_pattern)}

    states = jax.vmap(one_unit)(jnp.arange(cfg.n_units))
    if cfg.tail_pattern:
        tail = {str(i): B.block_state_init(cfg, bt, batch, cache_len, dtype)
                for i, bt in enumerate(cfg.tail_pattern)}
        states = {"units": states, "tail": tail}
    cross_kv = None
    if cfg.is_enc_dec and enc_out is not None and params is not None:
        cross_kv = precompute_cross_kv(params, enc_out, cfg)
    return DecodeState(block_states=states,
                       pos=jnp.zeros((batch,), jnp.int32),
                       cross_kv=cross_kv)


def abstract_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                          with_cross_len: int = 0) -> Any:
    """ShapeDtypeStruct decode state for the dry-run."""
    def build():
        st = init_decode_state(cfg, batch, cache_len)
        if with_cross_len:
            nkv, dh = cfg.n_kv_heads, cfg.head_dim
            kv = jnp.zeros((cfg.n_units, batch, with_cross_len, nkv, dh),
                           cfg.activation_dtype)
            st = st._replace(cross_kv=(kv, kv))
        return st

    return jax.eval_shape(build)


def precompute_cross_kv(params: Params, enc_out: Array,
                        cfg: ModelConfig) -> Tuple[Array, Array]:
    """Per-unit cross K/V from encoder output: (n_units, B, T, nkv, dh)."""
    def per_unit(unit_params):
        k, v, _ = B._cross_kv(unit_params["0"]["cross"], enc_out, cfg, None)
        return k, v

    return jax.vmap(per_unit)(params["units"])


def decode_step(params: Params, state: DecodeState, tokens: Array,
                cfg: ModelConfig) -> Tuple[Array, DecodeState]:
    """tokens (B, 1) -> (logits (B, 1, V) fp32, new state)."""
    x = L.constrain_batch(embed_tokens(params, tokens, cfg), cfg)

    def scan_body(carry, unit_in):
        x = carry
        if state.cross_kv is not None:
            unit_params, unit_state, (ck, cv) = unit_in
        else:
            unit_params, unit_state = unit_in
            ck = cv = None
        new_states = {}
        for i, bt in enumerate(cfg.block_pattern):
            cross = (ck, cv) if ck is not None else None
            x, ns = B.apply_block_decode(unit_params[str(i)], x,
                                         unit_state[str(i)], state.pos, cfg,
                                         bt, cross_kv=cross)
            new_states[str(i)] = ns
        return x, new_states

    has_tail = bool(cfg.tail_pattern)
    unit_states = (state.block_states["units"] if has_tail
                   else state.block_states)
    xs = ((params["units"], unit_states, state.cross_kv)
          if state.cross_kv is not None
          else (params["units"], unit_states))
    x, new_unit_states = jax.lax.scan(scan_body, x, xs)
    if has_tail:
        new_tail = {}
        for i, bt in enumerate(cfg.tail_pattern):
            x, ns = B.apply_block_decode(
                params["tail"][str(i)], x, state.block_states["tail"][str(i)],
                state.pos, cfg, bt)
            new_tail[str(i)] = ns
        new_block_states = {"units": new_unit_states, "tail": new_tail}
    else:
        new_block_states = new_unit_states
    logits = logits_from_hidden(params, x, cfg)
    return logits, state._replace(block_states=new_block_states,
                                  pos=state.pos + 1)
