"""Unified model core for the 10 assigned architectures."""

from .config import ModelConfig
from .model import (abstract_params, decode_step, encode, forward,
                    init_decode_state, init_params)
from .steps import (TrainState, abstract_train_state, cross_entropy,
                    init_train_state, make_loss_fn, make_serve_step,
                    make_train_step)
