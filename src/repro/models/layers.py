"""Shared layers: norms, RoPE, GQA attention (dense / chunked-online-softmax /
decode), MLPs and grouped-capacity MoE.

Precision policy: params fp32 (sharded), compute in cfg.dtype (bf16 default),
norms/softmax/logits accumulate fp32 — the production mixed-precision recipe.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]

NEG_INF = -1e30


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / (shape[0] ** 0.5))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def constrain_batch(x: Array, cfg: ModelConfig, *, rest=None) -> Array:
    """Pin the activation batch dim to the mesh batch axes (MaxText-style
    with_sharding_constraint at block boundaries).  Without this GSPMD may
    replicate the token dim across `data` — N_data× redundant compute
    (observed and fixed during the dry-run bring-up; see EXPERIMENTS.md).

    With cfg.sequence_parallel, the residual stream is additionally sharded
    (batch, S/model, d) — Megatron-SP: the norm/residual segments and their
    backward cotangents stay sharded over `model` instead of being gathered
    full per layer."""
    if not cfg.batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    tail = list(rest) if rest is not None else [None] * (x.ndim - 1)
    if (rest is None and cfg.sequence_parallel and x.ndim == 3
            and "model" not in cfg.batch_axes):
        tail[0] = "model"
    return jax.lax.with_sharding_constraint(x, P(tuple(cfg.batch_axes), *tail))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig) -> Array:
    dh = cfg.head_dim
    rot = int(dh * cfg.rope_pct) // 2 * 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32)
                                    / rot))
    return inv  # (rot/2,)


def apply_rope(x: Array, positions: Array, cfg: ModelConfig) -> Array:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = cfg.head_dim
    rot = int(dh * cfg.rope_pct) // 2 * 2
    if rot == 0:
        return x
    inv = rope_frequencies(cfg)                        # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, dh = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, nq * dh)),
        "wk": _init(ks[1], (d, nkv * dh)),
        "wv": _init(ks[2], (d, nkv * dh)),
        "wo": _init(ks[3], (nq * dh, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * dh,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _qk_norm(x: Array, scale: Array) -> Array:
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
            * scale).astype(x.dtype)


def _project_qkv(p: Params, x: Array, cfg: ModelConfig,
                 positions: Optional[Array]) -> Tuple[Array, Array, Array]:
    b, s, _ = x.shape
    dh, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, nq, dh)
    k = k.reshape(b, s, nkv, dh)
    v = v.reshape(b, s, nkv, dh)
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    if positions is not None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    return q, k, v


def _mask_bias(q_pos: Array, k_pos: Array, causal: bool, window: int) -> Array:
    """(..., S, T) additive mask from absolute positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones_like(diff, dtype=bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q: Array, k: Array, v: Array, bias: Array) -> Array:
    """q (B,S,nkv,g,dh), k/v (B,T,nkv,dh), bias (B,1 or nkv*g? ,S,T)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bsngh,btnh->bngst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / (dh ** 0.5) + bias[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bngst,btnh->bsngh", w, v)


def attention_full(p: Params, x: Array, cfg: ModelConfig, positions: Array,
                   *, causal: bool = True, window: int = 0,
                   kv_override: Optional[Tuple[Array, Array, Array]] = None,
                   chunk_q: Optional[int] = None) -> Array:
    """Full-sequence attention. Dense for short seq; chunked online-softmax
    (flash-style, O(S·chunk) memory) beyond ``chunk_q``.

    kv_override: (k, v, k_positions) for cross-attention.
    """
    b, s, d = x.shape
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = nq // nkv
    chunk_q = chunk_q or cfg.attn_chunk
    q, k, v = _project_qkv(p, x, cfg, positions)
    k_pos = positions
    if kv_override is not None:
        k, v, k_pos = kv_override
    qg = q.reshape(b, s, nkv, g, dh)

    t = k.shape[1]
    if s <= cfg.dense_attn_threshold or s != t or s % chunk_q != 0:
        bias = _mask_bias(positions, k_pos, causal, window)
        out = _sdpa(qg, k, v, bias)
    elif (cfg.attn_schedule == "extent" and causal
          and s // chunk_q <= 16):
        out = _extent_attention(qg, k, v, positions, k_pos, window, chunk_q)
    else:
        out = _chunked_attention(qg, k, v, positions, k_pos, causal, window,
                                 chunk_q)
    out = out.astype(x.dtype).reshape(b, s, nq * dh)
    return out @ p["wo"].astype(x.dtype)


def _chunked_attention(qg, k, v, q_pos, k_pos, causal, window, chunk):
    """Online-softmax over q and kv chunks — fixed memory, scan-of-scan HLO.

    Baseline ("masked") schedule: every (q-chunk, kv-chunk) pair is computed
    and masked; causal skipping is a §Perf hillclimb (see launch/dryrun notes).
    """
    b, s, nkv, g, dh = qg.shape
    t = k.shape[1]
    nqc = s // chunk
    nkc = t // chunk
    assert s % chunk == 0 and t % chunk == 0, (s, t, chunk)

    qg_c = qg.reshape(b, nqc, chunk, nkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qp_c = q_pos.reshape(b, nqc, chunk).transpose(1, 0, 2)
    k_c = k.reshape(b, nkc, chunk, nkv, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, nkc, chunk, nkv, dh).transpose(1, 0, 2, 3, 4)
    kp_c = k_pos.reshape(b, nkc, chunk).transpose(1, 0, 2)

    def q_step(_, q_in):
        q_blk, qp = q_in                                 # (B,c,nkv,g,dh), (B,c)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            k_blk, v_blk, kp = kv_in
            bias = _mask_bias(qp, kp, causal, window)    # (B,c,c)
            sc = jnp.einsum("bsngh,btnh->bngst", q_blk, k_blk,
                            preferred_element_type=jnp.float32)
            sc = sc / (dh ** 0.5) + bias[:, None, None, :, :]
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + pexp.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bngst,btnh->bngsh", pexp.astype(v_blk.dtype), v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nkv, g, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_c, v_c, kp_c))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4)        # (B,c,nkv,g,dh)

    _, outs = jax.lax.scan(q_step, None, (qg_c, qp_c))   # (nqc,B,c,nkv,g,dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, nkv, g, dh)


def _extent_attention(qg, k, v, q_pos, k_pos, window, chunk):
    """Causal chunked attention with static per-q-chunk kv extents.

    q-chunk i attends kv ∈ [lo_i, (i+1)·c) with lo_i = max(0, (i·c − w + 1)
    rounded down to a chunk) — fully-masked chunks are never computed
    (vs. the masked schedule's compute-then-mask: ~2x causal waste, ~w/S
    window waste).  Python loop over q chunks (static shapes per iteration,
    bounded count), inner online-softmax scan over the extent.
    """
    b, s, nkv, g, dh = qg.shape
    nqc = s // chunk
    outs = []
    for qi in range(nqc):
        lo = 0
        if window > 0:
            lo = max(0, (qi * chunk - window + 1)) // chunk * chunk
        hi = (qi + 1) * chunk
        q_blk = qg[:, qi * chunk: hi]
        qp = q_pos[:, qi * chunk: hi]
        k_ext = k[:, lo: hi]
        v_ext = v[:, lo: hi]
        kp_ext = k_pos[:, lo: hi]
        n_kv = (hi - lo) // chunk
        k_c = k_ext.reshape(b, n_kv, chunk, nkv, dh).transpose(1, 0, 2, 3, 4)
        v_c = v_ext.reshape(b, n_kv, chunk, nkv, dh).transpose(1, 0, 2, 3, 4)
        kp_c = kp_ext.reshape(b, n_kv, chunk).transpose(1, 0, 2)

        def kv_step(carry, kv_in, q_blk=q_blk, qp=qp):
            m, l, acc = carry
            k_blk, v_blk, kp = kv_in
            bias = _mask_bias(qp, kp, True, window)
            sc = jnp.einsum("bsngh,btnh->bngst", q_blk, k_blk,
                            preferred_element_type=jnp.float32)
            sc = sc / (dh ** 0.5) + bias[:, None, None, :, :]
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + pexp.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bngst,btnh->bngsh", pexp.astype(v_blk.dtype), v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nkv, g, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_c, v_c, kp_c))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.transpose(0, 3, 1, 2, 4))      # (B,c,nkv,g,dh)
    return jnp.concatenate(outs, axis=1)


def attention_decode(p: Params, x: Array, cache_k: Array, cache_v: Array,
                     pos: Array, cfg: ModelConfig, *, window: int = 0,
                     ring: bool = False) -> Tuple[Array, Array, Array]:
    """One-token decode with KV cache.

    x: (B, 1, d); cache_k/v: (B, S_cache, nkv, dh); pos: (B,) int32 current
    position.  ``ring=True`` uses the cache as a circular window buffer
    (S_cache == window) — bounded-memory SWA decode.
    Returns (attn_out (B,1,d), new_cache_k, new_cache_v).
    """
    b, _, d = x.shape
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = nq // nkv
    s_cache = cache_k.shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])

    slot = (pos % s_cache) if ring else pos
    if cfg.decode_pos_mode == "uniform":
        # all sequences share one position (synchronised batched decode):
        # dynamic-update-slice at a scalar index — fully shardable over the
        # batch axis, no gather/scatter of the cache (§Perf decode iteration)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot[0], 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot[0], 0, 0))
    else:
        # ragged per-sequence positions (continuous batching): scatter update
        bidx = jnp.arange(b)
        cache_k = cache_k.at[bidx, slot].set(k_new[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, slot].set(v_new[:, 0].astype(cache_v.dtype))

    idx = jnp.arange(s_cache)
    if ring:
        # slot i holds absolute position pos - ((pos - i) mod S); valid if >= 0
        k_positions = pos[:, None] - ((pos[:, None] - idx[None, :]) % s_cache)
        valid = k_positions >= 0
        if window > 0:
            valid &= (pos[:, None] - k_positions) < window
    else:
        k_positions = jnp.broadcast_to(idx[None, :], (b, s_cache))
        valid = idx[None, :] <= pos[:, None]
        if window > 0:
            valid &= (pos[:, None] - idx[None, :]) < window

    qg = q.reshape(b, 1, nkv, g, dh)
    sc = jnp.einsum("bsngh,btnh->bngst", qg, cache_k.astype(q.dtype),
                    preferred_element_type=jnp.float32) / (dh ** 0.5)
    sc = sc + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    w = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", w, cache_v.astype(q.dtype))
    out = out.reshape(b, 1, nq * dh) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wg": _init(ks[0], (d, f)), "wu": _init(ks[1], (d, f)),
                "wd": _init(ks[2], (f, d))}
    return {"wu": _init(ks[0], (d, f)), "bu": jnp.zeros((f,), jnp.float32),
            "wd": _init(ks[1], (f, d)), "bd": jnp.zeros((d,), jnp.float32)}


def apply_mlp(p: Params, x: Array, cfg: ModelConfig) -> Array:
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
        return h @ p["wd"].astype(dt)
    h = jax.nn.gelu(x @ p["wu"].astype(dt) + p["bu"].astype(dt))
    return h @ p["wd"].astype(dt) + p["bd"].astype(dt)


# ---------------------------------------------------------------------------
# MoE (GShard-style grouped capacity routing; DESIGN.md §5)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    p = {"router": _init(ks[0], (d, e), scale=0.02)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = _init(ks[1], (e, d, f))
        p["wu"] = _init(ks[2], (e, d, f))
        p["wd"] = _init(ks[3], (e, f, d))
    else:
        p["wu"] = _init(ks[1], (e, d, f))
        p["wd"] = _init(ks[2], (e, f, d))
    return p


def moe_capacity(cfg: ModelConfig, group: int) -> int:
    cap = int(group * cfg.moe_top_k * cfg.moe_capacity_factor
              / cfg.moe_experts)
    return max(cap, cfg.moe_top_k)


def apply_moe(p: Params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Grouped top-k capacity routing (GShard-style).

    The sequence is cut into "waves" of ``moe_group_size`` tokens per batch
    row; each wave routes independently with capacity C = g·k·cf/E.  The
    *batch* dim stays a vmap dim (it carries the data-sharding — scanning
    over it would serialize across devices); the *wave* dim is a lax.scan
    (bounds the (g, E, C) dispatch one-hots in memory).  Token order is
    preserved.  Returns (output, aux_load_balancing_loss).
    """
    b, s, d = x.shape
    e, topk = cfg.moe_experts, cfg.moe_top_k
    g = min(cfg.moe_group_size, s)
    assert s % g == 0, (s, g)
    n_waves = s // g
    cap = moe_capacity(cfg, g)
    dt = x.dtype

    waves = x.reshape(b, n_waves, g, d).transpose(1, 0, 2, 3)  # (W, B, g, d)

    def _experts(xin):
        """Batched expert FFN: (E, C, d) -> (E, C, d)."""
        if cfg.mlp_type in ("swiglu", "geglu"):
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(dt))) * \
                jnp.einsum("ecd,edf->ecf", xin, p["wu"].astype(dt))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin,
                                       p["wu"].astype(dt)))
        return jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))

    def route_group(xg):
        logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)  # (g, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, topk)                   # (g, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        sel = jax.nn.one_hot(top_e, e, dtype=jnp.float32)           # (g, k, E)
        sel_any = sel.sum(1)                                        # (g, E)
        # position of each token within its expert queue (per k slot,
        # priority: k slot 0 first, then token order)
        pos = jnp.cumsum(sel.reshape(g * topk, e), axis=0).reshape(
            g, topk, e) - sel  # 0-based
        keep = (pos < cap) * sel                                    # (g,k,E)
        pos_idx = jnp.minimum(pos, cap - 1).astype(jnp.int32)

        if cfg.moe_dispatch == "gather":
            # index-based dispatch: O(E·C·d) gathers, no one-hot matmuls.
            # slot = expert*cap + pos for each kept (token, k); unique by
            # construction (pos is a per-expert running count).
            slot_ek = (top_e * cap
                       + (pos_idx * sel).sum(-1).astype(jnp.int32))  # (g, k)
            kept = (keep.sum(-1) > 0)                                # (g, k)
            flat_slot = jnp.where(kept, slot_ek, e * cap)            # dump->EC
            tok_ids = jnp.broadcast_to(
                jnp.arange(g, dtype=jnp.int32)[:, None], (g, topk))
            buf_tok = jnp.full((e * cap + 1,), g, jnp.int32)         # g = zero row
            buf_tok = buf_tok.at[flat_slot.reshape(-1)].set(
                tok_ids.reshape(-1))
            xg_pad = jnp.concatenate(
                [xg, jnp.zeros((1, d), dt)], axis=0)                 # (g+1, d)
            xin = xg_pad[buf_tok[: e * cap]].reshape(e, cap, d)
            hout = _experts(xin)
            h_pad = jnp.concatenate(
                [hout.reshape(e * cap, d), jnp.zeros((1, d), dt)], axis=0)
            per_k = h_pad[jnp.where(kept, slot_ek, e * cap)]         # (g,k,d)
            yg = jnp.einsum("gk,gkd->gd", top_p.astype(dt)
                            * kept.astype(dt), per_k)
        else:
            # GShard one-hot einsum dispatch (baseline; §Perf shows the
            # combine matmul costs g·E·C·d flops — dominant when d_ff < d)
            disp = (keep[..., None]
                    * jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)).sum(1)
            comb = (keep * top_p[..., None])[..., None] * \
                jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)
            comb = comb.sum(1)                                       # (g,E,C)
            xin = jnp.einsum("gec,gd->ecd", disp.astype(dt), xg)     # (E,C,d)
            hout = _experts(xin)
            yg = jnp.einsum("gec,ecd->gd", comb.astype(dt), hout)
        # load-balancing aux (Switch): E * sum_e f_e * P_e
        f_e = sel_any.mean(0)
        p_e = probs.mean(0)
        aux = e * jnp.sum(f_e * p_e)
        return yg, aux

    def wave_step(_, xw):                       # xw: (B, g, d)
        yw, aux = jax.vmap(route_group)(xw)     # batch stays a vmap dim
        return None, (yw, aux.mean())

    if n_waves == 1:
        ys, auxs = jax.vmap(route_group)(waves[0])
        return ys.reshape(b, s, d), auxs.mean()
    _, (ys, auxs) = jax.lax.scan(wave_step, None, waves)  # (W, B, g, d)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d), auxs.mean()
